"""Pipelined overlap-aware serving benchmark (PR 6).

Replays the PR-3 Poisson arrival trace through ``CNNServingEngine`` at
pipeline depths {1, 2, 4} — depth 1 is the synchronous engine, depth >= 2
launches ticks asynchronously with double-buffered staging and donated
device inputs, retiring results lazily. Because overlap only exists in
real time, the replay here is the *wall-clock* discipline
(``_trace.replay_wallclock``): arrivals are released as real time passes
and the engine ticks continuously, so tick N+1's host-side packing
genuinely overlaps tick N's device compute. Three row groups:

* ``equiv`` — the same burst of requests pushed through depth-1 and
  depth-{2,4} engines dispatches the identical (bucket, batch) sequence,
  and per-request outputs must be **bitwise identical**
  (``np.array_equal``): async dispatch, buffer rotation and donation
  change scheduling and memory reuse, never math. Gated on every run,
  including ``--smoke``.
* ``replay`` — throughput/latency per depth on the raw engine. On a
  2-core CPU host device compute and host packing share the same cores,
  so the honest expectation is parity: the committed
  ``no_slower_depth2`` gate asserts throughput(depth 2) >= 0.90 ×
  throughput(depth 1) — the same envelope the layout and sharding
  benches use for shared-host noise — i.e. pipelining must cost nothing
  where it cannot win.
* ``delay`` — the same replay with an injected per-tick device delay
  (``device_delay_s`` = 2× the measured top-bucket service time),
  emulating a real accelerator whose compute the host does NOT share
  cores with. Sleeping releases the host, so the next tick's packing
  AND compute hide inside the current tick's delay window: the
  committed ``overlap_wins_under_delay`` gate asserts
  throughput(depth 2) > 1.15 × throughput(depth 1) in this
  configuration (ideal is ~2×: the synchronous engine pays the full
  delay per tick, depth 2 completes two ticks per delay).

``--smoke`` (CI serving-smoke job) runs the tiny-graph variant and gates
only output equivalence — wall-clock ratios on seconds-scale smoke runs
are scheduling noise, so the perf gates are enforced on the committed
full-run rows by the CI schema guard instead.
"""

from __future__ import annotations

import sys
from pathlib import Path
from typing import Dict, List

REPO = Path(__file__).resolve().parents[1]
for _p in (str(REPO), str(REPO / "src")):     # direct `python benchmarks/…`
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import numpy as np

from benchmarks._trace import hist, poisson_trace, replay_wallclock
from repro.cnn.executor import init_params
from repro.cnn.models import googlenet, vgg16
from repro.core.dse import identify_parameters
from repro.core.mapper import map_network
from repro.serving.cnn_engine import CNNRequest, CNNServingEngine

DEPTHS = (1, 2, 4)
# Same 10% envelope (and rationale) as bench_layout_elision/_sharded:
# same-program process-to-process variance exceeds 5% on shared-CPU
# hosts, so tighter no-slower margins would gate on scheduling luck.
NO_SLOWER_ENVELOPE = 0.90
# The injected-delay configuration emulates a device the host does not
# share cores with; depth 2 must win by strictly more than this.
DELAY_SPEEDUP_GATE = 1.15
ROW_PREFIX = "pipelined_serving,"


def _mk_engine(g, params, plan, batch, depth, delay_s=0.0):
    return CNNServingEngine(g, params, plan, batch_size=batch,
                            pipeline_depth=depth, device_delay_s=delay_s,
                            warmup=True)


def _equiv_rows(tag: str, g, params, plan, batch: int,
                n: int) -> List[str]:
    """Burst-drain the same requests through every depth; per-request
    outputs must be bitwise identical to the synchronous engine's (the
    dispatch sequence is deterministic: all requests queued up front +
    flush ticks ⇒ identical (bucket, batch) splits at every depth)."""
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])
    rng = np.random.default_rng(3)
    imgs = rng.standard_normal((n,) + shape).astype(np.float32)
    outs: Dict[int, Dict[int, np.ndarray]] = {}
    for depth in DEPTHS:
        eng = _mk_engine(g, params, plan, batch, depth)
        for i in range(n):
            eng.submit(CNNRequest(rid=i, image=imgs[i]))
        done = eng.run_until_done()
        outs[depth] = {rid: np.asarray(v) for rid, v in done.items()}
        assert len(outs[depth]) == n
    rows, all_ok = [], True
    for depth in DEPTHS[1:]:
        ok = all(np.array_equal(outs[1][r], outs[depth][r])
                 for r in range(n))
        all_ok &= ok
        rows.append(f"pipelined_serving,{tag},equiv,depth_{depth},"
                    f"outputs_identical,{ok}")
    rows.append(f"pipelined_serving,{tag},summary,-,outputs_ok,{all_ok}")
    return rows


def _replay_depths(tag: str, g, params, plan, batch: int, trace,
                   group: str, delay_s: float,
                   reps: int) -> Dict[int, float]:
    """One warmed engine per depth, the same trace replayed ``reps`` times
    each; best-of-reps throughput per depth (min-wall estimator — ambient
    load only ever slows a replay down). Returns {depth: rps} and appends
    per-depth rows via the returned dict's consumer."""
    self_rows: List[str] = []
    tput: Dict[int, float] = {}
    for depth in DEPTHS:
        eng = _mk_engine(g, params, plan, batch, depth, delay_s)
        best_rps, lat_at_best = 0.0, None
        for _ in range(reps):
            eng.reset()
            lat, makespan = replay_wallclock(eng, trace)
            rps = len(lat) / makespan
            if rps > best_rps:
                best_rps, lat_at_best = rps, lat
        st = eng.stats()
        pre = f"pipelined_serving,{tag},depth_{depth},{group}"
        self_rows.append(f"{pre},throughput_rps,{best_rps:.2f}")
        self_rows.append(
            f"{pre},p50_ms,"
            f"{float(np.percentile(lat_at_best, 50)) * 1e3:.2f}")
        self_rows.append(
            f"{pre},p99_ms,"
            f"{float(np.percentile(lat_at_best, 99)) * 1e3:.2f}")
        self_rows.append(f"{pre},served,{len(lat_at_best)}")
        self_rows.append(f"{pre},dispatch_hist,{hist(eng)}")
        self_rows.append(f"{pre},overlap_ratio,"
                         f"{st['pipeline']['overlap_ratio']:.3f}")
        tput[depth] = best_rps
    tput["rows"] = self_rows            # piggyback (consumed by run())
    return tput


def _measure(smoke: bool) -> List[str]:
    if smoke:
        tag, g = "vgg16_r8_smoke", vgg16(res=8, scale=0.05)
        plan, batch, n_requests, reps = None, 4, 24, 2
    else:
        tag, g = "googlenet_r56", googlenet(res=56, scale=0.25)
        hw = identify_parameters(g, max_dim=512)
        plan = map_network(g, hw=hw)
        batch, n_requests, reps = 8, 96, 3
    params = init_params(g, jax.random.PRNGKey(0))
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])

    rows = [f"pipelined_serving,{tag},config,-,batch,{batch}",
            f"pipelined_serving,{tag},config,-,n_requests,{n_requests}",
            f"pipelined_serving,{tag},config,-,depths,"
            f"{'|'.join(str(d) for d in DEPTHS)}"]

    # ---- equivalence (the hard gate, every run) ------------------------
    rows += _equiv_rows(tag, g, params, plan, batch, n_requests)

    # ---- offered load: 1.5x the saturation of the synchronous engine ---
    # Above saturation the queue backlogs, so every depth dispatches
    # continuously and throughput measures the tick pipeline itself, not
    # arrival gaps.
    probe = _mk_engine(g, params, plan, batch, 1)
    svc_top = probe.service_estimate(batch)
    rate = 1.5 * batch / svc_top
    trace = poisson_trace(rate, n_requests, shape, seed=42)
    rows.append(f"pipelined_serving,{tag},config,-,"
                f"svc_ms_top,{svc_top * 1e3:.2f}")
    rows.append(f"pipelined_serving,{tag},config,-,arrival_rps,{rate:.2f}")

    # ---- raw replay per depth ------------------------------------------
    raw = _replay_depths(tag, g, params, plan, batch, trace,
                         "replay", 0.0, reps)
    rows += raw.pop("rows")

    # ---- injected-device-delay replay per depth ------------------------
    # Delay = 2x the measured per-tick service time, same saturated
    # trace: the synchronous engine pays max(compute, delay) = the full
    # delay per tick (its compute hides inside the block), while at
    # depth 2 the NEXT tick is packed, launched and computed during the
    # current tick's delay window — two completions per delay, ideal
    # speedup ~2x. A delay <= the compute time would hide entirely
    # inside the block at every depth and prove nothing.
    delay_s = 2.0 * svc_top
    rows.append(f"pipelined_serving,{tag},config,-,"
                f"device_delay_ms,{delay_s * 1e3:.2f}")
    dly = _replay_depths(tag, g, params, plan, batch, trace,
                         "delay", delay_s, reps)
    rows += dly.pop("rows")

    # ---- summary gates -------------------------------------------------
    for d in DEPTHS[1:]:
        rows.append(f"pipelined_serving,{tag},summary,-,"
                    f"tput_ratio_{d}_over_1,{raw[d] / raw[1]:.3f}")
        rows.append(f"pipelined_serving,{tag},summary,-,"
                    f"delay_tput_ratio_{d}_over_1,{dly[d] / dly[1]:.3f}")
    no_slower = raw[2] >= NO_SLOWER_ENVELOPE * raw[1]
    delay_win = dly[2] > DELAY_SPEEDUP_GATE * dly[1]
    rows.append(f"pipelined_serving,{tag},summary,-,"
                f"no_slower_depth2,{no_slower}")
    rows.append(f"pipelined_serving,{tag},summary,-,"
                f"overlap_wins_under_delay,{delay_win}")
    return rows


def run(smoke: bool = False) -> List[str]:
    return _measure(smoke)


if __name__ == "__main__":
    out = run(smoke="--smoke" in sys.argv)
    print("\n".join(out))
    # Equivalence gates every invocation (including --smoke); the
    # wall-clock throughput gates are only enforced for the committed
    # full-run rows (CI schema guard) — smoke-scale replays on shared CI
    # hosts are scheduling noise.
    if any(row.endswith("outputs_ok,False") for row in out):
        sys.exit(1)
