"""DSE + solver timing: Algorithm-1 sweep and PBQP solve time (the paper
reports < 2 s on an AMD 3700X for the full Inception-v4 mapping)."""
from __future__ import annotations

import time
from typing import List

from repro.cnn.models import alexnet, googlenet, inception_v4, resnet18, vgg16
from repro.core.cost_model import V5E
from repro.core.dse import identify_parameters
from repro.core.mapper import CostGraphBuilder, map_network
from repro.core.pbqp import solve_series_parallel


def run() -> List[str]:
    rows: List[str] = []
    nets = {"alexnet": alexnet(), "vgg16": vgg16(), "resnet18": resnet18(),
            "googlenet": googlenet(res=224),
            "inception_v4": inception_v4(res=299)}
    for name, g in nets.items():
        t0 = time.time()
        hw = identify_parameters(g, max_dim=1024)
        t_dse = time.time() - t0
        builder = CostGraphBuilder(g, hw)
        t0 = time.time()
        pbqp, _ = builder.build()
        t_build = time.time() - t0
        t0 = time.time()
        res = solve_series_parallel(pbqp)
        t_solve = time.time() - t0
        n_states = 1.0
        for c in pbqp.costs.values():
            n_states *= c.size
        rows.append(
            f"dse,{name},convs={len(g.conv_nodes())},"
            f"space={n_states:.2e},dse_s={t_dse:.3f},"
            f"build_s={t_build:.3f},solve_s={t_solve:.4f},"
            f"exact={res.exact},p1={hw.p1},p2={hw.p2}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
