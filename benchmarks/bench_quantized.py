"""Mixed-precision (int8 overlay) vs all-bf16 benchmark (PR 9).

Two compiled variants of the same network, measured end-to-end over the
batch bucket ladder on reduced GoogleNet:

* ``bf16``  — ``map_network(g)``: the plan every PR before this one
  executed, all layers at the overlay's native precision;
* ``mixed`` — ``plan_mixed_precision(...)``: the precision-aware PBQP
  (int8 replicas priced with ``V5E_INT8``, boundary-conversion edge
  costs) with the accuracy gate armed — layers whose isolated int8 error
  exceeds the tolerance are demoted back to bf16 before the plan is
  finalized, so the committed plan is the one the gate would actually
  ship.

Both variants compute the same function up to quantization error, so
outputs must agree within the gate's tolerance (``outputs_ok``), every
int8 layer's isolated error must sit inside the gate (``accuracy_ok``),
and the mixed program must be no slower end-to-end (``no_slower``: the
summed median wall clock of one tick per bucket across the whole ladder,
within a 10% noise envelope — on CPU interpret/emulation backends int8
brings no machine speedup, so the gate asserts the quantized lowering
costs nothing, while the ``V5E_INT8`` cost model carries the >=1.5x
predicted win). The full run additionally asserts the PBQP actually
mixes precisions on GoogleNet (``precision_spread_ok``: >=1 int8 AND
>=1 bf16 layer — Winograd-winning layers must stay bf16).

Run standalone (``python benchmarks/bench_quantized.py``) or via
``benchmarks/run.py``; ``--smoke`` runs a tiny graph in seconds for CI.
"""
from __future__ import annotations

import sys
from typing import List

import jax
import numpy as np

from repro.cnn.executor import compile_plan, init_params
from repro.cnn.models import googlenet, vgg16
from repro.core.dse import identify_parameters
from repro.core.mapper import map_network
from repro.core.quant import plan_mixed_precision

try:                                    # package mode (benchmarks.run)
    from benchmarks._timing import sampled_interleaved
except ImportError:                     # script mode (python benchmarks/x.py)
    from _timing import sampled_interleaved

# Gate tolerance: a strict 1.2% isolated-layer error budget
# (mean|int8 - f32| over the median |f32| output magnitude). On reduced
# GoogleNet the per-layer errors straddle this line, so the committed
# plan exercises BOTH sides of the gate — most layers stay int8, the
# noisiest demote to bf16 — which is exactly the mixed regime the
# precision-aware PBQP exists for.
TOL = 0.012


def run(smoke: bool = False) -> List[str]:
    if smoke:
        tag, g = "vgg16_r8_smoke", vgg16(res=8, scale=0.05)
        batches, reps, hw = (1, 2), 3, None
    else:
        tag, g = "googlenet_r56", googlenet(res=56, scale=0.25)
        batches, reps = (1, 2, 4, 8), 13
        hw = identify_parameters(g, max_dim=512)
    params = init_params(g, jax.random.PRNGKey(0))
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])

    # Calibrate + gate on a small sample batch, then reuse the gated plan
    # (and its activation scales) for every bucket — exactly the artifact
    # a serving deployment would commit.
    calib = jax.random.normal(jax.random.PRNGKey(1), (2,) + shape)
    report = plan_mixed_precision(g, params, calib, tol=TOL, hw=hw)
    plan_bf16 = map_network(g, hw=hw)

    mix = report.precision_mix
    int8_errs = [report.errors[n] for n, p in report.plan.precisions.items()
                 if p == "int8"]
    rows = [
        f"quantized,{tag},config,int8_layers,{mix.get('int8', 0)}",
        f"quantized,{tag},config,bf16_layers,{mix.get('bf16', 0)}",
        f"quantized,{tag},config,demoted_layers,{len(report.demoted)}",
        f"quantized,{tag},config,gate_rounds,{report.rounds}",
        f"quantized,{tag},config,gate_tol,{TOL}",
        f"quantized,{tag},config,max_layer_err,"
        f"{max(report.errors.values()):.4f}",
        f"quantized,{tag},config,max_int8_layer_err,"
        f"{max(int8_errs) if int8_errs else 0.0:.4f}",
    ]

    runs = {
        "mixed": compile_plan(g, report.plan, act_scales=report.act_scales),
        "bf16": compile_plan(g, plan_bf16),
    }
    ok = True
    med = {name: {} for name in runs}
    for batch in batches:
        xb = jax.random.normal(jax.random.PRNGKey(2), (batch,) + shape)
        out = {name: np.asarray(r(params, xb)) for name, r in runs.items()}
        # Quantization error is real but gated: end-to-end outputs track
        # the bf16 program within the same envelope the accuracy tests
        # use for gated plans.
        ok &= bool(np.allclose(out["mixed"], out["bf16"],
                               rtol=0.1, atol=0.05))
        samples = sampled_interleaved(
            {name: (lambda r=r, x=xb: r(params, x))
             for name, r in runs.items()}, reps=reps)
        ms = {name: min(s) * 1e3 for name, s in samples.items()}
        for name, s in samples.items():
            med[name][batch] = float(np.median(s))
        # Paired per-rep comparison: each rep measures both variants
        # back-to-back, so the median of per-rep ratios cancels
        # machine-phase drift a min-vs-min comparison is hostage to.
        speedup = float(np.median(
            [bf / mx for bf, mx in
             zip(samples["bf16"], samples["mixed"])]))
        pre = f"quantized,{tag},b{batch}"
        rows.append(f"{pre},mixed_ms,{ms['mixed']:.2f}")
        rows.append(f"{pre},bf16_ms,{ms['bf16']:.2f}")
        rows.append(f"{pre},speedup_x,{speedup:.3f}")

    # Same aggregate-within-envelope gate as bench_layout_elision: the
    # summed ladder absorbs the >5% process-to-process jitter shared-CPU
    # hosts show on identical programs; per-bucket rows stay raw.
    mx_total = sum(med["mixed"].values())
    bf_total = sum(med["bf16"].values())
    no_slower = mx_total <= bf_total * 1.10
    accuracy_ok = all(e <= TOL for e in int8_errs)

    pre = f"quantized,{tag},summary"
    rows.append(f"{pre},mixed_ladder_ms,{mx_total * 1e3:.2f}")
    rows.append(f"{pre},bf16_ladder_ms,{bf_total * 1e3:.2f}")
    rows.append(f"{pre},outputs_ok,{ok}")
    rows.append(f"{pre},accuracy_ok,{accuracy_ok}")
    rows.append(f"{pre},no_slower,{no_slower}")
    if not smoke:
        # GoogleNet acceptance: the joint solve picks int8 where it pays
        # and keeps bf16 where Winograd wins — both must be present.
        spread_ok = mix.get("int8", 0) >= 1 and mix.get("bf16", 0) >= 1
        rows.append(f"{pre},precision_spread_ok,{spread_ok}")
    return rows


if __name__ == "__main__":
    out = run(smoke="--smoke" in sys.argv)
    print("\n".join(out))
    # Correctness + the accuracy gate gate the smoke job; the no_slower
    # perf summary is too noisy to assert on the tiny smoke graph and is
    # only enforced for the committed full-run rows (CI schema guard).
    if any(row.endswith(("outputs_ok,False", "accuracy_ok,False"))
           for row in out):
        sys.exit(1)
