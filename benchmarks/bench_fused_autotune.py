"""Fused-epilogue + measured-autotuning trajectory benchmark (PR 2).

Three compiled variants of the same reduced-GoogleNet plan, measured
end-to-end at batch 1 and batch 8:

* ``unfused_model`` — the PR-1 lowering: conv then separate ReLU op,
  cost-model (p1, p2)/dataflow binding (``epilogue="none"``);
* ``fused``        — CONV+ReLU lowered to ONE overlay call per layer
  (``epilogue="relu"``, the new default);
* ``fused_tuned``  — fused + a ``core.autotune`` record: every conv
  signature's (algorithm, dataflow, p1, p2, backend) binding replaced by
  the winner *measured on this device*.

Also emitted: per-layer model-binding vs measured-winner microbenchmarks
for the heaviest conv signatures, and a mixed-backend equivalence check
(one compiled plan alternating pallas/reference per layer vs the
all-reference oracle).

Run standalone (``python benchmarks/bench_fused_autotune.py``) or via
``benchmarks/run.py``; ``--smoke`` runs a tiny graph in seconds for CI.
"""
from __future__ import annotations

import sys
import time
from typing import List

import jax
import numpy as np

from repro.cnn.executor import compile_plan, init_params
from repro.cnn.models import googlenet, vgg16
from repro.core.autotune import (Binding, LayerTuning, TuningRecord,
                                 autotune_graph, benchmark_binding, conv_key,
                                 record_key)
from repro.core.cost_model import Dataflow
from repro.core.dse import identify_parameters
from repro.core.mapper import map_network


try:                                    # package mode (benchmarks.run)
    from benchmarks._timing import timed_interleaved
except ImportError:                     # script mode (python benchmarks/x.py)
    from _timing import timed_interleaved


def _e2e_rows(tag: str, g, plan, records, reps: int = 7) -> List[str]:
    """records: {batch: TuningRecord} — each batch is compared against a
    record *tuned at that batch size* (binding rankings shift with batch)."""
    params = init_params(g, jax.random.PRNGKey(0))
    res = g.nodes[g.source()].attrs["out_shape"]
    rows = []
    for batch, record in records.items():
        runs = {
            "unfused_model": compile_plan(g, plan, epilogue="none"),
            "fused": compile_plan(g, plan),
            "fused_tuned": compile_plan(g, plan, tuning=record),
        }
        xb = jax.random.normal(jax.random.PRNGKey(2), (batch,) + tuple(res))
        secs = timed_interleaved(
            {name: (lambda r=run: r(params, xb)) for name, run in runs.items()},
            reps=reps)
        ms = {name: s * 1e3 for name, s in secs.items()}
        for name in runs:
            rows.append(f"fused_autotune,{tag},batch{batch},"
                        f"{name}_ms,{ms[name]:.2f}")
        rows.append(f"fused_autotune,{tag},batch{batch},fused_speedup_x,"
                    f"{ms['unfused_model'] / ms['fused']:.3f}")
        rows.append(f"fused_autotune,{tag},batch{batch},tuned_speedup_x,"
                    f"{ms['unfused_model'] / ms['fused_tuned']:.3f}")
    return rows


def _per_layer_rows(tag: str, g, plan, record: TuningRecord,
                    top_n: int, reps: int) -> List[str]:
    """Heaviest conv signatures: model-predicted binding vs measured
    winner, both timed on the device (μs)."""
    rows = []
    by_key = {}
    for node in g.conv_nodes():
        by_key.setdefault(conv_key(node.conv), node)
    heavy = sorted(by_key.values(), key=lambda n: -n.conv.macs)[:top_n]
    for node in heavy:
        key = conv_key(node.conv)
        model = Binding(plan.assignment[node.id].key,
                        plan.dataflows[node.id].name,
                        plan.p1, plan.p2, "reference")
        tuned = record.lookup(node.conv)
        # tune_layer already timed the model baseline (first candidate);
        # only re-measure if this layer's plan binding wasn't the baseline.
        timed = dict(tuned.candidates)
        model_s = timed.get(model.label())
        if model_s is None:
            model_s = benchmark_binding(node.conv, model, reps=reps)
        rows.append(
            f"fused_autotune_layer,{tag},{key},"
            f"model:{model.label()},{model_s * 1e6:.0f},"
            f"tuned:{tuned.binding.label()},{tuned.measured_s * 1e6:.0f},"
            f"{model_s / tuned.measured_s:.2f}x")
    return rows


def _mixed_backend_row(tag: str, g) -> List[str]:
    """One compiled plan alternating pallas/reference per conv layer must be
    numerically identical (to tolerance) to the all-reference oracle."""
    entries = {}
    for i, node in enumerate(g.conv_nodes()):
        entries[record_key(node.conv)] = LayerTuning(
            binding=Binding("im2col", "NS", 128, 128,
                            "pallas" if i % 2 == 0 else "reference"),
            measured_s=0.0, candidates=[])
    params = init_params(g, jax.random.PRNGKey(0))
    res = g.nodes[g.source()].attrs["out_shape"]
    x = jax.random.normal(jax.random.PRNGKey(3), (2,) + tuple(res))
    mixed = compile_plan(g, tuning=TuningRecord(entries),
                         interpret=True)(params, x)
    oracle = compile_plan(g)(params, x)
    ok = bool(np.allclose(np.asarray(mixed), np.asarray(oracle),
                          rtol=2e-2, atol=2e-3))
    return [f"fused_autotune,{tag},mixed_backend,matches_reference,{ok}"]


def run(smoke: bool = False) -> List[str]:
    if smoke:
        tag, g = "vgg16_r8_smoke", vgg16(res=8, scale=0.05)
        batches, top_n, reps, e2e_reps = (1,), 2, 1, 3
    else:
        tag, g = "googlenet_r56", googlenet(res=56, scale=0.25)
        batches, top_n, reps, e2e_reps = (1, 8), 5, 2, 7
    hw = identify_parameters(g, max_dim=512)
    plan = map_network(g, hw=hw)

    rows = []
    records = {}
    for batch in batches:
        # Sweeping interpret-mode Pallas candidates at batch>1 is
        # prohibitively slow on CPU; batched tuning searches the lax +
        # reference backends (algorithm/dataflow selection stays live).
        backends = (("lax", "reference", "pallas") if batch == 1
                    else ("lax", "reference"))
        t0 = time.time()
        rec = autotune_graph(g, plan, dataflows=(Dataflow.NS,), reps=reps,
                             batch=None if batch == 1 else batch,
                             backends=backends)
        records[batch] = rec
        won_b = sorted({t.binding.backend for t in rec.entries.values()})
        won_a = sorted({t.binding.algo_key for t in rec.entries.values()})
        rows += [
            f"fused_autotune,{tag},autotune_b{batch},signatures,"
            f"{len(rec.entries)}",
            f"fused_autotune,{tag},autotune_b{batch},wall_s,"
            f"{time.time() - t0:.1f}",
            f"fused_autotune,{tag},autotune_b{batch},winner_backends,"
            + "|".join(won_b),
            f"fused_autotune,{tag},autotune_b{batch},winner_algos,"
            + "|".join(won_a),
        ]

    rows += _e2e_rows(tag, g, plan, records, reps=e2e_reps)
    rows += _per_layer_rows(tag, g, plan, records[batches[0]], top_n,
                            max(reps, 2))
    rows += _mixed_backend_row(tag, g)
    return rows


if __name__ == "__main__":
    print("\n".join(run(smoke="--smoke" in sys.argv)))
