"""Dynamic-batching arrival-trace benchmark (PR 3).

Replays Poisson arrival traces at several rates through two serving
policies over the same compiled-overlay stack:

* ``fixed8`` — the PR-2 engine: ONE batch-8 executable, every tick padded
  to 8 (a lone request pays the full batch-8 latency);
* ``bucketed_slo`` — the dynamic-batching engine: one executable per batch
  bucket {1, 2, 4, 8}, each lowered under the (signature, bucket) tuning
  winner, with the SLO tick scheduler (wait to fill a larger bucket while
  the oldest request has deadline budget, dispatch early when it is
  nearly spent).

The replay is a virtual-clock discrete-event loop (shared machinery in
``benchmarks/_trace.py``): arrivals carry synthetic timestamps, every
tick runs the REAL compiled program and its measured wall time advances
the clock — so per-request latency combines real service time with
simulated queueing. Rows record p50/p99 latency
and served throughput per (rate, policy), plus summary comparisons:
``bucketed_slo`` must beat ``fixed8`` p99 at the low rate and match its
throughput (>= 90%) at saturation.

``--smoke`` (CI's serving-smoke job) drives the engine end to end on a
tiny graph under bursty and trickle arrival patterns and checks outputs
against the eager reference.
"""

from __future__ import annotations

import sys
import time
from pathlib import Path
from typing import Dict, List, Optional

REPO = Path(__file__).resolve().parents[1]
for _p in (str(REPO), str(REPO / "src")):  # direct `python benchmarks/…`
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._trace import hist as _hist
from benchmarks._trace import poisson_trace as _poisson_trace
from benchmarks._trace import replay as _replay
from repro.cnn.executor import forward, init_params
from repro.cnn.models import googlenet, vgg16
from repro.core.autotune import TuningRecord, autotune_buckets
from repro.core.dse import identify_parameters
from repro.core.mapper import map_network
from repro.serving.cnn_engine import CNNServingEngine


def _engines(
    g, params, record: Optional[TuningRecord]
) -> Dict[str, CNNServingEngine]:
    """The two policies under test, both warmed (executables compiled,
    service estimates primed) so replay wall times are steady-state. The
    bucketed engine's SLO is set afterwards from measured service times."""
    fixed = CNNServingEngine(
        g, params, None, buckets=(8,), tuning=record, warmup=True
    )
    bucketed = CNNServingEngine(
        g, params, None, batch_size=8, tuning=record, warmup=True
    )
    return {"fixed8": fixed, "bucketed_slo": bucketed}


def _rate_rows(
    tag: str,
    g,
    params,
    record: Optional[TuningRecord],
    n_requests: int,
) -> List[str]:
    rows = []
    engines = _engines(g, params, record)
    svc1 = engines["bucketed_slo"].service_estimate(1)
    svc8 = engines["fixed8"].service_estimate(8)
    # SLO between the bucket-1 and bucket-8 service times: a lone request
    # is worth dispatching early, a fillable batch is worth a short wait.
    slo_s = 2.5 * svc1
    engines["bucketed_slo"].slo_s = slo_s
    saturation_rps = 8.0 / svc8
    rates = {
        "low": 0.15 * saturation_rps,
        "mid": 0.6 * saturation_rps,
        "high": 1.2 * saturation_rps,
    }
    rows.append(f"dynamic_batching,{tag},config,-,svc_ms_b1,{svc1 * 1e3:.2f}")
    rows.append(f"dynamic_batching,{tag},config,-,svc_ms_b8,{svc8 * 1e3:.2f}")
    rows.append(f"dynamic_batching,{tag},config,-,slo_ms,{slo_s * 1e3:.2f}")

    shape = tuple(g.nodes[g.source()].attrs["out_shape"])
    p99 = {}
    tput = {}
    for name, rate in rates.items():
        trace = _poisson_trace(rate, n_requests, shape, seed=42)
        for policy in ("fixed8", "bucketed_slo"):
            eng = engines[policy]
            eng.reset()
            lat, makespan = _replay(eng, trace)
            p50_ms = float(np.percentile(lat, 50)) * 1e3
            p99_ms = float(np.percentile(lat, 99)) * 1e3
            rps = len(lat) / makespan
            p99[(name, policy)] = p99_ms
            tput[(name, policy)] = rps
            pre = f"dynamic_batching,{tag},rate_{name},{policy}"
            rows.append(f"{pre},p50_ms,{p50_ms:.2f}")
            rows.append(f"{pre},p99_ms,{p99_ms:.2f}")
            rows.append(f"{pre},throughput_rps,{rps:.2f}")
            rows.append(f"{pre},served,{len(lat)}")
            rows.append(f"{pre},dispatch_hist,{_hist(eng)}")
        rows.append(
            f"dynamic_batching,{tag},rate_{name},-,arrival_rps,{rate:.2f}"
        )
    p99_win = p99[("low", "bucketed_slo")] < p99[("low", "fixed8")]
    tput_ok = tput[("high", "bucketed_slo")] >= 0.9 * tput[("high", "fixed8")]
    rows.append(f"dynamic_batching,{tag},summary,-,p99_win_low_rate,{p99_win}")
    rows.append(
        "dynamic_batching,"
        f"{tag},summary,-,throughput_match_saturation,{tput_ok}"
    )
    return rows


def _smoke_pattern_rows(
    tag: str, g, params, record: Optional[TuningRecord]
) -> List[str]:
    """Bursty + trickle arrival patterns through the bucketed-SLO engine,
    outputs checked against the eager reference (CI serving-smoke)."""
    rows = []
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])
    rng = np.random.default_rng(7)
    patterns = {
        # every request at t=0: exercises the max bucket + padded tail
        "smoke_bursty": [0.0] * 10,
        # arrivals spaced past any SLO: every dispatch is SLO-forced
        "smoke_trickle": [float(5 * i) for i in range(5)],
    }
    for name, times in patterns.items():
        eng = CNNServingEngine(
            g, params, None, batch_size=8, slo_s=0.05, tuning=record
        )
        imgs = rng.standard_normal((len(times),) + shape).astype(np.float32)
        trace = [(times[i], imgs[i]) for i in range(len(times))]
        lat, _ = _replay(eng, trace)
        ok = True
        for rid in range(len(times)):
            want = np.asarray(forward(g, params, jnp.asarray(imgs[rid])))
            good = np.allclose(eng.done[rid], want, rtol=2e-2, atol=2e-3)
            ok &= bool(good)
        pre = f"dynamic_batching,{tag},{name},bucketed_slo"
        rows.append(f"{pre},served,{len(lat)}")
        rows.append(f"{pre},dispatch_hist,{_hist(eng)}")
        rows.append(f"{pre},outputs_ok,{ok}")
    return rows


def run(smoke: bool = False) -> List[str]:
    if smoke:
        tag, g = "vgg16_r8_smoke", vgg16(res=8, scale=0.05)
        buckets, n_requests = (1, 2), 24
        plan = None
    else:
        tag, g = "googlenet_r56", googlenet(res=56, scale=0.25)
        buckets, n_requests = (1, 2, 4, 8), 96
        hw = identify_parameters(g, max_dim=512)
        plan = map_network(g, hw=hw)
    params = init_params(g, jax.random.PRNGKey(0))

    # Bucket-keyed tuning: each bucket's executable binds the winner
    # measured at that batch size (lax/reference sweep — interpret-mode
    # Pallas candidates are too slow to sweep on CPU at batch > 1).
    t0 = time.time()
    record = autotune_buckets(
        g,
        plan,
        buckets=buckets,
        backends=("lax", "reference"),
        reps=1,
    )
    rows = [
        "dynamic_batching,"
        f"{tag},config,-,autotune_wall_s,{time.time() - t0:.1f}",
        "dynamic_batching,"
        f"{tag},config,-,tuned_pairs,{len(record.entries)}",
    ]
    rows += _rate_rows(tag, g, params, record, n_requests)
    rows += _smoke_pattern_rows(tag, g, params, record)
    return rows


if __name__ == "__main__":
    out = run(smoke="--smoke" in sys.argv)
    print("\n".join(out))
    # Correctness gates the smoke job; perf summaries on the tiny smoke
    # graph are too noisy to assert and are only enforced for the
    # committed full-run rows (see the CI schema guard).
    if any(row.endswith("outputs_ok,False") for row in out):
        sys.exit(1)
