"""Paper Table 3 analogue: end-to-end single-image inference latency.

Cost-model projections for the full-size networks on both specs, plus a
MEASURED CPU wall-clock on reduced configs demonstrating that executing the
PBQP plan is semantically identical and that relative algorithm rankings
hold on real execution — and that the compiled overlay program
(``compile_plan``) beats the eager per-image Python loop, at batch 1 and
batch 8.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.cnn.executor import compile_plan, forward, init_params
from repro.cnn.models import googlenet, inception_v4
from repro.core.algorithms import IM2COL, KN2ROW
from repro.core.cost_model import FPGA_LIKE, V5E
from repro.core.dse import identify_parameters
from repro.core.mapper import map_network


def projections() -> List[str]:
    rows = []
    for spec in (V5E, FPGA_LIKE):
        for name, g, gops in (("googlenet", googlenet(res=224), 3.0),
                              ("inception_v4", inception_v4(res=299), 9.0)):
            hw = identify_parameters(g, spec=spec, max_dim=512)
            plan = map_network(g, hw=hw, spec=spec)
            lat_ms = plan.total_cost_s * 1e3
            gops_s = gops / plan.total_cost_s / 1e0
            rows.append(f"table3,{name},{spec.name},latency_ms,{lat_ms:.3f}")
            rows.append(f"table3,{name},{spec.name},throughput_GOPS,"
                        f"{gops_s:.0f}")
    rows.append("table3,paper_reference,alveo_u200,googlenet_ms,1.34")
    rows.append("table3,paper_reference,alveo_u200,inception_v4_ms,4.39")
    return rows


def _timed(fn, reps=3):
    jax.block_until_ready(fn())       # compile/warm
    t0 = time.time()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def measured_reduced() -> List[str]:
    """Wall-clock on CPU, reduced GoogleNet: plan vs im2col-only vs
    kn2row-only (jnp reference paths, jit-compiled)."""
    rows = []
    g = googlenet(res=56, scale=0.25)
    hw = identify_parameters(g, max_dim=512)
    plan = map_network(g, hw=hw)
    params = init_params(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (56, 56, 3))

    t_plan = _timed(lambda: forward(g, params, x, plan=plan))
    t_im2col = _timed(lambda: forward(g, params, x, default_algo=IM2COL))
    t_kn2row = _timed(lambda: forward(g, params, x, default_algo=KN2ROW))
    rows.append(f"table3_measured,googlenet_r56,cpu,plan_ms,"
                f"{t_plan * 1e3:.1f}")
    rows.append(f"table3_measured,googlenet_r56,cpu,im2col_ms,"
                f"{t_im2col * 1e3:.1f}")
    rows.append(f"table3_measured,googlenet_r56,cpu,kn2row_ms,"
                f"{t_kn2row * 1e3:.1f}")
    return rows


def measured_compiled() -> List[str]:
    """Compiled-plan (one jitted program, batched) vs the eager per-image
    Python loop on reduced GoogleNet, at batch 1 and batch 8. The compiled
    path removes per-layer Python dispatch and amortizes the launch over
    the batch — these rows track the perf trajectory of the overlay engine.
    """
    rows = []
    g = googlenet(res=56, scale=0.25)
    hw = identify_parameters(g, max_dim=512)
    plan = map_network(g, hw=hw)
    params = init_params(g, jax.random.PRNGKey(0))
    run_plan = compile_plan(g, plan)

    for batch in (1, 8):
        xb = jax.random.normal(jax.random.PRNGKey(2), (batch, 56, 56, 3))
        t_comp = _timed(lambda: run_plan(params, xb))
        t_eager = _timed(lambda: jnp.stack(
            [forward(g, params, xb[i], plan=plan)
             for i in range(batch)]))
        rows.append(f"e2e_compiled,googlenet_r56,batch{batch},"
                    f"compiled_ms,{t_comp * 1e3:.1f}")
        rows.append(f"e2e_compiled,googlenet_r56,batch{batch},"
                    f"eager_loop_ms,{t_eager * 1e3:.1f}")
        rows.append(f"e2e_compiled,googlenet_r56,batch{batch},"
                    f"speedup_x,{t_eager / t_comp:.2f}")
    return rows


def run() -> List[str]:
    return projections() + measured_reduced() + measured_compiled()


if __name__ == "__main__":
    print("\n".join(run()))
