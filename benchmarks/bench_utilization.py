"""Paper Figures 9/10: effective PE utilization (Eq. 14) per CONV layer
under three hardware configurations:

  bl1 'square-NS'     : largest square array, NS dataflow only
  bl2 'algo1-NS'      : Algorithm-1 array shape, NS only
  OPT 'algo1-optimized': Algorithm-1 shape + per-layer best dataflow

plus the end-to-end latency deltas the paper reports (32%/35% lower for
GoogleNet/Inception-v4 vs bl1 in their setting).
"""
from __future__ import annotations

from typing import List

from repro.cnn.models import googlenet, inception_v4
from repro.core.cost_model import (Dataflow, FPGA_LIKE, TPUSpec, node_cost)
from repro.core.dse import identify_parameters
from repro.core.mapper import map_network


def utilization_rows(spec: TPUSpec, model_name: str, graph,
                     square: int = 512) -> List[str]:
    hw = identify_parameters(graph, spec=spec, max_dim=512)
    plan = map_network(graph, hw=hw, spec=spec)
    rows = []
    tot = {"bl1": 0.0, "bl2": 0.0, "opt": 0.0}
    for node in graph.conv_nodes():
        algo = plan.assignment[node.id]
        # bl1: biggest square array, NS only.
        nc1 = node_cost(node.conv, algo, square, square, Dataflow.NS, spec)
        # bl2: DSE shape, NS only.
        nc2 = node_cost(node.conv, algo, hw.p1, hw.p2, Dataflow.NS, spec)
        # OPT: DSE shape + chosen dataflow.
        nco = node_cost(node.conv, algo, hw.p1, hw.p2,
                        plan.dataflows[node.id], spec)
        rows.append(f"fig9_10,{model_name},{node.name},"
                    f"{nc1.utilization:.3f},{nc2.utilization:.3f},"
                    f"{nco.utilization:.3f}")
        for k, nc in (("bl1", nc1), ("bl2", nc2), ("opt", nco)):
            tot[k] += nc.total
    for k in ("bl1", "bl2"):
        imp = 100 * (1 - tot["opt"] / tot[k])
        rows.append(f"fig9_10,{model_name},e2e_latency_vs_{k},,,{imp:.1f}%")
    return rows


def run() -> List[str]:
    rows: List[str] = []
    for name, g in (("googlenet", googlenet(res=224)),
                    ("inception_v4", inception_v4(res=299))):
        rows += utilization_rows(FPGA_LIKE, name, g)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
