"""Shared Poisson arrival-trace machinery for the serving benchmarks.

One copy of the trace generator and the two replay disciplines, used by
``bench_dynamic_batching`` (virtual clock), ``bench_sharded_serving``
(virtual clock per device count) and ``bench_pipelined_serving`` (real
clock — overlap only exists in real time):

* ``poisson_trace`` — deterministic Poisson arrivals + images per seed.
* ``replay`` — virtual-clock discrete events: arrivals carry synthetic
  timestamps, every tick runs the REAL compiled program and its measured
  wall time advances the clock, so per-request latency combines real
  service time with simulated queueing. Blind to pipelining by design
  (the virtual clock serializes ticks).
* ``replay_wallclock`` — real-clock events: arrivals are released as
  real time passes and the engine runs free, so host-side packing and
  device compute genuinely overlap when the engine pipelines. This is
  the only replay that can observe ``pipeline_depth`` > 1.
* ``replay_robust`` — the shed-aware virtual-clock discipline for
  robustness-armed engines (``bench_chaos_serving``): requests may end
  ``rejected_full`` / ``shed_deadline`` / ``failed`` instead of
  completing, so the loop terminates on *outcome conservation* (every
  submitted request accounted) rather than on every request finishing.
* ``hist`` — the per-bucket dispatch histogram row value.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.cnn_engine import (OUTCOME_COMPLETED, OUTCOME_FAILED,
                                      OUTCOME_REJECTED, OUTCOME_SHED,
                                      CNNRequest, CNNServingEngine)


def poisson_trace(
    rate_rps: float, n: int, shape: Tuple[int, ...], seed: int
) -> List[Tuple[float, np.ndarray]]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    times = np.cumsum(gaps) - gaps[0]  # first arrival at t=0
    imgs = rng.standard_normal((n,) + shape).astype(np.float32)
    return [(float(times[i]), imgs[i]) for i in range(n)]


def replay(
    eng: CNNServingEngine, trace: List[Tuple[float, np.ndarray]]
) -> Tuple[np.ndarray, float]:
    """Virtual-clock discrete-event replay: submit arrivals at their trace
    timestamps, let the engine's tick scheduler decide dispatches, advance
    the clock by each tick's measured wall time. Returns (per-request
    latencies, makespan)."""
    n = len(trace)
    done_at: Dict[int, float] = {}
    i, now = 0, 0.0
    while len(done_at) < n:
        while i < n and trace[i][0] <= now + 1e-12:
            eng.submit(
                CNNRequest(rid=i, image=trace[i][1], t_submit=trace[i][0])
            )
            i += 1
        served = eng.step(now=now)
        if served:
            wall = float(eng.last_tick["wall_s"])
            for rid in eng.done:
                if rid not in done_at:
                    done_at[rid] = now + wall
            now += wall  # the engine is busy while a tick runs
            continue
        nxt = []
        if i < n:
            nxt.append(trace[i][0])
        at = eng.next_dispatch_at()
        if at is not None:
            nxt.append(at)
        assert nxt, "replay stalled with requests outstanding"
        now = max(now, min(nxt))
    lat = np.array([done_at[rid] - trace[rid][0] for rid in range(n)])
    makespan = max(done_at.values()) - trace[0][0]
    return lat, makespan


def replay_robust(
    eng: CNNServingEngine, trace: List[Tuple[float, np.ndarray]],
    on_tick: Optional[Callable[[float], None]] = None,
) -> Tuple[Dict[int, str], Dict[int, float], float]:
    """Shed-aware virtual-clock replay for robustness-armed engines
    (``pipeline_depth == 1``; lazy retirement under a virtual clock
    would conflate simulated queueing with real completion order).

    Same discrete-event discipline as ``replay`` — arrivals at trace
    timestamps, the engine's scheduler decides, measured tick wall time
    advances the clock — but every request is tracked to its terminal
    outcome instead of assuming completion: submit verdicts catch
    ``rejected_full``, the engine's ``shed_rids`` / ``failed`` /
    ``done`` sets catch the rest (a failed tick still advances the
    clock by its measured fault wall time). Returns ``(outcomes,
    done_at, makespan)`` with ``outcomes[rid]`` one of the four
    ``RequestOutcome`` strings for every rid in the trace — conservation
    is the caller's gate, termination is this loop's.

    ``on_tick(now)`` (if given) fires after every ``eng.step`` — between
    ticks, the one place a plan supervisor may act (observe the tick,
    re-solve, hot-swap) without a tick ever observing a half-deployed
    ladder. The adaptive-serving benchmark drives ``PlanSupervisor.tick``
    and its environment-shift schedule through this hook."""
    n = len(trace)
    outcomes: Dict[int, str] = {}
    done_at: Dict[int, float] = {}
    i, now = 0, 0.0
    while True:
        while i < n and trace[i][0] <= now + 1e-12:
            verdict = eng.submit(
                CNNRequest(rid=i, image=trace[i][1], t_submit=trace[i][0]))
            if verdict == OUTCOME_REJECTED:
                outcomes[i] = OUTCOME_REJECTED
            i += 1
        served = eng.step(now=now)
        if on_tick is not None:
            on_tick(now)
        for rid in eng.shed_rids:
            outcomes.setdefault(rid, OUTCOME_SHED)
        for rid in eng.failed:
            outcomes.setdefault(rid, OUTCOME_FAILED)
        if served:
            wall = float(eng.last_tick["wall_s"])
            for rid in eng.done:
                if rid not in outcomes:
                    outcomes[rid] = OUTCOME_COMPLETED
                    done_at[rid] = now + wall
            now += wall  # the engine is busy while a tick runs
            continue
        if i >= n and not eng.queue:
            break
        nxt = []
        if i < n:
            nxt.append(trace[i][0])
        at = eng.next_dispatch_at()
        if at is not None:
            nxt.append(at)
        assert nxt, "robust replay stalled with requests outstanding"
        now = max(now, min(nxt))
    assert len(outcomes) == n, \
        f"replay lost requests: {n - len(outcomes)} unaccounted"
    makespan = (max(done_at.values()) - trace[0][0]) if done_at else 0.0
    return outcomes, done_at, makespan


def replay_multi(
    multi, traces: Dict[str, List[Tuple[float, np.ndarray]]]
) -> Tuple[Dict[str, Dict[int, str]], Dict[str, Dict[int, float]], float]:
    """Joint virtual-clock replay of one Poisson trace *per model*
    through a ``MultiModelEngine`` (synchronous tenants only — the same
    restriction the engine enforces at registration). Arrival streams
    merge into one timeline; each joint ``step`` dispatches tenants in
    deadline order and its measured wall time (``last_step["wall_s"]``,
    the sum of the round's serialized ticks) advances the shared clock.
    Every request is tracked to a terminal outcome exactly as in
    ``replay_robust``, but per tenant. Request ids need only be unique
    within their own tenant's trace.

    Returns ``(outcomes, done_at, makespan)`` keyed by model name;
    completion times come from the tenants' own ``RequestTrace`` logs
    (each tenant's ``trace_window`` must cover its trace length)."""
    events: List[Tuple[float, str, int, np.ndarray]] = []
    for name, tr in traces.items():
        for i, (t, img) in enumerate(tr):
            events.append((t, name, i, img))
    events.sort(key=lambda e: (e[0], e[1], e[2]))
    n = len(events)
    outcomes: Dict[str, Dict[int, str]] = {name: {} for name in traces}
    i, now = 0, 0.0
    while True:
        while i < n and events[i][0] <= now + 1e-12:
            t, name, rid, img = events[i]
            verdict = multi.submit(
                name, CNNRequest(rid=rid, image=img, t_submit=t))
            if verdict == OUTCOME_REJECTED:
                outcomes[name][rid] = OUTCOME_REJECTED
            i += 1
        served = multi.step(now=now)
        for name, eng in multi.engines.items():
            for rid in eng.shed_rids:
                outcomes[name].setdefault(rid, OUTCOME_SHED)
            for rid in eng.failed:
                outcomes[name].setdefault(rid, OUTCOME_FAILED)
            for rid in eng.done:
                outcomes[name].setdefault(rid, OUTCOME_COMPLETED)
        if served:
            now += float(multi.last_step["wall_s"])
            continue
        if i >= n and multi.queued_total() == 0:
            break
        nxt = []
        if i < n:
            nxt.append(events[i][0])
        at = multi.next_dispatch_at()
        if at is not None:
            nxt.append(at)
        assert nxt, "multi replay stalled with requests outstanding"
        now = max(now, min(nxt))
    for name, tr in traces.items():
        assert len(outcomes[name]) == len(tr), \
            f"replay lost {len(tr) - len(outcomes[name])} requests of " \
            f"model {name!r}"
    done_at: Dict[str, Dict[int, float]] = {name: {} for name in traces}
    for name, eng in multi.engines.items():
        for t in eng.request_log:
            if t.outcome == OUTCOME_COMPLETED:
                done_at[name][t.rid] = t.t_done
    ends = [t for per in done_at.values() for t in per.values()]
    makespan = (max(ends) - events[0][0]) if ends else 0.0
    return outcomes, done_at, makespan


def replay_wallclock(
    eng: CNNServingEngine, trace: List[Tuple[float, np.ndarray]]
) -> Tuple[np.ndarray, float]:
    """Real-clock replay: arrivals are released as wall time passes and
    the engine ticks continuously, so a pipelined engine's dispatch of
    tick N+1 really does overlap tick N's device compute — the overlap a
    virtual clock cannot express. Returns (per-request latencies from the
    engine's own RequestTrace log, real makespan). The engine should be
    warmed (compiles inside the replay would poison the measurement) and
    is reset()-safe to reuse across calls."""
    n = len(trace)
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter() - t0
        while i < n and trace[i][0] <= now:
            eng.submit(CNNRequest(rid=i, image=trace[i][1], t_submit=now))
            i += 1
        # Once every arrival is in, flush: remaining ticks should drain
        # back-to-back rather than wait on SLO budgets.
        dispatched = eng.step(now=now, flush=i >= n)
        if i >= n and not eng.queue:
            break
        if not dispatched and i < n:
            time.sleep(min(1e-3, max(0.0, trace[i][0] - now)))
    eng.drain()
    makespan = time.perf_counter() - t0
    lat = np.array([t.latency_s for t in eng.request_log][-n:])
    return lat, makespan


def hist(eng: CNNServingEngine) -> str:
    return "|".join(f"{b}:{c}" for b, c in sorted(eng.dispatches.items()) if c)
