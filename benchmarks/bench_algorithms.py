"""Paper Figures 1, 11, 12 and Table 4: per-layer algorithm trade-offs,
per-module latency under fixed-algorithm baselines vs DYNAMAP OPT, and the
end-to-end improvement percentages.

Runs the cost model on both device specs: the TPU-v5e target and the
Alveo-U200-like spec (the paper's own regime — where the paper's algorithm
mixes re-appear).
"""
from __future__ import annotations

import time
from collections import Counter, defaultdict
from typing import Dict, List

from repro.cnn.models import googlenet, inception_v4
from repro.core.algorithms import DEFAULT_MENU, IM2COL, KN2ROW, WINO_2_3
from repro.core.cost_model import FPGA_LIKE, TPUSpec, V5E, node_cost
from repro.core.dse import identify_parameters
from repro.core.graph import ConvMeta
from repro.core.mapper import evaluate_fixed_mapping, map_network


def figure1(spec: TPUSpec = V5E) -> List[str]:
    """Fig. 1: computation / memory loads of the three algorithms on three
    representative layer configurations."""
    rows = []
    layers = {
        "small-kernel 1x1 (56,256,64,1)": ConvMeta(256, 64, 56, 56, 1, 1),
        "square 3x3 (28,192,96,3)": ConvMeta(192, 96, 28, 28, 3, 3),
        "large 7x7 (56,64,128,7)": ConvMeta(64, 128, 56, 56, 7, 7),
    }
    for name, conv in layers.items():
        for algo in DEFAULT_MENU:
            if not algo.applicable(conv):
                continue
            mult = algo.multiplies(conv)
            nc = node_cost(conv, algo, 256, 256, spec=spec)
            rows.append(f"fig1,{name},{algo},{mult},{nc.total:.3e}")
    return rows


def _module_of(layer_name: str) -> str:
    if "/" in layer_name:
        return layer_name.split("/")[0]
    return layer_name.split("_")[0] if "_" in layer_name else layer_name


def figures_11_12(spec: TPUSpec, model_name: str, graph) -> List[str]:
    """Per-module execution time under bl3/bl4/bl5/OPT (Figs. 11/12)."""
    hw = identify_parameters(graph, spec=spec, max_dim=512)
    plan = map_network(graph, hw=hw, spec=spec)
    rows = []
    # Per-module node costs under each policy (transition costs are
    # graph-global; node costs attribute cleanly to modules).
    policies: Dict[str, Dict[int, float]] = {}
    from repro.core.algorithms import menu_for
    for pol, pick in (("bl3_im2col", "im2col"), ("bl4_kn2row", "kn2row"),
                      ("bl5_wino", "winograd")):
        per: Dict[int, float] = {}
        for node in graph.conv_nodes():
            menu = menu_for(node.conv)
            fams = [a.family.value for a in menu]
            if pick in fams:
                algo = menu[fams.index(pick)]
            else:
                algo = menu[fams.index("im2col")]
            per[node.id] = node_cost(node.conv, algo, hw.p1, hw.p2,
                                     hw.psi.get((node.id, algo.key)),
                                     spec).total
        policies[pol] = per
    policies["OPT"] = {
        nid: node_cost(graph.nodes[nid].conv, algo, hw.p1, hw.p2,
                       plan.dataflows.get(nid), spec).total
        for nid, algo in plan.assignment.items()}

    by_module: Dict[str, Dict[str, float]] = defaultdict(dict)
    for pol, per in policies.items():
        for nid, cost in per.items():
            mod = _module_of(graph.nodes[nid].name)
            by_module[mod][pol] = by_module[mod].get(pol, 0.0) + cost
    for mod in sorted(by_module):
        row = by_module[mod]
        rows.append(
            f"fig11_12,{model_name},{mod},"
            + ",".join(f"{row.get(p, 0):.3e}" for p in
                       ("bl3_im2col", "bl4_kn2row", "bl5_wino", "OPT")))
    return rows


def table4(spec: TPUSpec, model_name: str, graph) -> List[str]:
    """Table 4: end-to-end latency improvement of OPT over bl3/bl4/bl5."""
    hw = identify_parameters(graph, spec=spec, max_dim=512)
    plan = map_network(graph, hw=hw, spec=spec)
    rows = [f"table4,{model_name},{spec.name},OPT_ms,"
            f"{plan.total_cost_s * 1e3:.4f}"]
    hist = Counter(str(a) for a in plan.assignment.values())
    rows.append(f"table4,{model_name},{spec.name},algo_mix,"
                + "|".join(f"{k}:{v}" for k, v in sorted(hist.items())))
    for pol in ("im2col", "kn2row", "winograd"):
        bl = evaluate_fixed_mapping(graph, pol, hw=hw, spec=spec)
        imp = 100 * (1 - plan.total_cost_s / bl)
        rows.append(f"table4,{model_name},{spec.name},improvement_vs_{pol},"
                    f"{imp:.1f}%")
    return rows


def run() -> List[str]:
    rows: List[str] = []
    rows += figure1(V5E)
    nets = {"googlenet": googlenet(res=224),
            "inception_v4": inception_v4(res=299)}
    for spec in (V5E, FPGA_LIKE):
        for name, g in nets.items():
            t0 = time.time()
            rows += table4(spec, name, g)
            rows.append(f"table4,{name},{spec.name},wall_s,"
                        f"{time.time() - t0:.2f}")
    for name, g in nets.items():
        rows += figures_11_12(FPGA_LIKE, name, g)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
