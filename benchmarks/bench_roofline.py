"""§Roofline table assembly: reads experiments/roofline/*.json (probe-based
HLO-derived terms) and experiments/dryrun/*.json (memory analysis), emits
the per-(arch × shape) table for EXPERIMENTS.md."""
from __future__ import annotations

import json
from pathlib import Path
from typing import List

EXP = Path(__file__).resolve().parents[1] / "experiments"


def run() -> List[str]:
    rows = ["roofline,arch,shape,kind,compute_ms,memory_ms,collective_ms,"
            "bound,roofline_frac,useful_flops_ratio"]
    for f in sorted((EXP / "roofline").glob("*.json")):
        r = json.loads(f.read_text())
        if not r.get("ok"):
            rows.append(f"roofline,{r.get('arch')},{r.get('shape')},"
                        f"FAILED,{r.get('error', '')[:60]}")
            continue
        # roofline fraction: compute term / total (how close the dominant
        # bottleneck lets us get to the compute roofline)
        total = r["roofline_total_s"]
        frac = r["compute_s"] / total if total else 0.0
        rows.append(
            f"roofline,{r['arch']},{r['shape']},{r['kind']},"
            f"{r['compute_s'] * 1e3:.2f},{r['memory_s'] * 1e3:.2f},"
            f"{r['collective_s'] * 1e3:.2f},{r['bound']},"
            f"{frac:.3f},{r['useful_flops_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
