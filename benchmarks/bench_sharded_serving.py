"""Mesh-sharded serving benchmark (PR 5): multi-chip batch-dim scaling.

Exercises ``CNNServingEngine``'s mesh mode on 1 / 2 / 4 / 8 simulated
devices (``compile_plan(..., mesh=...)`` — params replicated, batch dim on
the mesh's data axis, bucket ladder in multiples of the shard count,
tuning looked up at the *per-chip* batch). Three row groups:

* ``equiv`` — at every device count and every bucket of its ladder, the
  sharded compiled plan's outputs are allclose to the single-device
  program under the SAME lowering (placement changes, math does not).
  This is the hard gate, enforced on every run including ``--smoke``.
* ``replay`` — the PR-3 Poisson arrival trace replayed through each
  sharded engine (same trace, same seed, offered at 0.6x the
  single-device saturation rate): per-device p50/p99 latency, served
  throughput and the bucket dispatch histogram. The committed
  ``throughput_monotonic_1_2_4`` gate asserts replayed throughput is
  non-decreasing 1→2→4 devices within the 10% noise envelope the layout
  bench established for shared-CPU hosts — on this host the 8 simulated
  chips share two physical cores, so the *true* scaling curve is flat
  (total FLOP rate is fixed no matter how the batch is placed); the
  gate proves sharded placement sustains the same offered load with no
  sharding tax, and leaves real speedups to real multi-chip hardware
  (ROADMAP's TPU item).
* ``scaling`` — descriptive: top-bucket tick wall clock per device
  count, measured interleaved (``_timing.sampled_interleaved``) so
  ambient load drift hits every mesh equally, with median *paired*
  per-rep tick ratios in the summary. Raw multi-device dispatch latency
  on an oversubscribed 2-core host is scheduling-luck-bimodal at d >= 4,
  which is exactly why the gate lives on the end-to-end replay instead.

Devices are simulated on CPU via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``. The flag must be
set before the XLA backend initializes, so when the current process does
not already see 8 devices (e.g. under ``benchmarks/run.py``), ``run()``
re-executes this module as a ``--child`` subprocess with the flag set and
collects its rows — the CI sharded-smoke job sets the flag itself and
runs in-process.

``--smoke`` (CI) runs the tiny-graph variant and gates only equivalence.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path
from typing import Dict, List

REPO = Path(__file__).resolve().parents[1]
for _p in (str(REPO), str(REPO / "src")):     # direct `python benchmarks/…`
    if _p not in sys.path:
        sys.path.insert(0, _p)

N_SIM_DEVICES = 8
DEVICE_COUNTS = (1, 2, 4, 8)
# Same 10% envelope (and rationale) as bench_layout_elision's no_slower:
# same-program process-to-process variance exceeds 5% on shared-CPU hosts,
# so tighter margins would gate on scheduling luck.
MONOTONIC_ENVELOPE = 0.90
ROW_PREFIX = "sharded_serving,"


# ---------------------------------------------------------------------------
# Child-side measurement (runs with 8 simulated devices).
# ---------------------------------------------------------------------------

def _measure(smoke: bool) -> List[str]:
    import jax
    import numpy as np

    from benchmarks._timing import sampled_interleaved
    from benchmarks._trace import hist as _hist
    from benchmarks._trace import poisson_trace as _poisson_trace
    from benchmarks._trace import replay as _replay
    from repro.cnn.executor import compile_plan, init_params
    from repro.cnn.models import googlenet, vgg16
    from repro.core.autotune import autotune_buckets
    from repro.core.dse import identify_parameters
    from repro.core.mapper import map_network
    from repro.launch.mesh import make_data_mesh
    from repro.serving.cnn_engine import CNNServingEngine, batch_buckets

    assert jax.device_count() >= N_SIM_DEVICES, (
        f"need {N_SIM_DEVICES} devices, got {jax.device_count()} — set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8")

    if smoke:
        tag, g = "vgg16_r8_smoke", vgg16(res=8, scale=0.05)
        plan, record, n_requests, reps = None, None, 24, 3
    else:
        tag, g = "googlenet_r56", googlenet(res=56, scale=0.25)
        hw = identify_parameters(g, max_dim=512)
        plan = map_network(g, hw=hw)
        # Per-chip tuning: sharded buckets look up bucket // data_shards,
        # so the PR-3 ladder {1, 2, 4, 8} covers every per-chip batch any
        # device count below induces.
        record = autotune_buckets(g, plan, buckets=(1, 2, 4, 8),
                                  backends=("lax", "reference"), reps=1)
        # 2x the PR-3 trace length: throughput = served / makespan, so a
        # longer replay tightens the gated estimate.
        n_requests, reps = 192, 15

    batch = 8
    params = init_params(g, jax.random.PRNGKey(0))
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])
    rows = [
        f"sharded_serving,{tag},config,-,devices_available,"
        f"{jax.device_count()}",
        f"sharded_serving,{tag},config,-,batch,{batch}",
    ]

    # ---- equivalence: sharded vs single-device, per bucket -------------
    # The reference is the UNSHARDED program under the same per-chip
    # lowering, so any mismatch is a placement bug, not a binding change.
    rng = np.random.default_rng(3)
    meshes = {d: make_data_mesh(d) for d in DEVICE_COUNTS}
    ref_runs: Dict[int, object] = {}
    top_runs: Dict[int, object] = {}
    all_ok = True
    for d in DEVICE_COUNTS:
        ladder = batch_buckets(batch, d)
        ok = True
        for bucket in ladder:
            per_chip = bucket // d
            if per_chip not in ref_runs:
                ref_runs[per_chip] = compile_plan(
                    g, plan, tuning=record, tuning_batch=per_chip)
            run_m = compile_plan(g, plan, tuning=record,
                                 tuning_batch=per_chip, mesh=meshes[d])
            if bucket == batch:
                top_runs[d] = run_m
            xb = rng.standard_normal((bucket,) + shape).astype(np.float32)
            y_m = np.asarray(run_m(params, xb))
            y_s = np.asarray(ref_runs[per_chip](params, xb))
            ok &= bool(np.allclose(y_m, y_s, rtol=1e-4, atol=1e-5))
        all_ok &= ok
        rows.append(f"sharded_serving,{tag},devices_{d},equiv,"
                    f"buckets,{'|'.join(str(b) for b in ladder)}")
        rows.append(f"sharded_serving,{tag},devices_{d},equiv,outputs_ok,{ok}")

    # ---- throughput scaling: interleaved top-bucket ticks --------------
    xb = rng.standard_normal((batch,) + shape).astype(np.float32)
    fns = {d: (lambda r=top_runs[d]: r(params, xb)) for d in DEVICE_COUNTS}
    samples = sampled_interleaved(fns, reps=reps)
    for d in DEVICE_COUNTS:
        t_min = min(samples[d])
        pre = f"sharded_serving,{tag},devices_{d},scaling"
        rows.append(f"{pre},tick_ms,{t_min * 1e3:.2f}")
        rows.append(f"{pre},throughput_rps,{batch / t_min:.2f}")
    tick_ratios = {}
    for a, b in ((1, 2), (2, 4), (4, 8)):
        # Throughput ratio b-over-a = paired tick-time ratio a-over-b.
        paired = [sa / sb for sa, sb in zip(samples[a], samples[b])]
        tick_ratios[(a, b)] = float(np.median(paired))

    # ---- Poisson replay per device count (the gated rows) --------------
    eng1 = CNNServingEngine(g, params, plan, batch_size=batch,
                            tuning=record, mesh=meshes[1], warmup=True)
    svc8 = eng1.service_estimate(batch)
    rate = 0.6 * batch / svc8
    trace = _poisson_trace(rate, n_requests, shape, seed=42)
    rows.append(f"sharded_serving,{tag},config,-,arrival_rps,{rate:.2f}")
    tput = {}
    for d in DEVICE_COUNTS:
        eng = eng1 if d == 1 else CNNServingEngine(
            g, params, plan, batch_size=batch, tuning=record,
            mesh=meshes[d], warmup=True)
        lat, makespan = _replay(eng, trace)
        st = eng.stats()
        assert st["sharding"]["data_shards"] == d
        tput[d] = len(lat) / makespan
        pre = f"sharded_serving,{tag},devices_{d},replay"
        rows.append(f"{pre},p50_ms,{float(np.percentile(lat, 50)) * 1e3:.2f}")
        rows.append(f"{pre},p99_ms,{float(np.percentile(lat, 99)) * 1e3:.2f}")
        rows.append(f"{pre},throughput_rps,{tput[d]:.2f}")
        rows.append(f"{pre},served,{len(lat)}")
        rows.append(f"{pre},dispatch_hist,{_hist(eng)}")
        rows.append(f"{pre},per_chip_batch_max,{batch // d}")

    mono = (tput[2] >= MONOTONIC_ENVELOPE * tput[1]
            and tput[4] >= MONOTONIC_ENVELOPE * tput[2])
    for a, b in ((1, 2), (2, 4), (4, 8)):
        rows.append(f"sharded_serving,{tag},summary,-,"
                    f"tput_ratio_{b}_over_{a},{tput[b] / tput[a]:.3f}")
        rows.append(f"sharded_serving,{tag},summary,-,"
                    f"tick_tput_ratio_{b}_over_{a},"
                    f"{tick_ratios[(a, b)]:.3f}")
    rows.append(f"sharded_serving,{tag},summary,-,outputs_ok,{all_ok}")
    rows.append(f"sharded_serving,{tag},summary,-,"
                f"throughput_monotonic_1_2_4,{mono}")
    return rows


# ---------------------------------------------------------------------------
# Parent-side harness entry point.
# ---------------------------------------------------------------------------

def _spawn_child(smoke: bool) -> List[str]:
    """Re-exec this module with the device-count flag set before XLA can
    initialize, and collect the child's rows from stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{N_SIM_DEVICES}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO / "src"), str(REPO), env.get("PYTHONPATH", "")]).rstrip(
        os.pathsep)
    cmd = [sys.executable, str(Path(__file__).resolve()), "--child"]
    if smoke:
        cmd.append("--smoke")
    try:
        proc = subprocess.run(cmd, env=env, cwd=str(REPO),
                              capture_output=True, text=True,
                              timeout=1800)
    except subprocess.TimeoutExpired as e:
        err = (e.stderr or b"").decode() if isinstance(e.stderr, bytes) \
            else (e.stderr or "")
        raise RuntimeError(
            f"sharded-serving child timed out after {e.timeout}s:\n"
            f"{err[-2000:]}") from e
    rows = [ln for ln in proc.stdout.splitlines()
            if ln.startswith(ROW_PREFIX)]
    if proc.returncode != 0 or not rows:
        raise RuntimeError(
            f"sharded-serving child failed (rc={proc.returncode}):\n"
            f"{proc.stderr[-2000:]}")
    return rows


def run(smoke: bool = False) -> List[str]:
    import jax
    if jax.device_count() >= N_SIM_DEVICES:
        return _measure(smoke)
    return _spawn_child(smoke)


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    out = _measure(smoke) if "--child" in sys.argv else run(smoke)
    print("\n".join(out))
    # Equivalence gates every invocation; the throughput-scaling summary is
    # only enforced for the committed full-run rows (CI schema guard) —
    # smoke-scale graphs are too noisy to assert scaling on.
    if any(row.endswith("outputs_ok,False") for row in out):
        sys.exit(1)
