"""Adaptive serving benchmark (PR 10): the closed re-mapping loop under
a mid-trace service shift, gated on the swap actually paying off.

Replays one Poisson arrival trace through two engines built on the same
initial PBQP plan:

* ``frozen`` — the plan never changes (today's one-shot offline DSE).
* ``adaptive`` — a ``PlanSupervisor`` rides the replay's ``on_tick``
  hook: it infers a transition-cost calibration from the engine's own
  service EMAs, re-solves the PBQP, compiles the new ladder through the
  shared ``ExecutableCache``, and hot-swaps it between ticks.

The environment shift is an injected per-tick device delay
(``device_delay_s`` rides the engine's completion path, so it lands in
measured service, the EMAs, and the virtual clock): after ``SHIFT_TICK``
dispatched ticks, transitions turn expensive — a plan still running the
original transition-heavy assignment pays ``SHIFT_X`` times the floor
delay, while a plan re-mapped away from those transitions pays
``REMAP_X`` times. The delay floor itself (active from tick 0) dominates
real kernel wall-time jitter, so every decision the loop makes — and
every latency this benchmark reports — is delay-dominated and
reproducible on a noisy host. The schedule is keyed on dispatched-tick
count and deployed-plan fingerprint only, so frozen/adaptive/reference
runs all experience the identical environment timeline.

Hard gates (``sys.exit`` on violation, smoke included — every quantity
is injected-delay-dominated, so there is no shared-host-noise exemption):

* ``plan_flipped`` — the supervisor swapped exactly once, no rollback,
  and the deployed plan's fingerprint actually changed.
* ``pre_swap_bitwise_ok`` — every request the adaptive engine completed
  before the swap is bitwise identical to the frozen (no-swap) run.
* ``post_swap_bitwise_ok`` — every request completed after the swap is
  bitwise identical to a reference replay deployed on the adopted plan
  from tick 0: the swap boundary changes *which* plan computes, never
  what a plan computes.
* ``conservation`` — the outcome ledger balances for every engine.
* ``p99_speedup_ok`` — in the tail of the post-shift window the frozen
  engine's completed-p99 is at least ``ADAPTIVE_GATE`` (1.10x) the
  adaptive engine's: the re-map must buy real latency, not just differ.
"""

from __future__ import annotations

import sys
from pathlib import Path
from typing import Dict, List

REPO = Path(__file__).resolve().parents[1]
for _p in (str(REPO), str(REPO / "src")):     # direct `python benchmarks/…`
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import numpy as np

from benchmarks._trace import replay_robust
from repro.cnn.executor import ExecutableCache, init_params
from repro.cnn.models import googlenet, vgg16
from repro.core.dse import identify_parameters
from repro.core.mapper import map_network, plan_fingerprint
from repro.serving.cnn_engine import OUTCOME_COMPLETED, CNNServingEngine
from repro.serving.supervisor import PlanSupervisor

PREFIX = "adaptive_serving"
# Frozen post-shift tail p99 must beat adaptive by at least this factor.
ADAPTIVE_GATE = 1.10
# Delay schedule, in units of the floor delay d0: before the shift every
# plan pays 1x; after it the original (transition-heavy) plan pays
# SHIFT_X and a re-mapped plan REMAP_X. The implied EMA inflation the
# supervisor sees, (w + SHIFT_X*d0)/(w + d0) ~= 4.4, prices transitions
# past the ~4x regime where the PBQP winner provably flips.
SHIFT_X, REMAP_X = 6.0, 2.0


def _poisson_trace(shape, seed: int, rate: float, n: int):
    rng = np.random.default_rng(seed)
    t, times = 0.0, []
    for gap in rng.exponential(1.0 / rate, size=n):
        t += gap
        times.append(t)
    imgs = rng.standard_normal((n,) + shape).astype(np.float32)
    return [(times[i], imgs[i]) for i in range(n)]


def _p99(done_at: Dict[int, float], trace, rids) -> float:
    lats = [done_at[r] - trace[r][0] for r in rids if r in done_at]
    return float(np.percentile(lats, 99)) if lats else float("nan")


class _Environment:
    """The injected delay schedule, identical for every engine: keyed on
    the engine's own dispatched-tick count and deployed-plan fingerprint
    — never wall time — so separate replays see the same timeline."""

    def __init__(self, d0: float, shift_tick: int, fp_initial):
        self.d0 = d0
        self.shift_tick = shift_tick
        self.fp_initial = fp_initial

    def apply(self, eng: CNNServingEngine) -> None:
        if eng._dispatched_ticks < self.shift_tick:
            eng.device_delay_s = self.d0
        elif plan_fingerprint(eng.plan) == self.fp_initial:
            eng.device_delay_s = SHIFT_X * self.d0
        else:
            eng.device_delay_s = REMAP_X * self.d0


def _measure(smoke: bool) -> List[str]:
    if smoke:
        tag, g = "vgg16_r8_smoke", vgg16(res=8, scale=0.05)
        hw = identify_parameters(g)
        batch = 4
        n_pre, n_post = 32, 72
    else:
        tag, g = "googlenet_r56", googlenet(res=56, scale=0.25)
        hw = identify_parameters(g, max_dim=512)
        batch = 8
        n_pre, n_post = 64, 144
    params = init_params(g, jax.random.PRNGKey(0))
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])
    plan_a = map_network(g, hw=hw, use_on_chip=False)
    fp_a = plan_fingerprint(plan_a)
    cache = ExecutableCache()

    def _mk(plan):
        return CNNServingEngine(g, params, plan, batch_size=batch,
                                cache=cache, warmup=True)

    # Probe the raw device service so the delay floor provably dominates
    # kernel jitter (>= 4ms or 2x the measured top-bucket wall).
    probe = _mk(plan_a)
    svc_top = probe.service_estimate(batch)
    d0 = max(0.004, 2.0 * svc_top)
    shift_tick = (n_pre + batch - 1) // batch
    env = _Environment(d0, shift_tick, fp_a)

    # Arrival rate: stable for the re-mapped service (w + REMAP_X*d0)
    # but unsustainable for the frozen engine's post-shift service
    # (w + SHIFT_X*d0) — the frozen queue must grow, the adaptive one
    # must not, and the p99 gap is the price of not re-mapping.
    rate = 0.7 * batch / (svc_top + REMAP_X * d0)
    n = n_pre + n_post
    trace = _poisson_trace(shape, seed=42, rate=rate, n=n)

    rows = [
        f"{PREFIX},{tag},config,-,n_requests,{n}",
        f"{PREFIX},{tag},config,-,batch,{batch}",
        f"{PREFIX},{tag},config,-,svc_ms_top,{svc_top * 1e3:.2f}",
        f"{PREFIX},{tag},config,-,delay_floor_ms,{d0 * 1e3:.2f}",
        f"{PREFIX},{tag},config,-,shift_tick,{shift_tick}",
        f"{PREFIX},{tag},config,-,rate_rps,{rate:.2f}",
    ]

    # ---- frozen replay (no supervisor; plan never changes) ------------
    frozen = _mk(plan_a)
    froz_outcomes, froz_done_at, _ = replay_robust(
        frozen, trace, on_tick=lambda now: env.apply(frozen))
    assert all(v == OUTCOME_COMPLETED for v in froz_outcomes.values())
    froz_conserved = frozen.submitted_total == n and \
        len(frozen.done) == n

    # ---- adaptive replay (supervisor on the on_tick hook) -------------
    adaptive = _mk(plan_a)
    adaptive.device_delay_s = d0
    swap_info: Dict[str, object] = {}
    # settle_checks=2: a construction-warmed engine seeds its per-bucket
    # EMAs at raw device walls (no injected delay), and with alpha=0.5 a
    # lightly-trafficked bucket needs more than one check window to
    # converge under the delay floor — one extra settle window keeps that
    # engine-attributable convergence out of the sticky scale, so the
    # only fold left is the injected shift itself.
    sup = PlanSupervisor(adaptive, g,
                         map_kwargs=dict(hw=hw, use_on_chip=False),
                         check_every=4, rollback_ticks=3, settle_checks=2,
                         on_swap=lambda result:
                             swap_info.update(plan=result.plan))

    def _adaptive_tick(now: float) -> None:
        pre_swaps = sup.swaps
        sup.tick()
        if sup.swaps != pre_swaps:              # rids completed pre-swap
            swap_info["pre_rids"] = set(adaptive.done)
            swap_info["at"] = now
        env.apply(adaptive)

    adpt_outcomes, adpt_done_at, _ = replay_robust(
        adaptive, trace, on_tick=_adaptive_tick)
    assert all(v == OUTCOME_COMPLETED for v in adpt_outcomes.values())
    adpt_conserved = adaptive.submitted_total == n and \
        len(adaptive.done) == n

    flipped = (sup.swaps == 1 and sup.rollbacks == 0
               and plan_fingerprint(adaptive.plan) != fp_a)
    rows += [
        f"{PREFIX},{tag},loop,-,swaps,{sup.swaps}",
        f"{PREFIX},{tag},loop,-,rollbacks,{sup.rollbacks}",
        f"{PREFIX},{tag},loop,-,checks,{sup.checks}",
        f"{PREFIX},{tag},loop,-,inferred_scale,{sup._inferred_scale:.3f}",
        f"{PREFIX},{tag},loop,-,swap_at_s,"
        f"{float(swap_info.get('at', float('nan'))):.3f}",
    ]

    # ---- bitwise gates across the swap boundary -----------------------
    pre_rids = swap_info.get("pre_rids", set())
    pre_bitwise = flipped and bool(pre_rids) and all(
        np.array_equal(np.asarray(adaptive.done[r]),
                       np.asarray(frozen.done[r]))
        for r in pre_rids)
    post_bitwise = False
    if flipped:
        reference = _mk(swap_info["plan"])      # adopted plan from tick 0
        ref_outcomes, _, _ = replay_robust(
            reference, trace, on_tick=lambda now: env.apply(reference))
        assert all(v == OUTCOME_COMPLETED for v in ref_outcomes.values())
        post_rids = set(adaptive.done) - pre_rids
        post_bitwise = bool(post_rids) and all(
            np.array_equal(np.asarray(adaptive.done[r]),
                           np.asarray(reference.done[r]))
            for r in post_rids)
        rows.append(f"{PREFIX},{tag},swap_window,-,pre_swap_completions,"
                    f"{len(pre_rids)}")
        rows.append(f"{PREFIX},{tag},swap_window,-,post_swap_completions,"
                    f"{len(post_rids)}")

    # ---- post-shift tail p99 ------------------------------------------
    tail = range(n_pre + n_post // 2, n)
    froz_p99 = _p99(froz_done_at, trace, tail)
    adpt_p99 = _p99(adpt_done_at, trace, tail)
    ratio = froz_p99 / adpt_p99 if adpt_p99 > 0 else float("nan")
    speedup_ok = bool(np.isfinite(ratio) and ratio >= ADAPTIVE_GATE)
    rows += [
        f"{PREFIX},{tag},post_shift,-,frozen_tail_p99_ms,"
        f"{froz_p99 * 1e3:.2f}",
        f"{PREFIX},{tag},post_shift,-,adaptive_tail_p99_ms,"
        f"{adpt_p99 * 1e3:.2f}",
        f"{PREFIX},{tag},post_shift,-,p99_ratio,{ratio:.2f}",
        f"{PREFIX},{tag},cache,-,entries,{cache.stats()['entries']}",
        f"{PREFIX},{tag},summary,-,plan_flipped,{flipped}",
        f"{PREFIX},{tag},summary,-,pre_swap_bitwise_ok,{pre_bitwise}",
        f"{PREFIX},{tag},summary,-,post_swap_bitwise_ok,{post_bitwise}",
        f"{PREFIX},{tag},summary,-,conservation,"
        f"{froz_conserved and adpt_conserved}",
        f"{PREFIX},{tag},summary,-,p99_speedup_ok,{speedup_ok}",
    ]
    return rows


def run(smoke: bool = False) -> List[str]:
    return _measure(smoke)


if __name__ == "__main__":
    out = run(smoke="--smoke" in sys.argv)
    print("\n".join(out))
    # Every gate is hard on every invocation, --smoke included: all the
    # gated quantities are injected-delay-dominated, so there is no
    # shared-host-noise exemption to grant.
    hard = ("plan_flipped", "pre_swap_bitwise_ok", "post_swap_bitwise_ok",
            "conservation", "p99_speedup_ok")
    for row in out:
        f = row.split(",")
        if f[2] == "summary" and f[4] in hard and f[5] != "True":
            sys.exit(f"adaptive gate failed: {row}")
