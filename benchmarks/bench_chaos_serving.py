"""Chaos serving benchmark (PR 7): overload + injected faults, gated on
conservation, bitwise-equivalent completions, and post-burst recovery.

Replays a three-phase Poisson trace (steady → overload burst → steady
recovery) through two engines built on the same compiled bucket ladder:

* ``baseline`` — the fault-free engine (unbounded queue, no faults, no
  shedding): every request completes; its phase-C latencies define the
  recovery envelope.
* ``chaos`` — the robustness-armed engine: bounded admission
  (``max_queue``), deadline shedding, a seeded ``FaultPlan`` (transient
  completion-surfaced faults the bounded retry loop absorbs, one
  unrecoverable tick that exhausts retries and fails cleanly, straggler
  delays), and the degrade controller (queue-pressure + robust-z spike
  hysteresis).

Both replays run the shed-aware virtual-clock discipline
(``_trace.replay_robust``): every submitted request is tracked to its
terminal ``RequestOutcome``. Three committed gates:

* ``conservation`` — completed + rejected_full + shed_deadline + failed
  == submitted, for every scenario including the pipelined-chaos group
  (a faulted in-flight tick at depth 2 must not lose or double-count
  requests).
* ``completed_bitwise_ok`` — every request the chaos engine completed
  has output **bitwise identical** (``np.array_equal``) to the fault-free
  engine's output for the same rid: retries replay from the pinned
  staging buffer through the same executables, and degrade/shed change
  *scheduling*, never math (cross-bucket bitwise determinism verified by
  the ``armed_idle`` group below).
* ``recovery_p99_ok`` — p99 latency of the chaos engine's completed
  requests in the tail of the recovery phase is within
  ``RECOVERY_ENVELOPE`` × the fault-free engine's same-window p99: after
  the burst clears, the armed engine must return to the fault-free
  latency regime, not limp.

A fourth gate pins the no-op guarantee: ``idle_knobs_noop`` replays a
steady trace through a default engine and through an engine with every
robustness knob armed but idle (empty ``FaultPlan`` — the dispatch hook
is threaded through ``compile_plan`` — plus unreachable admission/degrade
thresholds) and requires the identical dispatch histogram and bitwise
identical outputs: arming the machinery costs existing configs nothing.

``--smoke`` (CI chaos-smoke step) runs the tiny-graph variant and gates
conservation + bitwise + idle-noop; the recovery-latency gate is enforced
on the committed full-run rows by the CI schema guard (smoke-scale
latency ratios on shared hosts are scheduling noise).
"""

from __future__ import annotations

import sys
from pathlib import Path
from typing import Dict, List, Tuple

REPO = Path(__file__).resolve().parents[1]
for _p in (str(REPO), str(REPO / "src")):     # direct `python benchmarks/…`
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import numpy as np

from benchmarks._trace import hist, poisson_trace, replay_robust
from repro.cnn.executor import init_params
from repro.cnn.models import googlenet, vgg16
from repro.core.dse import identify_parameters
from repro.core.mapper import map_network
from repro.distributed.fault import FaultPlan, TickFault
from repro.serving.cnn_engine import (OUTCOME_COMPLETED, OUTCOME_FAILED,
                                      OUTCOME_REJECTED, OUTCOME_SHED,
                                      CNNRequest, CNNServingEngine,
                                      DegradeConfig)

OUTCOMES = (OUTCOME_COMPLETED, OUTCOME_REJECTED, OUTCOME_SHED,
            OUTCOME_FAILED)
# Post-burst completed-p99 must land within this factor of the fault-free
# run's same-window p99 — generous enough for shared-host measured-wall
# variance, tight enough that a degrade mode that fails to stand down
# (or a backlog that never clears) blows straight through it.
RECOVERY_ENVELOPE = 1.5
PREFIX = "chaos_serving"


def _phased_trace(shape: Tuple[int, ...], seed: int,
                  segments: List[Tuple[float, int]]):
    """Concatenated Poisson segments (rate_rps, n) — one arrival stream
    whose rate steps phase to phase; returns (trace, phase boundaries as
    rid ranges)."""
    rng = np.random.default_rng(seed)
    times: List[float] = []
    bounds: List[Tuple[int, int]] = []
    t = 0.0
    for rate, n in segments:
        start = len(times)
        for gap in rng.exponential(1.0 / rate, size=n):
            t += gap
            times.append(t)
        bounds.append((start, len(times)))
    imgs = rng.standard_normal((len(times),) + shape).astype(np.float32)
    return [(times[i], imgs[i]) for i in range(len(times))], bounds


def _p99_window(done_at: Dict[int, float], trace, lo: int, hi: int
                ) -> float:
    lats = [done_at[r] - trace[r][0] for r in range(lo, hi) if r in done_at]
    return float(np.percentile(lats, 99)) if lats else float("nan")


def _outcome_rows(tag: str, scen: str, outcomes: Dict[int, str]
                  ) -> List[str]:
    rows = []
    for oc in OUTCOMES:
        n = sum(1 for v in outcomes.values() if v == oc)
        rows.append(f"{PREFIX},{tag},{scen},outcomes,{oc},{n}")
    return rows


def _steady_noop_rows(tag: str, g, params, plan, batch: int, slo_s: float,
                      trace) -> Tuple[List[str], bool]:
    """Default engine vs armed-but-idle engine on the same steady trace:
    identical dispatch histogram + bitwise identical outputs, proving
    the robustness machinery (threaded dispatch hook included) is a
    strict no-op until something actually trips it."""
    def _mk(**kw):
        return CNNServingEngine(g, params, plan, batch_size=batch,
                                slo_s=slo_s, warmup=True, **kw)

    default = _mk()
    armed = _mk(max_queue=10 ** 9, fault_plan=FaultPlan({}),
                max_retries=2,
                degrade=DegradeConfig(enter_queue=10 ** 9,
                                      exit_queue=10 ** 8))
    outs = {}
    hists = {}
    for name, eng in (("default", default), ("armed", armed)):
        outcomes, _, _ = replay_robust(eng, trace)
        assert all(v == OUTCOME_COMPLETED for v in outcomes.values()), name
        outs[name] = {r: np.asarray(v) for r, v in eng.done.items()}
        hists[name] = hist(eng)
    same_hist = hists["default"] == hists["armed"]
    same_out = all(np.array_equal(outs["default"][r], outs["armed"][r])
                   for r in outs["default"])
    rows = [
        f"{PREFIX},{tag},armed_idle,-,dispatch_hist_match,{same_hist}",
        f"{PREFIX},{tag},armed_idle,-,outputs_identical,{same_out}",
    ]
    return rows, same_hist and same_out


def _pipelined_chaos_rows(tag: str, g, params, plan, batch: int,
                          n: int) -> Tuple[List[str], bool, bool]:
    """Faulted in-flight ticks at depth 2: one unrecoverable tick (fails
    its requests after exhausting retries) and one transient tick (a
    retry replays it from the pinned staging buffer) inside a
    burst-drain. Conservation + bitwise-vs-fault-free over the
    completed set — lazy retirement must stay unpoisoned."""
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])
    rng = np.random.default_rng(11)
    imgs = rng.standard_normal((n,) + shape).astype(np.float32)

    def _drain(fault_plan):
        eng = CNNServingEngine(g, params, plan, batch_size=batch,
                               pipeline_depth=2, warmup=True,
                               fault_plan=fault_plan, max_retries=2)
        for i in range(n):
            eng.submit(CNNRequest(rid=i, image=imgs[i]))
        eng.run_until_done()
        return eng

    clean = _drain(None)
    plan_faults = FaultPlan({1: TickFault(failures=10),    # exhausts
                             2: TickFault(failures=1)})    # transient
    chaos = _drain(plan_faults)
    rb = chaos.stats()["robustness"]
    conserved = (rb["outcomes"][OUTCOME_COMPLETED]
                 + rb["outcomes"][OUTCOME_FAILED] == n
                 and rb["pending"] == 0)
    bitwise = all(np.array_equal(np.asarray(v), np.asarray(clean.done[r]))
                  for r, v in chaos.done.items())
    rows = [
        f"{PREFIX},{tag},pipelined,outcomes,completed,"
        f"{rb['outcomes'][OUTCOME_COMPLETED]}",
        f"{PREFIX},{tag},pipelined,outcomes,failed,"
        f"{rb['outcomes'][OUTCOME_FAILED]}",
        f"{PREFIX},{tag},pipelined,-,retries,{rb['retries']}",
        f"{PREFIX},{tag},pipelined,-,conservation,{conserved}",
        f"{PREFIX},{tag},pipelined,-,outputs_identical,{bitwise}",
    ]
    return rows, conserved, bitwise


def _measure(smoke: bool) -> List[str]:
    if smoke:
        tag, g = "vgg16_r8_smoke", vgg16(res=8, scale=0.05)
        plan, batch = None, 4
        n_a, n_b, n_c = 16, 32, 20
        pipelined_n = 12
    else:
        tag, g = "googlenet_r56", googlenet(res=56, scale=0.25)
        hw = identify_parameters(g, max_dim=512)
        plan = map_network(g, hw=hw)
        batch = 8
        n_a, n_b, n_c = 48, 96, 64
        pipelined_n = 24
    params = init_params(g, jax.random.PRNGKey(0))
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])
    n = n_a + n_b + n_c

    # Rates off the measured top-bucket service time: steady at 0.6× the
    # ladder's saturation throughput, burst at 2.4× (unsustainable — the
    # queue MUST grow, forcing shed/reject/degrade to earn their keep).
    probe = CNNServingEngine(g, params, plan, batch_size=batch, warmup=True)
    svc_top = probe.service_estimate(batch)
    sat_rps = batch / svc_top
    steady, burst = 0.6 * sat_rps, 2.4 * sat_rps
    slo_s = 4.0 * svc_top
    max_queue = 4 * batch
    trace, bounds = _phased_trace(
        shape, seed=42, segments=[(steady, n_a), (burst, n_b),
                                  (steady, n_c)])

    # Fault plan: seeded transient completion faults + straggler delays
    # across the whole run, plus one pinned unrecoverable tick in the
    # burst so the exhausted-retries path is always exercised.
    fault_plan = FaultPlan.seeded(seed=7, n_ticks=max(2 * n // batch, 24),
                                  fail_rate=0.12, failures=1,
                                  delay_rate=0.08, delay_s=1.5 * svc_top)
    fault_plan.faults[5] = TickFault(failures=10)

    rows = [
        f"{PREFIX},{tag},config,-,n_requests,{n}",
        f"{PREFIX},{tag},config,-,batch,{batch}",
        f"{PREFIX},{tag},config,-,slo_ms,{slo_s * 1e3:.2f}",
        f"{PREFIX},{tag},config,-,svc_ms_top,{svc_top * 1e3:.2f}",
        f"{PREFIX},{tag},config,-,steady_rps,{steady:.2f}",
        f"{PREFIX},{tag},config,-,burst_rps,{burst:.2f}",
        f"{PREFIX},{tag},config,-,max_queue,{max_queue}",
        f"{PREFIX},{tag},config,-,planned_faults,{len(fault_plan)}",
    ]

    def _mk(**kw):
        return CNNServingEngine(g, params, plan, batch_size=batch,
                                slo_s=slo_s, warmup=True, **kw)

    # ---- baseline (fault-free) replay ---------------------------------
    base = _mk()
    base_outcomes, base_done_at, base_makespan = replay_robust(base, trace)
    assert all(v == OUTCOME_COMPLETED for v in base_outcomes.values())
    rows += _outcome_rows(tag, "baseline", base_outcomes)
    rows.append(f"{PREFIX},{tag},baseline,-,makespan_s,{base_makespan:.3f}")
    rows.append(f"{PREFIX},{tag},baseline,-,dispatch_hist,{hist(base)}")

    # ---- chaos replay --------------------------------------------------
    chaos = _mk(max_queue=max_queue, shed_deadline=True,
                fault_plan=fault_plan, max_retries=2,
                retry_backoff_s=0.0,
                degrade=DegradeConfig(enter_queue=3 * batch,
                                      exit_queue=batch))
    chaos_outcomes, chaos_done_at, chaos_makespan = \
        replay_robust(chaos, trace)
    rb = chaos.stats()["robustness"]
    rows += _outcome_rows(tag, "chaos", chaos_outcomes)
    rows.append(f"{PREFIX},{tag},chaos,-,makespan_s,{chaos_makespan:.3f}")
    rows.append(f"{PREFIX},{tag},chaos,-,dispatch_hist,{hist(chaos)}")
    rows.append(f"{PREFIX},{tag},chaos,-,retries,{rb['retries']}")
    rows.append(f"{PREFIX},{tag},chaos,-,failed_ticks,{rb['failed_ticks']}")
    rows.append(f"{PREFIX},{tag},chaos,-,queue_high_water,"
                f"{rb['queue_high_water']}")
    rows.append(f"{PREFIX},{tag},chaos,-,degrade_entries,"
                f"{rb['degrade']['entries']}")
    rows.append(f"{PREFIX},{tag},chaos,-,degrade_exits,"
                f"{rb['degrade']['exits']}")
    rows.append(f"{PREFIX},{tag},chaos,-,straggler_spikes,"
                f"{rb['degrade']['straggler_spikes']}")

    # ---- gate: conservation -------------------------------------------
    # Two independent ledgers must both balance: the replay's per-rid
    # outcome map, and the engine's own robustness counters.
    counted = {oc: sum(1 for v in chaos_outcomes.values() if v == oc)
               for oc in OUTCOMES}
    conserved = (sum(counted.values()) == n
                 and counted == rb["outcomes"]
                 and rb["pending"] == 0)

    # ---- gate: bitwise equivalence of completed outputs ---------------
    bitwise = all(
        np.array_equal(np.asarray(chaos.done[r]), np.asarray(base.done[r]))
        for r, v in chaos_outcomes.items() if v == OUTCOME_COMPLETED)

    # ---- gate: post-burst p99 recovery --------------------------------
    # Compare the tail half of the recovery phase (the head still drains
    # burst backlog) against the fault-free run's same window.
    c_lo, c_hi = bounds[2]
    tail_lo = c_lo + (c_hi - c_lo) // 2
    base_p99 = _p99_window(base_done_at, trace, tail_lo, c_hi)
    chaos_p99 = _p99_window(chaos_done_at, trace, tail_lo, c_hi)
    recovered = bool(np.isfinite(chaos_p99)
                     and chaos_p99 <= RECOVERY_ENVELOPE * base_p99)
    rows.append(f"{PREFIX},{tag},recovery,-,baseline_tail_p99_ms,"
                f"{base_p99 * 1e3:.2f}")
    rows.append(f"{PREFIX},{tag},recovery,-,chaos_tail_p99_ms,"
                f"{chaos_p99 * 1e3:.2f}")

    # ---- armed-but-idle no-op gate (steady trace) ---------------------
    steady_trace = poisson_trace(steady, max(n_a, 12), shape, seed=3)
    noop_rows, noop_ok = _steady_noop_rows(tag, g, params, plan, batch,
                                           slo_s, steady_trace)
    rows += noop_rows

    # ---- pipelined chaos (faulted in-flight ticks, depth 2) -----------
    pipe_rows, pipe_conserved, pipe_bitwise = _pipelined_chaos_rows(
        tag, g, params, plan, batch, pipelined_n)
    rows += pipe_rows

    rows.append(f"{PREFIX},{tag},summary,-,conservation,"
                f"{conserved and pipe_conserved}")
    rows.append(f"{PREFIX},{tag},summary,-,completed_bitwise_ok,"
                f"{bitwise and pipe_bitwise}")
    rows.append(f"{PREFIX},{tag},summary,-,recovery_p99_ok,{recovered}")
    rows.append(f"{PREFIX},{tag},summary,-,idle_knobs_noop,{noop_ok}")
    rows.append(f"{PREFIX},{tag},summary,-,faults_exercised,"
                f"{rb['retries'] > 0 and counted[OUTCOME_FAILED] > 0}")
    rows.append(f"{PREFIX},{tag},summary,-,overload_exercised,"
                f"{counted[OUTCOME_REJECTED] + counted[OUTCOME_SHED] > 0}")
    return rows


def run(smoke: bool = False) -> List[str]:
    return _measure(smoke)


if __name__ == "__main__":
    out = run(smoke="--smoke" in sys.argv)
    print("\n".join(out))
    # Conservation, bitwise-completion and the armed-idle no-op gate on
    # every invocation (including --smoke); the recovery-latency gate is
    # enforced on the committed full-run rows by the CI schema guard —
    # smoke-scale latency ratios on shared CI hosts are scheduling noise.
    hard = ("conservation", "completed_bitwise_ok", "idle_knobs_noop",
            "faults_exercised")
    for row in out:
        f = row.split(",")
        if f[2] == "summary" and f[4] in hard and f[5] != "True":
            sys.exit(f"chaos gate failed: {row}")
