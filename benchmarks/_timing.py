"""Shared wall-clock helpers for the benchmark modules."""
from __future__ import annotations

import time

import jax


def sampled_interleaved(fns, reps=7):
    """Per-rep wall times for each variant, measured round-robin so ambient
    load drift hits every variant equally instead of biasing whichever ran
    last. Returns {name: [seconds] * reps}; rep i of every variant runs
    back-to-back, so cross-variant comparisons can be *paired* per rep
    (ratios of adjacent measurements cancel machine-phase drift that
    min-vs-min comparisons do not)."""
    for fn in fns.values():
        jax.block_until_ready(fn())   # compile/warm all first
    samples = {name: [] for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            samples[name].append(time.perf_counter() - t0)
    return samples


def timed_interleaved(fns, reps=7):
    """min-of-reps per variant over ``sampled_interleaved`` measurements —
    the standard noise-robust latency estimator."""
    return {name: min(s)
            for name, s in sampled_interleaved(fns, reps=reps).items()}
