"""Layout-transition elision benchmark (PR 4).

Two compiled variants of the same mapped plan, measured end-to-end over
the batch bucket ladder on reduced GoogleNet:

* ``roundtrip`` — ``compile_plan(..., elide=False)``: the layout-agnostic
  lowering every PR before this one executed — each edge materializes
  NHWC and every conv re-gathers its own input representation;
* ``elided``    — the layout-aware lowering: consumers whose input layout
  matches the edge's store format read it directly (im2col chains reuse
  the Toeplitz buffer, Winograd chains stay in the scattered tile domain,
  split vertices materialize the chosen format once and fan it out).

Both variants execute the same plan, so outputs must agree (checked) and
the elided program must be no slower end-to-end (``no_slower``: the
summed median wall clock of one tick per bucket across the whole ladder,
within a 10% noise envelope — repeated runs of the *same* program vary
by more than 5% process-to-process on shared-CPU hosts, so per-bucket
ratios and tighter margins gate on scheduling luck, not on the change;
the per-bucket ``speedup_x`` rows use paired per-rep medians and are
informational). The bench also closes
the cost-model loop: the Table 2 *predicted* transition saving
(``mapper.transition_report``) is compared against the *realized*
wall-clock delta, and their ratio is distilled into a
``TransitionCalibration`` scale — the measured-calibration hook
``cost_model.transition_cost`` accepts.

Run standalone (``python benchmarks/bench_layout_elision.py``) or via
``benchmarks/run.py``; ``--smoke`` runs a tiny graph in seconds for CI.
"""
from __future__ import annotations

import sys
from typing import List

import jax
import numpy as np

from repro.cnn.executor import compile_plan, init_params
from repro.cnn.models import googlenet, vgg16
from repro.core.cost_model import TransitionCalibration
from repro.core.dse import identify_parameters
from repro.core.mapper import lower_plan, map_network, transition_report

try:                                    # package mode (benchmarks.run)
    from benchmarks._timing import sampled_interleaved
except ImportError:                     # script mode (python benchmarks/x.py)
    from _timing import sampled_interleaved


def run(smoke: bool = False) -> List[str]:
    if smoke:
        tag, g = "vgg16_r8_smoke", vgg16(res=8, scale=0.05)
        batches, reps, plan = (1, 2), 3, None
    else:
        tag, g = "googlenet_r56", googlenet(res=56, scale=0.25)
        batches, reps = (1, 2, 4, 8), 13
        hw = identify_parameters(g, max_dim=512)
        plan = map_network(g, hw=hw)
    params = init_params(g, jax.random.PRNGKey(0))
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])

    lowered = lower_plan(g, plan)
    rows = [
        f"layout_elision,{tag},config,transition_edges,"
        f"{len(lowered.transitions)}",
        f"layout_elision,{tag},config,elided_edges,"
        f"{len(lowered.elided_edges)}",
    ]

    runs = {
        "elided": compile_plan(g, plan),
        "roundtrip": compile_plan(g, plan, elide=False),
    }
    ok = True
    med = {name: {} for name in runs}
    for batch in batches:
        xb = jax.random.normal(jax.random.PRNGKey(2), (batch,) + shape)
        out = {name: np.asarray(r(params, xb)) for name, r in runs.items()}
        ok &= bool(np.allclose(out["elided"], out["roundtrip"],
                               rtol=1e-4, atol=1e-5))
        samples = sampled_interleaved(
            {name: (lambda r=r, x=xb: r(params, x))
             for name, r in runs.items()}, reps=reps)
        ms = {name: min(s) * 1e3 for name, s in samples.items()}
        for name, s in samples.items():
            med[name][batch] = float(np.median(s))
        # Paired per-rep comparison: each rep measures both variants
        # back-to-back, so the median of per-rep ratios cancels
        # machine-phase drift a min-vs-min comparison is hostage to.
        speedup = float(np.median(
            [rt / el for rt, el in
             zip(samples["roundtrip"], samples["elided"])]))
        pre = f"layout_elision,{tag},b{batch}"
        rows.append(f"{pre},elided_ms,{ms['elided']:.2f}")
        rows.append(f"{pre},roundtrip_ms,{ms['roundtrip']:.2f}")
        rows.append(f"{pre},speedup_x,{speedup:.3f}")

    # The gate sums the whole bucket ladder (one tick per bucket, as the
    # serving engine would dispatch them) and allows a 10% envelope:
    # repeated runs of the SAME variant differ by >5% process-to-process
    # on shared-CPU hosts (XLA CPU re-schedules per compile), so the
    # aggregate-within-envelope gate asserts what is actually measurable
    # here — the elided program is not meaningfully slower — while the
    # per-bucket rows publish the raw picture.
    el_total = sum(med["elided"].values())
    rt_total = sum(med["roundtrip"].values())
    no_slower = el_total <= rt_total * 1.10

    # Predicted (Table 2) vs realized transition savings → calibration.
    # Realized is normalized per image over the ladder (Σ median deltas /
    # Σ batch sizes); predicted prices one image's transitions. A realized
    # delta at or below zero (within the noise envelope, or XLA fused the
    # conversions away) clamps the scale to 0 — a calibration is a cost
    # multiplier and can never be negative.
    rep = transition_report(g, lowered)
    predicted_s = rep["predicted_saving_s"]
    realized_s = (rt_total - el_total) / sum(batches)
    scale = max(realized_s / predicted_s, 0.0) if predicted_s > 0 else 0.0
    cal = TransitionCalibration(default=scale)
    rep_cal = transition_report(g, lowered, calibration=cal)
    pre = f"layout_elision,{tag},summary"
    rows.append(f"{pre},elided_ladder_ms,{el_total * 1e3:.2f}")
    rows.append(f"{pre},roundtrip_ladder_ms,{rt_total * 1e3:.2f}")
    rows.append(f"{pre},predicted_saving_us,{predicted_s * 1e6:.3f}")
    rows.append(f"{pre},realized_saving_us,{realized_s * 1e6:.1f}")
    rows.append(f"{pre},calibration_scale,{scale:.1f}")
    rows.append(f"{pre},calibrated_saving_us,"
                f"{rep_cal['predicted_saving_s'] * 1e6:.1f}")
    rows.append(f"{pre},outputs_ok,{ok}")
    rows.append(f"{pre},no_slower,{no_slower}")
    return rows


if __name__ == "__main__":
    out = run(smoke="--smoke" in sys.argv)
    print("\n".join(out))
    # Correctness gates the smoke job; the no_slower perf summary is too
    # noisy to assert on the tiny smoke graph and is only enforced for the
    # committed full-run rows (see the CI schema guard).
    if any(row.endswith("outputs_ok,False") for row in out):
        sys.exit(1)
