"""Benchmark harness: one module per paper table/figure. Prints
``name,...`` CSV rows (μs-scale latencies are cost-model seconds ×1e6 where
applicable; derived columns documented per module)."""
from __future__ import annotations

import time


def main() -> None:
    import benchmarks.bench_algorithms as ba
    import benchmarks.bench_dse as bd
    import benchmarks.bench_e2e as be
    import benchmarks.bench_roofline as br
    import benchmarks.bench_utilization as bu

    for name, mod in (("bench_algorithms", ba), ("bench_utilization", bu),
                      ("bench_dse", bd), ("bench_e2e", be),
                      ("bench_roofline", br)):
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness running end to end
            rows = [f"{name},ERROR,{e!r}"]
        print(f"# === {name} ({time.time() - t0:.1f}s) ===")
        print("\n".join(rows))
        print()


if __name__ == "__main__":
    main()
