"""Benchmark harness: one module per paper table/figure. Prints
``name,...`` CSV rows (μs-scale latencies are cost-model seconds ×1e6 where
applicable; derived columns documented per module) and lands the same rows
in ``BENCH_RESULTS.json`` at the repo root so the perf trajectory
(e.g. the compiled-plan vs eager-loop wall-clock from bench_e2e) is
machine-readable across PRs.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def main() -> None:
    import benchmarks.bench_adaptive_serving as bas
    import benchmarks.bench_algorithms as ba
    import benchmarks.bench_chaos_serving as bc
    import benchmarks.bench_dse as bd
    import benchmarks.bench_dynamic_batching as bdb
    import benchmarks.bench_e2e as be
    import benchmarks.bench_fused_autotune as bf
    import benchmarks.bench_layout_elision as bl
    import benchmarks.bench_multi_model as bm
    import benchmarks.bench_pipelined_serving as bp
    import benchmarks.bench_quantized as bq
    import benchmarks.bench_roofline as br
    import benchmarks.bench_sharded_serving as bs
    import benchmarks.bench_utilization as bu

    results = {}
    for name, mod in (("bench_algorithms", ba), ("bench_utilization", bu),
                      ("bench_dse", bd), ("bench_e2e", be),
                      ("bench_fused_autotune", bf),
                      ("bench_layout_elision", bl),
                      ("bench_quantized", bq),
                      ("bench_dynamic_batching", bdb),
                      ("bench_sharded_serving", bs),
                      ("bench_pipelined_serving", bp),
                      ("bench_chaos_serving", bc),
                      ("bench_multi_model", bm),
                      ("bench_adaptive_serving", bas),
                      ("bench_roofline", br)):
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness running end to end
            rows = [f"{name},ERROR,{e!r}"]
        elapsed = time.time() - t0
        results[name] = {"elapsed_s": round(elapsed, 1), "rows": rows}
        print(f"# === {name} ({elapsed:.1f}s) ===")
        print("\n".join(rows))
        print()

    out = REPO / "BENCH_RESULTS.json"
    out.write_text(json.dumps(results, indent=2))
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
