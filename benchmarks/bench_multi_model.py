"""Multi-model serving benchmark (PR 8): two tenants, one engine.

Registers two tenants of the SAME architecture (independent params) in
one ``MultiModelEngine`` and replays a Poisson trace per tenant through
the joint deadline-ordered scheduler (``_trace.replay_multi``), against
a solo baseline where each model gets a dedicated ``CNNServingEngine``
at the same per-model arrival rate. Three always-on gates plus one
full-run latency gate:

* ``conservation`` — each tenant's outcome ledger balances
  (``completed + rejected_full + shed_deadline + failed + pending ==
  submitted``) AND matches the replay's per-rid outcome map: the joint
  scheduler must not lose, double-count or cross-wire requests between
  tenants.
* ``cross_model_cache_hits`` — registering tenant B hit the shared
  ``ExecutableCache`` once per bucket: identical architectures share
  every compiled ``(graph, plan, bucket, mesh)`` executable, the whole
  point of hashing graphs instead of keying on object identity.
* ``outputs_ok`` — spot-checked joint-served outputs match the eager
  single-image reference *under each tenant's own params*: shared
  executables must never leak one tenant's weights into another's
  results.
* ``p99_ratio_ok`` (full runs; CI re-checks the committed rows) — each
  tenant's joint-served p99 is within ``P99_ENVELOPE`` × its solo p99.
  The joint engine carries 2× the aggregate load of either solo run, so
  this bounds the cost of co-tenancy, not noise: per-model rates sit at
  0.25× ladder saturation, where a correct joint scheduler has slack.

``--smoke`` (CI serving-smoke step) runs the tiny-graph variant and
gates conservation + cache hits + outputs; the p99 envelope is enforced
on the committed full-run rows by the CI schema guard (smoke-scale
latency ratios on shared hosts are scheduling noise).
"""

from __future__ import annotations

import sys
from pathlib import Path
from typing import List

REPO = Path(__file__).resolve().parents[1]
for _p in (str(REPO), str(REPO / "src")):     # direct `python benchmarks/…`
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import numpy as np

from benchmarks._trace import poisson_trace, replay, replay_multi
from repro.cnn.executor import ExecutableCache, forward, init_params
from repro.cnn.models import googlenet, vgg16
from repro.core.dse import identify_parameters
from repro.core.mapper import map_network
from repro.serving.cnn_engine import (OUTCOME_COMPLETED, OUTCOME_FAILED,
                                      OUTCOME_REJECTED, OUTCOME_SHED,
                                      CNNServingEngine)
from repro.serving.multi_engine import MultiModelEngine

OUTCOMES = (OUTCOME_COMPLETED, OUTCOME_REJECTED, OUTCOME_SHED,
            OUTCOME_FAILED)
# Joint p99 per tenant must land within this factor of the same tenant
# served alone at the same per-model rate. The joint run serves DOUBLE
# the aggregate traffic, so >1 ratios are physics; 1.25 is tight enough
# that a scheduler which starves one tenant or serializes badly fails.
P99_ENVELOPE = 1.25
PREFIX = "multi_model"


def _p99_ms(lats) -> float:
    return float(np.percentile(np.asarray(lats), 99)) * 1e3


def _conserved(eng: CNNServingEngine, outcomes, n: int) -> bool:
    """Both ledgers balance and agree: the replay's per-rid outcome map
    and the engine's own robustness counters."""
    rb = eng.stats()["robustness"]
    counted = {oc: sum(1 for v in outcomes.values() if v == oc)
               for oc in OUTCOMES}
    return (sum(counted.values()) == n
            and counted == rb["outcomes"]
            and rb["pending"] == 0
            and eng.submitted_total == n)


def _measure(smoke: bool) -> List[str]:
    if smoke:
        tag, g = "vgg16_r8_smoke_x2", vgg16(res=8, scale=0.05)
        plan, batch, n = None, 4, 12
    else:
        tag, g = "googlenet_r56_x2", googlenet(res=56, scale=0.25)
        hw = identify_parameters(g, max_dim=512)
        plan = map_network(g, hw=hw)
        batch, n = 8, 48
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])
    params = {"model_a": init_params(g, jax.random.PRNGKey(0)),
              "model_b": init_params(g, jax.random.PRNGKey(1))}

    # One shared cache for the whole bench: the probe pre-compiles the
    # ladder, tenant A re-hits it, and the metric that matters —
    # cross-model hits — is the hit delta across tenant B's registration.
    cache = ExecutableCache()
    probe = CNNServingEngine(g, params["model_a"], plan, batch_size=batch,
                             warmup=True, cache=cache)
    svc_top = probe.service_estimate(batch)
    sat_rps = batch / svc_top
    rate = 0.25 * sat_rps                     # per model; aggregate 0.5×
    # Under sparse arrivals the SLO scheduler waits ~slo before an
    # undersized dispatch, so solo p99 ≈ slo while the joint worst case
    # adds one other-tenant tick: the structural ratio is 1 + svc/slo.
    # 6× keeps that at ~1.17, inside the 1.25 envelope with real margin.
    slo_s = 6.0 * svc_top

    multi = MultiModelEngine(cache=cache)
    multi.register_model("model_a", g, params["model_a"], plan,
                         slo_s=slo_s, batch_size=batch, warmup=True)
    hits_before_b = cache.hits
    multi.register_model("model_b", g, params["model_b"], plan,
                         slo_s=slo_s, batch_size=batch, warmup=True)
    cross_hits = cache.hits - hits_before_b
    buckets = multi.engines["model_a"].buckets

    rows = [
        f"{PREFIX},{tag},config,-,n_per_model,{n}",
        f"{PREFIX},{tag},config,-,batch,{batch}",
        f"{PREFIX},{tag},config,-,svc_ms_top,{svc_top * 1e3:.2f}",
        f"{PREFIX},{tag},config,-,rate_rps_per_model,{rate:.2f}",
        f"{PREFIX},{tag},config,-,slo_ms,{slo_s * 1e3:.2f}",
        f"{PREFIX},{tag},cache,-,entries,{len(cache)}",
        f"{PREFIX},{tag},cache,-,hits,{cache.hits}",
        f"{PREFIX},{tag},cache,-,misses,{cache.misses}",
        f"{PREFIX},{tag},cache,-,cross_model_hits,{cross_hits}",
    ]

    # ---- joint replay: one trace per tenant, merged timeline ----------
    traces = {name: poisson_trace(rate, n, shape, seed=i + 1)
              for i, name in enumerate(("model_a", "model_b"))}
    outcomes, done_at, makespan = replay_multi(multi, traces)
    rows.append(f"{PREFIX},{tag},joint,-,makespan_s,{makespan:.3f}")

    conserved, outputs_ok = True, True
    joint_p99 = {}
    for name in ("model_a", "model_b"):
        eng = multi.engines[name]
        conserved = conserved and _conserved(eng, outcomes[name], n)
        lats = [done_at[name][r] - traces[name][r][0]
                for r in range(n) if r in done_at[name]]
        joint_p99[name] = _p99_ms(lats)
        for oc in OUTCOMES:
            cnt = sum(1 for v in outcomes[name].values() if v == oc)
            rows.append(f"{PREFIX},{tag},outcomes,{name},{oc},{cnt}")
        rows.append(f"{PREFIX},{tag},joint,{name},p99_ms,"
                    f"{joint_p99[name]:.2f}")
        # Shared executables, private params: the joint-served result
        # must equal the eager reference under THIS tenant's weights.
        for rid in sorted(eng.done)[:3]:
            ref = forward(g, params[name], traces[name][rid][1][None])
            if not np.allclose(np.asarray(eng.done[rid]), ref[0],
                               rtol=1e-4, atol=1e-4):
                outputs_ok = False

    # ---- solo baselines: dedicated engine per model, same rate --------
    solo_p99 = {}
    for name in ("model_a", "model_b"):
        solo = CNNServingEngine(g, params[name], plan, batch_size=batch,
                                slo_s=slo_s, warmup=True, cache=cache)
        lat, _ = replay(solo, traces[name])
        solo_p99[name] = _p99_ms(lat)
        rows.append(f"{PREFIX},{tag},solo,{name},p99_ms,"
                    f"{solo_p99[name]:.2f}")

    ratio_ok = True
    for name in ("model_a", "model_b"):
        ratio = joint_p99[name] / solo_p99[name]
        ratio_ok = ratio_ok and ratio <= P99_ENVELOPE
        rows.append(f"{PREFIX},{tag},joint,{name},p99_vs_solo,"
                    f"{ratio:.3f}")

    rows.append(f"{PREFIX},{tag},summary,-,conservation,{conserved}")
    rows.append(f"{PREFIX},{tag},summary,-,cross_model_cache_hits,"
                f"{cross_hits >= len(buckets) and cross_hits > 0}")
    rows.append(f"{PREFIX},{tag},summary,-,outputs_ok,{outputs_ok}")
    rows.append(f"{PREFIX},{tag},summary,-,p99_ratio_ok,{ratio_ok}")
    return rows


def run(smoke: bool = False) -> List[str]:
    return _measure(smoke)


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    out = run(smoke=smoke)
    print("\n".join(out))
    # Conservation, cache sharing and output isolation gate every
    # invocation (including --smoke); the p99 co-tenancy envelope gates
    # full runs here and the committed full-run rows in CI — smoke-scale
    # latency ratios on shared hosts are scheduling noise.
    hard = ["conservation", "cross_model_cache_hits", "outputs_ok"]
    if not smoke:
        hard.append("p99_ratio_ok")
    for row in out:
        f = row.split(",")
        if f[2] == "summary" and f[4] in hard and f[5] != "True":
            sys.exit(f"multi-model gate failed: {row}")
