"""Fused-epilogue equivalence (the §3 in-pipeline auxiliary units).

CONV+ReLU lowered to ONE overlay call must equal the unfused
conv-then-relu reference for every algorithm family, on both backends,
batched and unbatched — and the fused compiled plan must equal the unfused
PR-1-style lowering end to end. Mixed pallas/reference backends inside one
compiled plan must be semantically invisible.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cnn import layers as L
from repro.cnn import overlay
from repro.cnn.executor import compile_plan, forward, init_params
from repro.cnn.models import googlenet
from repro.core.algorithms import IM2COL, KN2ROW, WINO_2_3
from repro.core.autotune import Binding, LayerTuning, TuningRecord, record_key
from repro.core.cost_model import Dataflow
from repro.core.graph import LayerKind
from repro.core.mapper import ConvLowering, lower_plan
from repro.kernels.conv_im2col.ref import conv_ref
from repro.kernels.gemm.ops import batched_gemm, gemm

RNG = np.random.default_rng(7)


def rnd(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


# ------------------------------------------------------------ kernel level
@pytest.mark.parametrize("df", list(Dataflow))
def test_gemm_epilogue_fused(df):
    a, b = rnd(40, 24), rnd(24, 16)
    bias = rnd(16)
    base = np.asarray(a) @ np.asarray(b)
    got_relu = gemm(a, b, df, 128, 128, interpret=True, epilogue="relu")
    np.testing.assert_allclose(np.asarray(got_relu), np.maximum(base, 0),
                               rtol=1e-5, atol=1e-5)
    got_br = gemm(a, b, df, 128, 128, interpret=True, epilogue="bias_relu",
                  bias=bias)
    np.testing.assert_allclose(np.asarray(got_br),
                               np.maximum(base + np.asarray(bias), 0),
                               rtol=1e-5, atol=1e-5)


def test_batched_gemm_epilogue_fused():
    a, b = rnd(3, 16, 24), rnd(3, 24, 8)
    bias = rnd(8)
    base = np.einsum("gmk,gkn->gmn", np.asarray(a), np.asarray(b))
    got = batched_gemm(a, b, interpret=True, epilogue="bias_relu", bias=bias)
    np.testing.assert_allclose(np.asarray(got),
                               np.maximum(base + np.asarray(bias), 0),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- overlay level
@pytest.mark.parametrize("algo", [IM2COL, KN2ROW, WINO_2_3])
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("batched", [False, True])
def test_fused_conv_relu_equals_unfused(algo, use_pallas, batched):
    """conv+ReLU fused == unfused reference, all families × backends ×
    ranks (the tentpole equivalence)."""
    x = rnd(2, 12, 12, 5) if batched else rnd(12, 12, 5)
    w = rnd(3, 3, 5, 9)
    unfused = np.maximum(np.asarray(conv_ref(x, w)), 0)
    fused = overlay.apply_conv(x, w, algo, Dataflow.WS, 256, 128,
                               use_pallas=use_pallas, interpret=True,
                               epilogue="relu")
    assert fused.shape == unfused.shape
    np.testing.assert_allclose(np.asarray(fused), unfused,
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("algo", [IM2COL, KN2ROW, WINO_2_3])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_bias_relu(algo, use_pallas):
    """bias+ReLU epilogue: y = relu(conv(x) + b) in one overlay call."""
    x, w, b = rnd(10, 10, 4), rnd(3, 3, 4, 6), rnd(6)
    want = np.maximum(np.asarray(conv_ref(x, w)) + np.asarray(b), 0)
    got = overlay.apply_conv(x, w, algo, use_pallas=use_pallas,
                             interpret=True, epilogue="bias_relu", bias=b)
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-3, atol=5e-3)


def test_fused_multiround_winograd():
    """K>r Winograd runs rounds of accumulation — the epilogue must apply
    once, after the last round (ReLU does not distribute over +)."""
    x, w = rnd(9, 9, 3), rnd(5, 5, 3, 4)
    want = np.maximum(np.asarray(conv_ref(x, w)), 0)
    got = overlay.apply_conv(x, w, WINO_2_3, use_pallas=True,
                             interpret=True, epilogue="relu")
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-3, atol=5e-3)


def test_unknown_epilogue_rejected():
    x, w = rnd(8, 8, 3), rnd(3, 3, 3, 4)
    with pytest.raises(ValueError, match="epilogue"):
        overlay.apply_conv(x, w, IM2COL, epilogue="gelu")
    with pytest.raises(ValueError, match="bias"):
        overlay.apply_conv(x, w, IM2COL, epilogue="bias")  # bias missing


@pytest.mark.parametrize("batched", [False, True])
def test_lax_backend_with_fused_epilogue(batched):
    """backend="lax" (XLA native conv) joins the overlay with the same
    fused-epilogue semantics as every other backend."""
    x = rnd(2, 11, 11, 4) if batched else rnd(11, 11, 4)
    w, b = rnd(3, 3, 4, 6), rnd(6)
    want = np.maximum(np.asarray(conv_ref(x, w, stride=2)) + np.asarray(b), 0)
    got = overlay.apply_conv(x, w, KN2ROW, stride=2, backend="lax",
                             epilogue="bias_relu", bias=b)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
    # backend overrides use_pallas; junk backends are rejected
    with pytest.raises(ValueError, match="backend"):
        overlay.apply_conv(x, w, IM2COL, backend="cuda")


# ------------------------------------------------------ compiled-plan level
@pytest.fixture(scope="module")
def reduced_googlenet():
    g = googlenet(res=56, scale=0.25)
    params = init_params(g, jax.random.PRNGKey(0))
    return g, params


def test_compiled_fused_equals_unfused_plan(reduced_googlenet):
    """epilogue="relu" (fused, the new default) and epilogue="none"
    (PR-1's conv-then-relu) compile to the same function."""
    g, params = reduced_googlenet
    xb = rnd(2, 56, 56, 3)
    fused = compile_plan(g)(params, xb)
    unfused = compile_plan(g, epilogue="none")(params, xb)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-6)


def test_lowering_carries_epilogue_and_backend(reduced_googlenet):
    g, _ = reduced_googlenet
    low = lower_plan(g, None)
    assert all(l.epilogue == "relu" and l.backend == "auto"
               for l in low.values())
    low = lower_plan(g, None, epilogue="none", backend="reference")
    assert all(l.epilogue == "none" and l.backend == "reference"
               for l in low.values())
    # lowerings stay hashable — a (graph, lowering) pair keys one program
    assert hash(ConvLowering(IM2COL, Dataflow.NS, 128, 128,
                             backend="lax")) is not None


def test_mixed_backend_compiled_plan_matches_reference_oracle(
        reduced_googlenet):
    """One compiled plan cycling pallas/reference/lax per conv layer equals
    the all-reference oracle (the ROADMAP mixed-backend item)."""
    g, params = reduced_googlenet
    entries = {}
    backends = ("pallas", "reference", "lax")
    for i, node in enumerate(g.conv_nodes()):
        key = record_key(node.conv)
        entries[key] = LayerTuning(
            binding=Binding("im2col", "NS", 128, 128, backends[i % 3]),
            measured_s=0.0, candidates=[])
    record = TuningRecord(entries)
    lowering = lower_plan(g, None, default_algo=IM2COL, tuning=record)
    assert {l.backend for l in lowering.values()} == set(backends)

    xb = rnd(2, 56, 56, 3)
    mixed = compile_plan(g, default_algo=IM2COL, tuning=record,
                         interpret=True)(params, xb)
    oracle = compile_plan(g, default_algo=IM2COL)(params, xb)
    np.testing.assert_allclose(np.asarray(mixed), np.asarray(oracle),
                               rtol=2e-2, atol=2e-3)


def test_googlenet_bias_relu_lowering_parity(reduced_googlenet):
    """The ROADMAP conv-bias item: ``init_params`` creates per-conv biases
    and the GoogleNet lowering fuses them (``epilogue="bias_relu"``); the
    fused compiled plan must equal the *unfused* bias+relu reference
    (conv, then bias-add, then ReLU applied outside the overlay)."""
    g, params0 = reduced_googlenet
    # init_params created zero biases for every conv
    for node in g.conv_nodes():
        b = params0[node.id]["b"]
        assert b.shape == (node.conv.c_out,)
        np.testing.assert_array_equal(np.asarray(b), 0)
    # randomize the biases so the parity check is non-trivial
    params = {}
    for nid, p in params0.items():
        params[nid] = dict(p)
        if g.nodes[nid].kind is LayerKind.CONV:
            params[nid]["b"] = rnd(*p["b"].shape)
    low = lower_plan(g, None, epilogue="bias_relu")
    assert all(l.epilogue == "bias_relu" for l in low.values())

    @overlay.nhwc_conv
    def unfused(x, w, *a, stride=1, padding="SAME", epilogue="none",
                bias=None, **kw):
        y = conv_ref(x, w, stride=stride, padding=padding)
        if bias is not None:
            y = y + bias
        return jnp.maximum(y, 0) if epilogue.endswith("relu") else y

    xb = rnd(2, 56, 56, 3)
    fused = compile_plan(g, epilogue="bias_relu")(params, xb)
    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(overlay, "apply_conv", unfused)
        ref = forward(g, params, xb, epilogue="bias_relu")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)
    # a random bias must actually change the function
    base = compile_plan(g)(params0, xb)
    assert not np.allclose(np.asarray(fused), np.asarray(base),
                           rtol=2e-2, atol=2e-3)


# -------------------------------------------------------- avg_pool overlay
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_avg_pool_via_overlay(padding, use_pallas):
    """§3.4: AvgPool as a K×K conv with 1/(K1K2) channel-diagonal weights
    through the overlay GEMM unit == the jnp reduce-window path, including
    the SAME-padding valid-count division at the edges."""
    for x in (rnd(9, 9, 5), rnd(2, 9, 9, 5)):
        want = L.avg_pool(x, 3, 2, padding)
        got = L.avg_pool(x, 3, 2, padding, via="overlay",
                         use_pallas=use_pallas, interpret=True)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-3, atol=5e-3)


def test_executor_avg_pool_via_overlay(reduced_googlenet):
    """The compiled program can route POOL_AVG through the overlay GEMM
    unit without changing the network function."""
    g, params = reduced_googlenet
    x = rnd(56, 56, 3)
    via_overlay = compile_plan(g, avg_pool_via="overlay")(params, x)
    via_jnp = compile_plan(g)(params, x)
    np.testing.assert_allclose(np.asarray(via_overlay), np.asarray(via_jnp),
                               rtol=2e-2, atol=2e-3)


def test_avg_pool_rejects_unknown_via():
    with pytest.raises(ValueError, match="via"):
        L.avg_pool(rnd(8, 8, 3), 2, 2, via="fpga")


# ------------------------------------------------------------ serving tick
def test_serving_engine_reuses_batch_buffer(reduced_googlenet):
    """step() must reuse one preallocated staging buffer across ticks and
    zero only stale tail slots — outputs stay correct over partial ticks."""
    from repro.serving.cnn_engine import CNNRequest, CNNServingEngine
    g, params = reduced_googlenet
    eng = CNNServingEngine(g, params, None, batch_size=4)
    buf0 = eng._batch_buf
    imgs = [np.asarray(rnd(56, 56, 3)) for _ in range(6)]
    for rid, img in enumerate(imgs[:4]):
        eng.submit(CNNRequest(rid=rid, image=img))
    assert eng.step() == 4
    # partial tick: 2 requests; slots 2-3 hold stale images and must be
    # zeroed, slots beyond stay zero
    for rid, img in enumerate(imgs[4:], start=4):
        eng.submit(CNNRequest(rid=rid, image=img))
    assert eng.step() == 2
    assert eng._batch_buf is buf0            # no per-tick allocation
    np.testing.assert_array_equal(eng._batch_buf[2:], 0)
    for rid, img in enumerate(imgs):
        want = forward(g, params, jnp.asarray(img))
        np.testing.assert_allclose(eng.done[rid], np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
