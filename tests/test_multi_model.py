"""Multi-tenant serving tier (PR 8).

Pins the shared-executable-cache contract (``graph_hash`` /
``executable_cache_key`` / ``ExecutableCache`` — identical architectures
share compiled programs, differing ones never collide), the
cross-model tuning-reuse helpers (``TuningRecord.merge``,
``signature_coverage``) and the ``MultiModelEngine`` joint scheduler:
per-tenant outcome conservation under joint serving, deadline-ordered
tenant ticks, the global queue cap rejecting into the owning tenant's
ledger, and the global per-step wall budget.
"""
import numpy as np
import pytest

import jax

from repro.cnn.executor import (ExecutableCache, compile_plan,
                                executable_cache_key, forward, graph_hash,
                                init_params)
from repro.cnn.models import vgg16
from repro.core.autotune import (Binding, LayerTuning, TuningRecord,
                                 record_key, signature_coverage)
from repro.serving.cnn_engine import (OUTCOME_COMPLETED, OUTCOME_REJECTED,
                                      CNNRequest, CNNServingEngine)
from repro.serving.multi_engine import MultiModelEngine

RNG = np.random.default_rng(13)


class FakeClock:
    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t


@pytest.fixture(scope="module")
def tiny():
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    return g, params


def img():
    return np.asarray(RNG.standard_normal((8, 8, 3)), np.float32)


def conserved(eng) -> bool:
    rb = eng.stats()["robustness"]
    return (sum(rb["outcomes"].values()) + rb["pending"]
            == eng.submitted_total)


# ---------------------------------------------------------------------------
# Graph hashing + executable cache.
# ---------------------------------------------------------------------------

class TestGraphHash:
    def test_independent_builds_hash_equal(self):
        # Node names/ids are construction artifacts, not architecture.
        assert graph_hash(vgg16(res=8, scale=0.05)) == \
            graph_hash(vgg16(res=8, scale=0.05))

    def test_structural_difference_changes_hash(self):
        base = graph_hash(vgg16(res=8, scale=0.05))
        assert graph_hash(vgg16(res=8, scale=0.1)) != base     # widths
        assert graph_hash(vgg16(res=16, scale=0.05)) != base   # resolution

    def test_cache_key_differs_for_differing_graphs(self, tiny):
        g, _ = tiny
        other = vgg16(res=8, scale=0.1)
        for bucket in (1, 2, 4):
            assert executable_cache_key(g, None, tuning_batch=bucket) != \
                executable_cache_key(other, None, tuning_batch=bucket)

    def test_cache_key_distinguishes_buckets_and_options(self, tiny):
        g, _ = tiny
        k = executable_cache_key(g, None, tuning_batch=2)
        assert executable_cache_key(g, None, tuning_batch=4) != k
        assert executable_cache_key(g, None, tuning_batch=2,
                                    epilogue="relu") != \
            executable_cache_key(g, None, tuning_batch=2,
                                 epilogue="bias_relu")
        assert executable_cache_key(g, None, tuning_batch=2,
                                    donate=True) != k


class TestExecutableCache:
    def test_identical_graphs_share_executable(self, tiny):
        g, params = tiny
        cache = ExecutableCache()
        g2 = vgg16(res=8, scale=0.05)        # independent build, same arch
        r1 = compile_plan(g, None, cache=cache)
        r2 = compile_plan(g2, None, cache=cache)
        assert r1 is r2
        assert cache.stats() == {"entries": 1, "hits": 1, "misses": 1}

    def test_shared_executable_private_params(self, tiny):
        g, pa = tiny
        pb = init_params(g, jax.random.PRNGKey(1))
        cache = ExecutableCache()
        run = compile_plan(g, None, cache=cache)
        x = img()[None]
        ya, yb = np.asarray(run(pa, x)), np.asarray(run(pb, x))
        assert not np.allclose(ya, yb)       # params are call args
        assert np.allclose(ya, forward(g, pa, x), rtol=1e-4, atol=1e-4)

    def test_differing_graphs_get_separate_entries(self, tiny):
        g, _ = tiny
        cache = ExecutableCache()
        compile_plan(g, None, cache=cache)
        compile_plan(vgg16(res=8, scale=0.1), None, cache=cache)
        assert len(cache) == 2
        assert cache.misses == 2 and cache.hits == 0

    def test_engines_share_bucket_ladder_through_cache(self, tiny):
        g, pa = tiny
        pb = init_params(g, jax.random.PRNGKey(1))
        cache = ExecutableCache()
        ea = CNNServingEngine(g, pa, None, batch_size=4, cache=cache)
        misses_after_a = cache.misses
        eb = CNNServingEngine(vgg16(res=8, scale=0.05), pb, None,
                              batch_size=4, cache=cache)
        assert cache.misses == misses_after_a    # B compiled nothing
        assert cache.hits >= len(ea.buckets)
        for b in ea.buckets:
            assert ea._runs[b] is eb._runs[b]


# ---------------------------------------------------------------------------
# Cross-model tuning reuse.
# ---------------------------------------------------------------------------

def _entry(conv, bucket, measured_s=1e-3):
    b = Binding("im2col", "NS", 64, 64, "reference")
    return record_key(conv, bucket), LayerTuning(b, measured_s, [],
                                                 batch=bucket)


class TestTuningReuse:
    def test_signature_coverage_partition(self, tiny):
        g, _ = tiny
        conv = next(iter(g.conv_nodes())).conv
        key, ent = _entry(conv, 2)
        rec = TuningRecord({key: ent})
        cov = signature_coverage(g, rec, buckets=(2, 4))
        assert cov["exact"] == [key]
        # Bucket 4 rides the bucket-2 entry via lookup's fallback.
        assert cov["fallback"] == [record_key(conv, 4)]
        assert cov["missing"]                 # untuned signatures remain
        total = sum(len(v) for v in cov.values())
        assert total == len({record_key(n.conv, b)
                             for n in g.conv_nodes() for b in (2, 4)})

    def test_identical_signatures_same_key(self):
        # Two independently built identical architectures share tuning
        # keys outright — the record transfers with no merge logic.
        c1 = next(iter(vgg16(res=8, scale=0.05).conv_nodes())).conv
        c2 = next(iter(vgg16(res=8, scale=0.05).conv_nodes())).conv
        assert record_key(c1, 4) == record_key(c2, 4)

    def test_merge_keeps_incumbents_adopts_new(self, tiny):
        g, _ = tiny
        convs = [n.conv for n in g.conv_nodes()]
        k0, e0 = _entry(convs[0], 2, measured_s=1e-3)
        mine = TuningRecord({k0: e0}, meta={"buckets": [2]})
        k0b, e0b = _entry(convs[0], 2, measured_s=9e-3)
        k1, e1 = _entry(convs[-1], 4, measured_s=2e-3)
        theirs = TuningRecord({k0b: e0b, k1: e1},
                              meta={"buckets": [2, 4], "backend": "cpu"})
        assert mine.merge(theirs) == 1
        assert mine.entries[k0].measured_s == 1e-3   # incumbent kept
        assert mine.entries[k1].measured_s == 2e-3   # challenger adopted
        assert mine.meta["buckets"] == [2, 4]
        assert mine.meta["backend"] == "cpu"


# ---------------------------------------------------------------------------
# MultiModelEngine.
# ---------------------------------------------------------------------------

def _multi(g, clock=None, **kw):
    pa = init_params(g, jax.random.PRNGKey(0))
    pb = init_params(g, jax.random.PRNGKey(1))
    multi = MultiModelEngine(clock=clock or FakeClock(), **kw)
    multi.register_model("a", g, pa, None, batch_size=4)
    multi.register_model("b", g, pb, None, batch_size=4)
    return multi, pa, pb


class TestMultiModelEngine:
    def test_joint_serving_conserves_and_isolates(self, tiny):
        g, _ = tiny
        multi, pa, pb = _multi(g)
        imgs = {n: [img() for _ in range(3)] for n in ("a", "b")}
        for name in ("a", "b"):
            for i, im in enumerate(imgs[name]):
                assert multi.submit(name, CNNRequest(
                    rid=i, image=im, t_submit=0.0)) == "queued"
        done = multi.run_until_done()
        for name, params in (("a", pa), ("b", pb)):
            assert sorted(done[name]) == [0, 1, 2]
            assert conserved(multi.engines[name])
            ref = forward(g, params, imgs[name][0][None])
            assert np.allclose(done[name][0], ref[0], rtol=1e-4, atol=1e-4)

    def test_registration_shares_cache(self, tiny):
        g, _ = tiny
        multi, *_ = _multi(g)
        st = multi.stats()
        assert st["cache"]["hits"] >= len(multi.engines["a"].buckets)
        assert st["global"]["models"] == 2

    def test_deadline_order_across_tenants(self, tiny):
        g, _ = tiny
        clk = FakeClock()
        multi, *_ = _multi(g, clock=clk)
        multi.engines["a"].slo_s = 1.0
        multi.engines["b"].slo_s = 0.1     # tighter SLO: due first
        multi.submit("a", CNNRequest(rid=0, image=img(), t_submit=0.0))
        multi.submit("b", CNNRequest(rid=0, image=img(), t_submit=0.0))
        assert multi.engines["b"].oldest_deadline() < \
            multi.engines["a"].oldest_deadline()
        multi.step(now=5.0, flush=True)
        # b's tighter deadline dispatched first: its trace shows an
        # earlier dispatch timestamp (a's tick waited behind b's).
        tb = multi.engines["b"].request_log[-1]
        ta = multi.engines["a"].request_log[-1]
        assert tb.t_dispatch <= ta.t_dispatch

    def test_global_queue_cap_rejects_into_tenant_ledger(self, tiny):
        g, _ = tiny
        multi, *_ = _multi(g, global_max_queue=2)
        assert multi.submit("a", CNNRequest(
            rid=0, image=img(), t_submit=0.0)) == "queued"
        assert multi.submit("b", CNNRequest(
            rid=0, image=img(), t_submit=0.0)) == "queued"
        verdict = multi.submit("a", CNNRequest(
            rid=1, image=img(), t_submit=0.0))
        assert verdict == OUTCOME_REJECTED
        ea = multi.engines["a"]
        assert ea.submitted_total == 2 and ea.rejected_total == 1
        assert ea.request_log[-1].outcome == OUTCOME_REJECTED
        multi.run_until_done()
        assert all(conserved(e) for e in multi.engines.values())

    def test_global_budget_limits_ticks_per_step(self, tiny):
        g, _ = tiny
        multi, *_ = _multi(g, global_budget_s=1e-12)
        for name in ("a", "b"):
            multi.engines[name]._warmup()   # prime service estimates
            multi.submit(name, CNNRequest(rid=0, image=img(),
                                          t_submit=0.0))
        multi.step(now=5.0)
        # The first due tick always runs; the second tenant's estimated
        # tick blows the (absurdly small) budget and waits a round.
        assert multi.last_step["ticks"] == 1
        assert len(multi.last_step["skipped"]) == 1
        multi.step(now=5.0)
        assert multi.last_step["ticks"] == 1
        assert multi.queued_total() == 0
        assert all(conserved(e) for e in multi.engines.values())

    def test_flush_ignores_budget(self, tiny):
        g, _ = tiny
        multi, *_ = _multi(g, global_budget_s=1e-12)
        for name in ("a", "b"):
            multi.submit(name, CNNRequest(rid=0, image=img(),
                                          t_submit=0.0))
        multi.step(now=5.0, flush=True)
        assert multi.last_step["ticks"] == 2
        assert multi.last_step["skipped"] == ()

    def test_duplicate_registration_raises(self, tiny):
        g, params = tiny
        multi = MultiModelEngine(clock=FakeClock())
        multi.register_model("a", g, params, None, batch_size=4)
        with pytest.raises(ValueError, match="already registered"):
            multi.register_model("a", g, params, None, batch_size=4)

    def test_reserved_kwargs_and_pipelining_rejected(self, tiny):
        g, params = tiny
        multi = MultiModelEngine(clock=FakeClock())
        with pytest.raises(ValueError, match="clock"):
            multi.register_model("a", g, params, None,
                                 clock=FakeClock())
        with pytest.raises(ValueError, match="pipeline_depth"):
            multi.register_model("a", g, params, None, pipeline_depth=2)

    def test_unknown_model_raises(self, tiny):
        g, params = tiny
        multi = MultiModelEngine(clock=FakeClock())
        multi.register_model("a", g, params, None, batch_size=4)
        with pytest.raises(KeyError, match="unknown model"):
            multi.submit("nope", CNNRequest(rid=0, image=img()))

    def test_stats_schema(self, tiny):
        g, _ = tiny
        multi, *_ = _multi(g)
        multi.submit("a", CNNRequest(rid=0, image=img(), t_submit=0.0))
        multi.run_until_done()
        st = multi.stats()
        assert set(st) == {"models", "cache", "global"}
        assert set(st["models"]) == {"a", "b"}
        # Per-model stats keep the single-engine schema verbatim.
        assert st["models"]["a"]["submitted"] == 1
        assert "robustness" in st["models"]["a"]
        assert st["global"]["submitted"] == 1
        assert st["global"]["queued"] == 0

    def test_swap_isolation_across_tenants(self, tiny):
        """PR 10: hot-swapping tenant a's plan must not evict tenant b's
        cache entries (the shared cache never evicts — a swap only adds)
        nor perturb b's ladder, ledger, EMAs, or queued work."""
        from repro.core.cost_model import TransitionCalibration
        from repro.core.dse import identify_parameters
        from repro.core.mapper import map_network, plan_fingerprint
        g, _ = tiny
        hw = identify_parameters(g)
        plan_a = map_network(g, hw=hw, use_on_chip=False)
        plan_b = map_network(g, hw=hw, use_on_chip=False,
                             calibration=TransitionCalibration(default=6.0))
        assert plan_fingerprint(plan_a) != plan_fingerprint(plan_b)

        pa = init_params(g, jax.random.PRNGKey(0))
        pb = init_params(g, jax.random.PRNGKey(1))
        multi = MultiModelEngine(clock=FakeClock())
        multi.register_model("a", g, pa, plan_a, batch_size=4)
        multi.register_model("b", g, pb, plan_a, batch_size=4)
        for name in ("a", "b"):
            for i in range(4):
                multi.submit(name, CNNRequest(rid=i, image=img(),
                                              t_submit=0.0))
        multi.step(now=1.0, flush=True)

        eng_b = multi.engines["b"]
        b_runs = eng_b._runs                  # object identity must hold
        b_ledger = dict(eng_b.stats()["robustness"]["outcomes"])
        b_emas = dict(eng_b._svc)
        b_done = set(eng_b.done)
        cache_entries = multi.cache.stats()["entries"]

        old = multi.swap_plan("a", plan_b)
        assert plan_fingerprint(old[0]) == plan_fingerprint(plan_a)
        assert plan_fingerprint(multi.engines["a"].plan) \
            == plan_fingerprint(plan_b)
        # b is untouched: same ladder objects, ledger, EMAs, results.
        assert eng_b._runs is b_runs
        assert plan_fingerprint(eng_b.plan) == plan_fingerprint(plan_a)
        assert dict(eng_b.stats()["robustness"]["outcomes"]) == b_ledger
        assert dict(eng_b._svc) == b_emas
        assert set(eng_b.done) == b_done
        # The shared cache only grew (plan_b's ladder); nothing evicted.
        assert multi.cache.stats()["entries"] >= cache_entries
        assert multi.engines["a"].stats()["plan"]["swaps"] == 1
        assert eng_b.stats()["plan"]["swaps"] == 0

        # Joint serving continues conserved on both sides of the swap.
        for name in ("a", "b"):
            for i in range(4, 8):
                multi.submit(name, CNNRequest(rid=i, image=img(),
                                              t_submit=2.0))
        multi.run_until_done()
        assert all(conserved(e) for e in multi.engines.values())
        assert set(multi.engines["a"].done) == set(range(8))
        assert set(eng_b.done) == set(range(8))

        with pytest.raises(KeyError, match="unknown model"):
            multi.swap_plan("nope", plan_b)
