"""Mesh-sharded compiled plans + multi-chip serving engine (PR 5).

The mesh path changes *placement*, never math: a compiled plan with
``mesh=`` shards the batch dim across the mesh's data axes with params
replicated, so outputs must equal the single-device program bucket for
bucket. Pinned here: that equivalence, the shard-divisible bucket ladder,
stale-slot zeroing across sharded bucket switches, per-chip tuning-record
lookups, and the engine's sharded ``stats()`` accounting.

Multi-device cases need 8 simulated devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — CI's
sharded-smoke job sets it; under plain tier-1 they skip). The 1-device
mesh cases run everywhere, so the sharded code path itself can never rot
unnoticed between sharded-smoke runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cnn.executor import compile_plan, forward, init_params
from repro.cnn.models import vgg16
from repro.core.autotune import Binding, LayerTuning, TuningRecord, record_key
from repro.distributed.sharding import data_shard_count
from repro.launch.mesh import make_data_mesh
from repro.serving.cnn_engine import (CNNRequest, CNNServingEngine,
                                      batch_buckets)

NEED8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

RNG = np.random.default_rng(23)


@pytest.fixture(scope="module")
def tiny():
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    return g, params


def imgs(n):
    return np.asarray(RNG.standard_normal((n, 8, 8, 3)), np.float32)


def submit_n(eng, n, start_rid=0):
    reqs = [CNNRequest(rid=start_rid + i, image=img)
            for i, img in enumerate(imgs(n))]
    for r in reqs:
        eng.submit(r)
    return reqs


# -------------------------------------------------- sharded bucket ladder
def test_sharded_bucket_ladder():
    assert batch_buckets(8, 1) == [1, 2, 4, 8]     # shard=1 = PR-3 ladder
    assert batch_buckets(8, 2) == [2, 4, 8]
    assert batch_buckets(8, 4) == [4, 8]
    assert batch_buckets(8, 8) == [8]
    assert batch_buckets(24, 4) == [4, 8, 16, 24]  # non-pow2 cap = top
    with pytest.raises(ValueError, match="multiple"):
        batch_buckets(6, 4)                        # cap must divide
    with pytest.raises(ValueError, match="shard"):
        batch_buckets(8, 0)


def test_mesh_helpers():
    mesh = make_data_mesh(1)
    assert mesh.axis_names == ("data",)
    assert data_shard_count(mesh) == 1
    with pytest.raises(ValueError, match="n_devices"):
        make_data_mesh(jax.device_count() + 1)


# ------------------------------------------- single-device mesh (runs always)
def test_mesh1_compiled_plan_matches_unsharded(tiny):
    """A 1-device mesh exercises the whole sharded lowering path (jit
    in_shardings, replication, input validation) on plain tier-1 hosts."""
    g, params = tiny
    run_m = compile_plan(g, None, mesh=make_data_mesh(1))
    run_s = compile_plan(g, None)
    assert run_m.data_shards == 1
    x = imgs(4)
    np.testing.assert_allclose(np.asarray(run_m(params, x)),
                               np.asarray(run_s(params, x)),
                               rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="batched"):
        run_m(params, x[0])                        # mesh mode needs (B,…)


def test_mesh1_engine_serves_and_accounts(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=4,
                           mesh=make_data_mesh(1))
    assert eng.buckets == [1, 2, 4]
    reqs = submit_n(eng, 3)
    assert eng.step() == 3
    assert eng.last_tick["per_chip_batch"] == 4
    sh = eng.stats()["sharding"]
    assert sh == {"data_shards": 1, "mesh_devices": 1,
                  "per_chip_batch": {1: 1, 2: 2, 4: 4}}
    for r in reqs:
        want = forward(g, params, jnp.asarray(r.image))
        np.testing.assert_allclose(eng.done[r.rid], np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


# ------------------------------------------------------- 8-device equivalence
@NEED8
@pytest.mark.parametrize("devices", [2, 4, 8])
def test_sharded_outputs_match_single_device_per_bucket(tiny, devices):
    """The §3 invariant extends across placement: every bucket of the
    sharded ladder produces outputs allclose to the SAME lowering compiled
    without a mesh."""
    g, params = tiny
    mesh = make_data_mesh(devices)
    run_s = compile_plan(g, None)
    run_m = compile_plan(g, None, mesh=mesh)
    for bucket in batch_buckets(8, devices):
        x = imgs(bucket)
        np.testing.assert_allclose(np.asarray(run_m(params, x)),
                                   np.asarray(run_s(params, x)),
                                   rtol=1e-4, atol=1e-5)


@NEED8
def test_sharded_batch_divisibility_rejected(tiny):
    g, params = tiny
    run_m = compile_plan(g, None, mesh=make_data_mesh(4))
    assert run_m.data_shards == 4
    with pytest.raises(ValueError, match="data shards"):
        run_m(params, imgs(6))                     # 6 % 4 != 0


@NEED8
def test_sharded_engine_ladder_and_bucket_validation(tiny):
    g, params = tiny
    mesh = make_data_mesh(4)
    eng = CNNServingEngine(g, params, None, batch_size=8, mesh=mesh)
    assert eng.buckets == [4, 8]
    assert eng.data_shards == 4
    with pytest.raises(ValueError, match="data-shard"):
        CNNServingEngine(g, params, None, buckets=(2, 8), mesh=mesh)
    with pytest.raises(ValueError, match="multiple"):
        CNNServingEngine(g, params, None, batch_size=6, mesh=mesh)


@NEED8
def test_sharded_stale_slot_zeroing_across_bucket_switches(tiny):
    """A bucket-8 tick then a padded bucket-4 tick: the smaller sharded
    dispatch must zero the slots the larger one staged — a stale image
    leaking into the padded tail would land on shard 2+ and corrupt
    nothing visible except under sharding, which is exactly why this is
    pinned at 8 devices."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=8,
                           mesh=make_data_mesh(4))
    buf0 = eng._batch_buf
    reqs = submit_n(eng, 8)
    assert eng.step() == 8
    assert eng.last_tick["bucket"] == 8
    reqs += submit_n(eng, 2, start_rid=8)          # pads into bucket 4
    assert eng.step(flush=True) == 2
    assert eng.last_tick["bucket"] == 4
    assert eng.last_tick["per_chip_batch"] == 1
    assert eng._batch_buf is buf0                  # one staging buffer, ever
    np.testing.assert_array_equal(eng._batch_buf[2:], 0)
    for r in reqs:
        want = forward(g, params, jnp.asarray(r.image))
        np.testing.assert_allclose(eng.done[r.rid], np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


@NEED8
def test_sharded_engine_stats_accounting(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=8,
                           mesh=make_data_mesh(2))
    submit_n(eng, 5)
    assert eng.step(flush=True) == 5               # bucket 8 (covers 5)
    s = eng.stats()
    assert s["sharding"] == {"data_shards": 2, "mesh_devices": 2,
                             "per_chip_batch": {2: 1, 4: 2, 8: 4}}
    assert s["dispatches"] == {2: 0, 4: 0, 8: 1}
    assert s["served"] == 5 and s["window"] == 5
    assert set(s["service_ema_s"]) == {8}          # sharded wall time EMA
    for tr in eng.request_log:
        assert tr.bucket == 8


@NEED8
def test_sharded_tuning_lookup_keys_off_per_chip_batch(tiny):
    """With 4 data shards, bucket 4 runs per-chip batch 1 and bucket 8
    per-chip batch 2 — so a record tuned at per-chip buckets {1, 2} must
    bind backend-distinct lowerings, proving single-device tuning records
    transfer to sharded serving unchanged."""
    g, params = tiny
    entries = {}
    for node in g.conv_nodes():
        entries[record_key(node.conv, 1)] = LayerTuning(
            binding=Binding("im2col", "NS", 128, 128, "reference"),
            measured_s=1.0, candidates=[], batch=1)
        entries[record_key(node.conv, 2)] = LayerTuning(
            binding=Binding("im2col", "NS", 128, 128, "lax"),
            measured_s=1.0, candidates=[], batch=2)
    rec = TuningRecord(entries)
    from repro.cnn import overlay
    seen = []
    real = overlay.apply_conv

    def spy(x, w, *a, **kw):
        seen.append(kw.get("backend"))
        return real(x, w, *a, **kw)

    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(overlay, "apply_conv", spy)
        eng = CNNServingEngine(g, params, None, batch_size=8, tuning=rec,
                               mesh=make_data_mesh(4))
        assert eng.buckets == [4, 8]
        reqs = submit_n(eng, 8)
        assert eng.step() == 8                     # traces bucket 8 → b2
        reqs += submit_n(eng, 4, start_rid=8)
        assert eng.step() == 4                     # traces bucket 4 → b1
    n_conv = len(g.conv_nodes())
    assert seen[:n_conv] == ["lax"] * n_conv
    assert seen[n_conv:] == ["reference"] * n_conv
    for r in reqs:
        want = forward(g, params, jnp.asarray(r.image))
        np.testing.assert_allclose(eng.done[r.rid], np.asarray(want),
                                   rtol=2e-2, atol=2e-3)
