"""Layout-aware lowering: store formats are materialized between layers.

The PBQP's third output (after algorithm and dataflow) is the per-edge DRAM
store format; these tests pin that it is now *observable in the executed
program*: matched consumers read the stored format directly (no NHWC round
trip), mismatched split siblings pay a converting load, and — the §3
invariant extended to layouts — none of it changes the computed function.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cnn import overlay
from repro.cnn.executor import compile_plan, forward, init_params
from repro.cnn.models import _concat, _start, googlenet
from repro.core.algorithms import (IM2COL, KN2ROW, Layout, WINO_2_3,
                                   WINO_4_3)
from repro.core.autotune import (Binding, LayerTuning, TuningRecord,
                                 elision_overrides_from_meta, record_key,
                                 tune_elision)
from repro.core.cost_model import Dataflow, TransitionCalibration
from repro.core.dse import identify_parameters
from repro.core.graph import ConvMeta, LayerKind
from repro.core.layouts import (LayoutSpec, consumer_spec, invertible,
                                is_nhwc)
from repro.core.mapper import lower_plan, map_network, transition_report
from repro.kernels.layouts import materialize, restore

RNG = np.random.default_rng(3)


def rnd(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


# ------------------------------------------------------------ conversions
@pytest.mark.parametrize("spec", [
    LayoutSpec("toeplitz", h=11, w=9, c=4, k1=3, k2=3, stride=1),
    LayoutSpec("toeplitz", h=11, w=9, c=4, k1=3, k2=3, stride=2),
    LayoutSpec("toeplitz", h=11, w=9, c=4, k1=1, k2=1, stride=1),
    LayoutSpec("toeplitz", h=12, w=12, c=3, k1=7, k2=7, stride=2),
    LayoutSpec("toeplitz", h=11, w=9, c=4, k1=3, k2=3, stride=1,
               padding="VALID"),
    LayoutSpec("winograd", h=10, w=7, c=3, k1=3, k2=3, m=2, r=3),
    LayoutSpec("winograd", h=10, w=7, c=3, k1=3, k2=3, m=4, r=3),
])
def test_materialize_restore_round_trip_exact(spec):
    """Overlapping positions hold bitwise copies, so the round trip is
    exact — no tolerance — for single images and batches."""
    x = rnd(spec.h, spec.w, spec.c)
    v = materialize(x, spec)
    assert v.ndim == spec.base_rank
    np.testing.assert_array_equal(np.asarray(restore(v, spec)),
                                  np.asarray(x))
    xb = jnp.stack([x, 2 * x, -x])
    vb = materialize(xb, spec)
    assert vb.shape == (3,) + v.shape
    np.testing.assert_array_equal(np.asarray(restore(vb, spec)),
                                  np.asarray(xb))


def test_layout_spec_validation_and_guards():
    with pytest.raises(ValueError, match="layout kind"):
        LayoutSpec("nchw")
    with pytest.raises(ValueError, match="padding"):
        LayoutSpec("toeplitz", h=4, w=4, c=2, k1=3, k2=3, padding="same")
    with pytest.raises(ValueError, match="single-round"):
        LayoutSpec("winograd", h=8, w=8, c=2, k1=5, k2=5, m=2, r=3)
    # Toeplitz drops pixels when windows skip them → not invertible, and
    # consumer_spec refuses to offer it as a store format.
    skip = LayoutSpec("toeplitz", h=9, w=9, c=2, k1=1, k2=1, stride=2)
    assert not invertible(skip)
    conv = ConvMeta(c_in=2, c_out=3, h1=9, h2=9, k1=1, k2=1, stride=2)
    assert consumer_spec(IM2COL, conv) is None
    # kn2row consumes the 3-D tensor as-is; multi-round Winograd cannot
    # consume tiles.
    assert is_nhwc(consumer_spec(KN2ROW, conv))
    conv5 = ConvMeta(c_in=2, c_out=3, h1=9, h2=9, k1=5, k2=5, stride=1)
    assert consumer_spec(WINO_2_3, conv5) is None


# --------------------------------------------------- lower_plan structure
@pytest.fixture(scope="module")
def mapped_googlenet():
    g = googlenet(res=56, scale=0.25)
    hw = identify_parameters(g, max_dim=512)
    plan = map_network(g, hw=hw)
    params = init_params(g, jax.random.PRNGKey(0))
    return g, plan, params


def test_lowered_program_structure(mapped_googlenet):
    g, plan, _ = mapped_googlenet
    low = lower_plan(g, plan)
    # every edge got a transition; the mapping protocol still serves the
    # pre-layout call sites
    assert set(low.transitions) == set(g.edges)
    assert len(low) == len(g.conv_nodes())
    assert all(low[n.id] is low.convs[n.id] for n in g.conv_nodes())
    assert all(lo.epilogue == "relu" for lo in low.values())
    # the PBQP chose store formats for every split producer; the lowering
    # realizes them (store_formats is keyed by producer node)
    for producer, fmt in plan.store_formats.items():
        assert g.out_degree(producer) > 1
        if fmt is not Layout.TENSOR3D:
            assert producer in low.store_specs
            assert low.store_specs[producer].layout is fmt
    # elided edges consume exactly their producer's stored spec
    for (u, v) in low.elided_edges:
        assert low.convs[v].in_layout == low.store_specs[u]
    assert low.elided_edges, "reduced GoogleNet must elide some transitions"
    # the network input never stores a format — it arrives in NHWC
    src = g.source()
    assert src not in low.store_specs
    assert all(u != src for (u, v) in low.elided_edges)


def test_elide_false_is_layout_agnostic(mapped_googlenet):
    g, plan, _ = mapped_googlenet
    low = lower_plan(g, plan, elide=False)
    assert low.elided_edges == []
    assert low.store_specs == {}
    assert all(lo.in_layout is None and lo.out_layout is None
               for lo in low.values())
    assert all(t.reason == "elision disabled"
               for t in low.transitions.values() if not t.elide)


def test_lower_plan_validation_errors(mapped_googlenet):
    g, plan, _ = mapped_googlenet
    with pytest.raises(ValueError, match="epilogue"):
        lower_plan(g, plan, epilogue="gelu")
    with pytest.raises(ValueError, match="backend"):
        lower_plan(g, plan, backend="cuda")
    with pytest.raises(ValueError, match="not an edge"):
        lower_plan(g, plan, elide_overrides={(999, 1000): False})
    with pytest.raises(ValueError, match="must be bool"):
        lower_plan(g, plan, elide_overrides={g.edges[0]: "no"})
    # a tuning record carrying a junk backend fails at lowering, not trace
    node = g.conv_nodes()[0]
    rec = TuningRecord({record_key(node.conv): LayerTuning(
        binding=Binding("im2col", "NS", 128, 128, "cuda"),
        measured_s=0.0, candidates=[])})
    with pytest.raises(ValueError, match="backend"):
        lower_plan(g, None, tuning=rec)


def test_elide_overrides_flip_single_edges(mapped_googlenet):
    g, plan, _ = mapped_googlenet
    low = lower_plan(g, plan)
    edge = low.elided_edges[0]
    low2 = lower_plan(g, plan, elide_overrides={edge: False})
    assert edge not in low2.elided_edges
    assert not low2.transitions[edge].elide
    assert "override" in low2.transitions[edge].reason
    # every other elided edge is untouched
    assert set(low2.elided_edges) == set(low.elided_edges) - {edge}


# ------------------------------------- the (producer, consumer) matrix
ALGOS = [IM2COL, KN2ROW, WINO_2_3, WINO_4_3]


def _two_conv_graph():
    """input → convA (3×3) → convB (3×3) → output: every algorithm family
    applies to both layers."""
    g, cur = _start(12, 4)
    cur = cur.conv(6, 3, 3, name="convA").conv(5, 3, 3, name="convB")
    out = g.add_node(LayerKind.OUTPUT, name="output", out_shape=(12, 12, 5))
    g.add_edge(cur.node, out)
    return g


def _forced_plan(g, assignment):
    plan = map_network(g)
    dfs = list(Dataflow)
    return dataclasses.replace(
        plan,
        assignment={nid: algo for nid, algo in assignment.items()},
        dataflows={nid: dfs[i % 3] for i, nid in enumerate(assignment)})


@pytest.mark.parametrize("dst", ALGOS, ids=lambda a: a.key)
@pytest.mark.parametrize("src", ALGOS, ids=lambda a: a.key)
def test_transition_matrix_equivalence(src, dst):
    """All (producer algorithm, consumer algorithm) pairs: the elided
    compiled plan equals the NHWC-round-trip baseline — layout switching
    is semantically invisible, like algorithm and dataflow switching."""
    g = _two_conv_graph()
    a, b = [n.id for n in g.conv_nodes()]
    plan = _forced_plan(g, {a: src, b: dst})
    params = init_params(g, jax.random.PRNGKey(1))
    xb = rnd(2, 12, 12, 4)
    lowered = lower_plan(g, plan)
    want_spec = consumer_spec(dst, g.nodes[b].conv)
    if not is_nhwc(want_spec):
        # the chain edge must actually elide for non-trivial formats
        assert (a, b) in lowered.elided_edges
        assert lowered[b].in_layout == want_spec
        assert lowered[a].out_layout == want_spec
    got = compile_plan(g, plan)(params, xb)
    base = compile_plan(g, plan, elide=False)(params, xb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("algo", [IM2COL, WINO_2_3], ids=lambda a: a.key)
def test_elided_chain_on_pallas_backend(algo):
    """The matched-layout kernels (Toeplitz GEMM, tile-domain Winograd)
    agree with the baseline on the Pallas path too."""
    g = _two_conv_graph()
    a, b = [n.id for n in g.conv_nodes()]
    plan = _forced_plan(g, {a: algo, b: algo})
    params = init_params(g, jax.random.PRNGKey(2))
    xb = rnd(2, 12, 12, 4)
    got = compile_plan(g, plan, use_pallas=True, interpret=True)(params, xb)
    base = compile_plan(g, plan, elide=False)(params, xb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=5e-3, atol=5e-3)


def test_winograd_chain_stays_in_tile_domain(monkeypatch):
    """Back-to-back 3×3 Winograd convs: the producer stores the consumer's
    scattered tile layout and the consumer reads it directly — the edge
    never round-trips through NHWC."""
    g = _two_conv_graph()
    a, b = [n.id for n in g.conv_nodes()]
    plan = _forced_plan(g, {a: WINO_2_3, b: WINO_2_3})
    params = init_params(g, jax.random.PRNGKey(3))
    seen = []
    real = overlay.apply_conv

    def spy(x, w, algo, *args, **kw):
        seen.append((x.ndim, kw.get("in_layout"), kw.get("out_layout")))
        return real(x, w, algo, *args, **kw)

    monkeypatch.setattr(overlay, "apply_conv", spy)
    run = compile_plan(g, plan)
    y = run(params, rnd(12, 12, 4))
    (nd_a, in_a, out_a), (nd_b, in_b, out_b) = seen
    # the network input arrives NHWC (INPUT edges never store a format);
    # convA stores convB's tiles, so the inter-layer edge lives in the
    # scattered domain.
    assert in_a is None and nd_a == 3
    assert out_a is not None and out_a.kind == "winograd" and out_a.c == 6
    assert out_a.m == 2 and out_a.r == 3
    assert in_b == out_a and out_b is None
    assert nd_b == 4            # convB received tiles, not an NHWC map
    # the eager path shares the lowering (and therefore the layouts)
    x = rnd(12, 12, 4)
    np.testing.assert_allclose(
        np.asarray(forward(g, params, x, plan=plan)),
        np.asarray(run(params, x)), rtol=1e-4, atol=1e-5)
    assert y.ndim == 3


# ------------------------------------------------------- split fan-out
def _split_graph():
    """conv0 fans out to two matched im2col 1×1 convs, one kn2row 1×1 conv
    and a pool — the store-format split vertex case."""
    g, cur = _start(12, 4)
    c0 = cur.conv(6, 3, 3, name="conv0")
    b1 = c0.conv(5, 1, 1, name="b1")
    b2 = c0.conv(7, 1, 1, name="b2")
    b3 = c0.conv(4, 1, 1, name="b3")
    b4 = c0.pool(3, 1, name="pool")
    cat = _concat(g, [b1, b2, b3, b4], "cat")
    out = g.add_node(LayerKind.OUTPUT, name="output",
                     out_shape=(12, 12, 5 + 7 + 4 + 6))
    g.add_edge(cat.node, out)
    ids = {n.name: n.id for n in g.nodes.values()}
    return g, ids


def test_split_fanout_materializes_store_format_once(monkeypatch):
    g, ids = _split_graph()
    plan = _forced_plan(g, {ids["conv0"]: IM2COL, ids["b1"]: IM2COL,
                            ids["b2"]: IM2COL, ids["b3"]: KN2ROW})
    plan = dataclasses.replace(
        plan, store_formats={ids["conv0"]: Layout.TOEPLITZ})
    lowered = lower_plan(g, plan)
    c0 = ids["conv0"]
    store = lowered.store_specs[c0]
    assert store.kind == "toeplitz" and store.k1 == 1
    # matched consumers elide; the kn2row conv and the pool pay the
    # converting load from the stored Toeplitz matrix
    assert lowered.transitions[(c0, ids["b1"])].elide
    assert lowered.transitions[(c0, ids["b2"])].elide
    t3 = lowered.transitions[(c0, ids["b3"])]
    tp = lowered.transitions[(c0, ids["pool"])]
    assert not t3.elide and t3.layout == store
    assert not tp.elide and tp.layout == store

    params = init_params(g, jax.random.PRNGKey(4))
    xb = rnd(3, 12, 12, 4)
    seen = []
    real = overlay.apply_conv

    def spy(x, w, algo, *args, **kw):
        seen.append((x.ndim, kw.get("in_layout"), kw.get("out_layout")))
        return real(x, w, algo, *args, **kw)

    monkeypatch.setattr(overlay, "apply_conv", spy)
    got = compile_plan(g, plan)(params, xb)
    base = compile_plan(g, plan, elide=False)(params, xb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-5)
    # trace order is topological: conv0, b1, b2, b3
    assert seen[0][2] == store                     # conv0 stores Toeplitz
    assert seen[1][1] == store and seen[2][1] == store
    assert seen[1][0] == 3                         # batched Toeplitz rank
    assert seen[3][1] is None                      # kn2row restored NHWC


def test_split_tensor3d_store_keeps_nhwc():
    """When the PBQP picks the 3-D tensor store at a split, nothing is
    materialized: kn2row/pool consumers match trivially, im2col consumers
    keep the round trip (and say why)."""
    g, ids = _split_graph()
    plan = _forced_plan(g, {ids["conv0"]: IM2COL, ids["b1"]: IM2COL,
                            ids["b2"]: IM2COL, ids["b3"]: KN2ROW})
    plan = dataclasses.replace(
        plan, store_formats={ids["conv0"]: Layout.TENSOR3D})
    lowered = lower_plan(g, plan)
    c0 = ids["conv0"]
    assert c0 not in lowered.store_specs
    assert lowered.transitions[(c0, ids["b3"])].elide     # matched 3-D
    assert lowered.transitions[(c0, ids["pool"])].elide
    t1 = lowered.transitions[(c0, ids["b1"])]
    assert not t1.elide and "NHWC" in t1.reason


# --------------------------------------------- report + measured loop
def test_transition_report_and_calibration(mapped_googlenet):
    g, plan, _ = mapped_googlenet
    lowered = lower_plan(g, plan)
    rep = transition_report(g, lowered)
    conv_ids = {n.id for n in g.conv_nodes()}
    want = [(u, v) for (u, v) in lowered.elided_edges if v in conv_ids]
    assert rep["n_elided"] == len(want) > 0
    assert rep["predicted_saving_s"] > 0
    assert rep["predicted_roundtrip_s"] > rep["predicted_elided_s"]
    # the measured-calibration hook scales every transition pair
    cal = TransitionCalibration(default=2.0)
    rep2 = transition_report(g, lowered, calibration=cal)
    np.testing.assert_allclose(rep2["predicted_saving_s"],
                               2.0 * rep["predicted_saving_s"], rtol=1e-9)


def test_tune_elision_returns_overrides():
    g = _two_conv_graph()
    rec = TuningRecord()
    overrides = tune_elision(g, None, reps=1, record=rec)
    lowered = lower_plan(g, None)
    assert set(overrides) <= set(lowered.elided_edges)
    assert all(v is False for v in overrides.values())
    assert elision_overrides_from_meta(rec) == overrides
    # overrides feed straight back into lowering
    lowered2 = lower_plan(g, None, elide_overrides=overrides)
    assert set(lowered2.elided_edges) == \
        set(lowered.elided_edges) - set(overrides)
