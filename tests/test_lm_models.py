"""LM stack: per-arch smoke tests + decode↔prefill consistency + SSD oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models.attention import chunked_attention
from repro.models.model import (decode_step, forward, init_cache, init_model,
                                logits_from_hidden, loss_fn)
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32):
    n_front = cfg.frontend_tokens if cfg.frontend != "none" else 0
    batch = {"tokens": jax.random.randint(KEY, (b, s - n_front), 0,
                                          cfg.vocab)}
    if n_front:
        batch["frontend_embeds"] = jax.random.normal(
            KEY, (b, n_front, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(name):
    """Assignment requirement: reduced config, one forward/train step on
    CPU, output shapes + no NaNs."""
    cfg = get_config(name, reduced=True)
    params = init_model(cfg, KEY)
    batch = make_batch(cfg)
    hidden, aux = forward(params, batch["tokens"], cfg,
                          batch.get("frontend_embeds"))
    assert hidden.shape == (2, 32, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()
    loss, metrics = loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_decode_step_runs(name):
    cfg = get_config(name, reduced=True)
    params = init_model(cfg, KEY)
    cache = init_cache(cfg, batch=2, max_len=64)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = decode_step(params, tok, cache, jnp.int32(0), cfg)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ["qwen2.5-14b", "mamba2-370m",
                                  "h2o-danube-1.8b", "deepseek-v2-236b",
                                  "zamba2-2.7b"])
def test_decode_matches_forward(name):
    """Token-by-token decode reproduces the teacher-forced forward logits
    (the core serving-correctness invariant, incl. MLA absorbed decode and
    mamba recurrent decode)."""
    import dataclasses
    # float32 so the equivalence check isn't swamped by bf16 noise; for MoE
    # archs raise capacity so no tokens drop (capacity-drop populations
    # necessarily differ between teacher-forced prefill and decode).
    cfg = dataclasses.replace(get_config(name, reduced=True),
                              dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    if cfg.frontend != "none":
        pytest.skip("frontend archs prepend embeddings")
    params = init_model(cfg, KEY)
    b, s = 2, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    hidden, _ = forward(params, tokens, cfg)
    ref_logits = logits_from_hidden(params, cfg, hidden)  # (b, s, V)

    cache = init_cache(cfg, batch=b, max_len=32)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, tokens[:, t:t + 1], cache,
                                jnp.int32(t), cfg)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_masks_distant_tokens():
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    assert cfg.sliding_window == 64
    q = jax.random.normal(KEY, (1, 8, 2, 16))
    k = jax.random.normal(KEY, (1, 8, 2, 16))
    v = jax.random.normal(KEY, (1, 8, 2, 16))
    full = chunked_attention(q, k, v, window=0, chunk=4)
    win = chunked_attention(q, k, v, window=2, chunk=4)
    # with window 2, position 7 ignores keys 0..5 → must differ from full
    assert not np.allclose(np.asarray(full[0, 7]), np.asarray(win[0, 7]),
                           atol=1e-4)
    # position 0/1 see the same context in both
    np.testing.assert_allclose(np.asarray(full[0, 0]),
                               np.asarray(win[0, 0]), rtol=1e-4, atol=1e-5)


def test_chunked_attention_matches_full_softmax():
    b, s, h, d = 2, 33, 4, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, 2, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, 2, d))
    out = chunked_attention(q, k, v, chunk=8)
    # reference: dense causal softmax with GQA repeat
    kr = jnp.repeat(k, 2, axis=2)
    vr = jnp.repeat(v, 2, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(d)
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None], sc, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunked_matches_naive_recurrence():
    """SSD (Eq. duality) vs the literal h_t = exp(dtA)h + dt·B x recurrence."""
    rng = np.random.default_rng(3)
    b, l, h, p, n = 2, 24, 3, 4, 8
    xbar = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32) * 0.5
    dta = -jnp.asarray(rng.random((b, l, h)), jnp.float32) * 0.5
    b_in = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32) * 0.5
    c_in = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32) * 0.5
    got = ssd_chunked(xbar, dta, b_in, c_in, chunk=8)

    state = np.zeros((b, h, p, n), np.float32)
    want = np.zeros((b, l, h, p), np.float32)
    for t in range(l):
        da = np.exp(np.asarray(dta[:, t]))                  # (b, h)
        state = state * da[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", np.asarray(xbar[:, t]), np.asarray(b_in[:, t]))
        want[:, t] = np.einsum("bhpn,bn->bhp", state, np.asarray(c_in[:, t]))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_moe_aux_losses_and_capacity():
    from repro.models.moe import init_moe, moe_ffn
    cfg = get_config("deepseek-v2-236b", reduced=True)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.bfloat16)
    y, aux = moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux["load_balance"]) >= 1.0 - 1e-3   # ≥ 1 by Cauchy-Schwarz
    assert np.isfinite(float(aux["router_z"]))
