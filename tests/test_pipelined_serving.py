"""Pipelined serving tier (async tick dispatch, PR 6).

``CNNServingEngine(pipeline_depth=d)`` launches up to ``d`` ticks before
blocking on any of them: ``step()`` dispatches and returns, an in-flight
queue tracks the launched device work, and completion happens lazily at
the next ``step()``/``drain()``/``poll()``. Pinned here: depth-1
reproduces the synchronous engine exactly (no in-flight state ever),
async outputs are bitwise identical to synchronous ones, out-of-order
``poll()`` preserves the request→result mapping, RequestTrace timestamps
stay monotonic (submit <= dispatch <= done, done nondecreasing across
ticks), stale slots are zeroed per rotating staging buffer, and the
``stats()["pipeline"]`` block reports depth / in-flight / overlap.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.cnn.executor import forward, init_params
from repro.cnn.models import vgg16
from repro.serving.cnn_engine import CNNRequest, CNNServingEngine

RNG = np.random.default_rng(23)


class FakeClock:
    """Deterministic injectable time source (engine clock only — the
    pipeline's readiness bookkeeping runs on perf_counter regardless)."""

    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t


@pytest.fixture(scope="module")
def tiny():
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    return g, params


def img():
    return np.asarray(RNG.standard_normal((8, 8, 3)), np.float32)


def submit_n(eng, n, start_rid=0, imgs=None):
    reqs = [CNNRequest(rid=start_rid + i,
                       image=imgs[i] if imgs is not None else img())
            for i in range(n)]
    for r in reqs:
        eng.submit(r)
    return reqs


# ------------------------------------------------------------ validation


def test_depth_validation(tiny):
    g, params = tiny
    with pytest.raises(ValueError, match="pipeline_depth"):
        CNNServingEngine(g, params, None, batch_size=2, pipeline_depth=0)


def test_depth1_is_synchronous(tiny):
    """Depth 1 must reproduce today's engine: every step completes its
    tick inline — results land in ``done`` before step() returns and no
    in-flight state ever exists."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=2)
    assert eng.pipeline_depth == 1
    submit_n(eng, 2)
    assert eng.step(now=0.0) == 2
    assert len(eng._inflight) == 0
    assert set(eng.done) == {0, 1}
    assert eng.stats()["pipeline"]["inflight"] == 0


# ------------------------------------------------------------ async results


def test_async_outputs_match_reference_and_sync(tiny):
    """The pipelined engine's results are bitwise identical to the
    synchronous engine's (same executables, same padded staging), and
    both match the eager forward reference."""
    g, params = tiny
    n = 10
    imgs = [img() for _ in range(n)]
    outs = {}
    for depth in (1, 3):
        eng = CNNServingEngine(g, params, None, batch_size=4,
                               pipeline_depth=depth)
        submit_n(eng, n, imgs=imgs)
        done = eng.run_until_done()
        assert set(done) == set(range(n))
        outs[depth] = {r: np.asarray(v) for r, v in done.items()}
    for r in range(n):
        assert np.array_equal(outs[1][r], outs[3][r])
        want = np.asarray(forward(g, params, jnp.asarray(imgs[r])))
        assert np.allclose(outs[3][r], want, rtol=2e-2, atol=2e-3)


def test_step_returns_before_completion_then_drain(tiny):
    """At depth >= 2 a dispatched tick is NOT in ``done`` right after
    step() — it sits in flight until drain() retires it."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, buckets=(2,),
                           pipeline_depth=2, warmup=True)
    submit_n(eng, 2)
    assert eng.step(now=0.0, flush=True) == 2
    assert len(eng._inflight) == 1
    assert 0 not in eng.done            # launched, not yet retired
    done = eng.drain()
    assert len(eng._inflight) == 0
    assert set(done) == {0, 1}


def test_pipeline_depth_bounds_inflight(tiny):
    """The dispatch loop force-completes the oldest tick rather than
    exceed ``pipeline_depth`` launched-but-unretired ticks (each pins a
    staging buffer)."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, buckets=(1,),
                           pipeline_depth=2, warmup=True)
    submit_n(eng, 5)
    for i in range(5):
        assert eng.step(now=0.0, flush=True) == 1
        assert len(eng._inflight) <= 2
    eng.drain()
    assert set(eng.done) == set(range(5))


def test_poll_out_of_order_preserves_mapping(tiny):
    """poll() on a request in a LATER tick retires everything up to and
    including its tick; each rid still gets its own image's logits. An
    injected device delay holds the ticks in flight (on the tiny graph
    they would otherwise be ready — and lazily reaped — by the next
    step())."""
    g, params = tiny
    n = 6
    imgs = [img() for _ in range(n)]
    eng = CNNServingEngine(g, params, None, buckets=(2,),
                           pipeline_depth=3, device_delay_s=0.2,
                           warmup=True)
    submit_n(eng, n, imgs=imgs)
    for _ in range(3):                  # three bucket-2 ticks in flight
        eng.step(now=0.0, flush=True)
    assert len(eng._inflight) == 3
    out5 = eng.poll(5)                  # newest tick → retires all three
    assert out5 is not None and len(eng._inflight) == 0
    assert set(eng.done) == set(range(n))
    for r in range(n):
        want = np.asarray(forward(g, params, jnp.asarray(imgs[r])))
        assert np.allclose(np.asarray(eng.done[r]), want,
                           rtol=2e-2, atol=2e-3)
    assert eng.poll(99) is None


# ------------------------------------------------------------ timestamps


def test_trace_timestamps_monotonic(tiny):
    """submit <= dispatch <= done per request, and completion times are
    nondecreasing in dispatch order even when several ticks were in
    flight simultaneously (the serial-device completion model)."""
    g, params = tiny
    clock = FakeClock()
    eng = CNNServingEngine(g, params, None, buckets=(2,),
                           pipeline_depth=4, clock=clock, warmup=True)
    for i in range(8):
        clock.t = 0.1 * i
        eng.submit(CNNRequest(rid=i, image=img()))
    clock.t = 1.0
    while eng.queue:
        eng.step(flush=True)
    eng.drain()
    assert len(eng.request_log) == 8
    for tr in eng.request_log:
        assert tr.t_submit <= tr.t_dispatch <= tr.t_done
        assert tr.queue_s >= 0.0 and tr.service_s > 0.0
        assert tr.latency_s == pytest.approx(tr.t_done - tr.t_submit)
    dones = [tr.t_done for tr in eng.request_log]
    assert dones == sorted(dones)


# ------------------------------------------------------------ staging


def test_rotating_buffers_and_stale_slot_zeroing(tiny):
    """Each in-flight tick pins its own staging buffer; a buffer reused
    for a smaller batch has its stale tail zeroed, so padded lanes never
    leak a previous tick's images."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=4,
                           pipeline_depth=2, warmup=True)
    assert len(eng._batch_bufs) == 2
    assert eng._batch_buf is eng._batch_bufs[0]   # compat alias
    imgs = [img() for _ in range(8)]
    submit_n(eng, 8, imgs=imgs)
    eng.step(now=0.0, flush=True)       # bucket 4 → buffer 0 full
    eng.step(now=0.0, flush=True)       # bucket 4 → buffer 1 full
    eng.drain()
    # Both buffers now hold 4 stale images each. A 1-request tick reuses
    # the next buffer in rotation and must zero lanes [1:4].
    eng.submit(CNNRequest(rid=8, image=imgs[0]))
    eng.step(now=0.0, flush=True)
    eng.drain()
    used = eng._batch_bufs[eng._last_buf_index]
    assert np.array_equal(used[0], imgs[0])
    assert not used[1:4].any()
    # the OTHER buffer still holds its stale (nonzero) images untouched
    other = eng._batch_bufs[1 - eng._last_buf_index]
    assert other[1:4].any()


# ------------------------------------------------------------ stats


def test_pipeline_stats_block(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, buckets=(2,),
                           pipeline_depth=2, warmup=True)
    p0 = eng.stats()["pipeline"]
    assert p0["depth"] == 2
    assert p0["inflight"] == p0["dispatched_ticks"] == 0
    assert p0["overlap_ratio"] == 0.0
    submit_n(eng, 4)
    eng.step(now=0.0, flush=True)
    assert eng.stats()["pipeline"]["inflight"] == 1
    eng.step(now=0.0, flush=True)
    eng.drain()
    p = eng.stats()["pipeline"]
    assert p["inflight"] == 0
    assert p["dispatched_ticks"] == p["completed_ticks"] == 2
    assert p["device_busy_s"] > 0.0
    assert 0.0 <= p["overlap_ratio"] <= 1.0
    # reset clears pipeline accounting along with request accounting
    eng.reset()
    p2 = eng.stats()["pipeline"]
    assert p2["dispatched_ticks"] == p2["completed_ticks"] == 0
    assert p2["device_busy_s"] == 0.0


def test_reset_with_inflight_drains_first(tiny):
    """reset() on an engine with launched ticks retires them (device
    work is not abandoned mid-flight) before clearing accounting."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, buckets=(2,),
                           pipeline_depth=2, warmup=True)
    submit_n(eng, 2)
    eng.step(now=0.0, flush=True)
    assert len(eng._inflight) == 1
    eng.reset()
    assert len(eng._inflight) == 0
    assert eng.stats()["submitted"] == 0 and not eng.done


def test_warmup_primes_emas_at_depth2(tiny):
    """Warmup runs synchronously (block_until_ready) regardless of
    depth, so service EMAs are primed before the first real tick."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=2,
                           pipeline_depth=2, warmup=True)
    emas = eng.stats()["service_ema_s"]
    assert set(emas) == {1, 2}
    assert all(v > 0.0 for v in emas.values())


def test_device_delay_inflates_service_ema(tiny):
    """The injected device delay (slow-accelerator emulation) shows up
    in the measured per-tick service time — the EMA tracks device
    completion, not host dispatch."""
    g, params = tiny
    delay = 0.05
    fast = CNNServingEngine(g, params, None, buckets=(1,), warmup=True)
    slow = CNNServingEngine(g, params, None, buckets=(1,),
                            device_delay_s=delay, warmup=True)
    submit_n(fast, 1)
    submit_n(slow, 1)
    fast.step(now=0.0, flush=True)
    slow.step(now=0.0, flush=True)
    # Warmup measures the raw device wall (no injected delay), so after
    # one real tick the EMA blends one delayed sample: >= 0.4x the delay.
    assert (slow.stats()["service_ema_s"][1]
            >= fast.stats()["service_ema_s"][1] + 0.4 * delay)
