"""Sharding rules + single-device mesh integration (the 512-device path is
exercised by launch.dryrun; here we verify rule correctness and that the
sharded step functions run on the smoke mesh)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.api import (activation_policy, policy_from_mesh)
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        param_spec, params_shardings)
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import (input_specs, make_opt_config, model_shapes,
                                opt_shapes, serve_step, train_step)
from repro.configs.base import SHAPES
from repro.models.model import init_cache, init_model
from repro.optim.adamw import init_opt_state


def fake_mesh_16x16() -> Mesh:
    """Axis-shape bookkeeping only — never touches devices (we build the
    mesh from a reshaped view of the single CPU device repeated? No: we use
    an abstract mesh substitute)."""
    # AbstractMesh carries axis names/sizes without devices. Its signature
    # in jax 0.4.37 takes ((name, size), ...) pairs.
    from jax.sharding import AbstractMesh
    return AbstractMesh((("data", 16), ("model", 16)))


def test_param_spec_rules():
    mesh = fake_mesh_16x16()
    # embedding: vocab divisible → (model, data)
    assert param_spec("embed/table", (32000, 2560), mesh) == \
        P("model", "data")
    # odd vocab → fall back to d on model
    assert param_spec("embed/table", (50280, 1024), mesh) == \
        P(None, "model")
    # generic projection: out on model, in on data
    assert param_spec("layers/attn/wq/w", (48, 5120, 5120), mesh) == \
        P(None, "data", "model")
    # expert-stacked: E on model, d on data
    assert param_spec("layers/moe/w_gate", (8, 160, 64, 128), mesh) == \
        P(None, "model", "data", None)
    # small norm scale stays replicated
    assert param_spec("ln_f/scale", (64,), mesh) == P(None)


def test_param_spec_divisibility_fallback():
    mesh = fake_mesh_16x16()
    # out dim 33 not divisible by 16 → TP lands on the in dim instead
    spec = param_spec("x/w", (64, 33), mesh)
    assert spec == P("model", None) or spec == P("data", None) \
        or spec[-1] is None


def test_batch_and_cache_shardings_divisibility():
    mesh = fake_mesh_16x16()
    cfg = get_config("qwen2.5-14b")
    specs = input_specs(cfg, SHAPES["decode_32k"])
    tok_sh = batch_shardings({"tokens": specs["tokens"]}, mesh)["tokens"]
    assert tok_sh.spec[0] in ("data", ("data",))  # 128 % 16 == 0
    c_sh = cache_shardings(specs["cache"], mesh)
    leaves = jax.tree.leaves(c_sh)
    assert any(s.spec != P() for s in leaves)     # something is sharded
    # long_500k: batch 1 → batch unsharded everywhere
    cfg2 = get_config("mamba2-370m")
    specs2 = input_specs(cfg2, SHAPES["long_500k"])
    tok2 = batch_shardings({"tokens": specs2["tokens"]}, mesh)["tokens"]
    assert tok2.spec == P(None, None) or tok2.spec == P()


def test_train_and_serve_steps_run_on_smoke_mesh():
    mesh = make_smoke_mesh()
    cfg = get_config("zamba2-2.7b", reduced=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    opt_cfg = make_opt_config(cfg)
    opt_state = init_opt_state(params, opt_cfg)
    p_sh = params_shardings(params, mesh)
    params = jax.device_put(params, p_sh)
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32)}
    with mesh, activation_policy(policy_from_mesh(mesh)):
        step = jax.jit(functools.partial(train_step, cfg=cfg,
                                         opt_cfg=opt_cfg, microbatches=2))
        params2, opt2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))

    cache = init_cache(cfg, 2, 16)
    with mesh:
        logits, cache2 = jax.jit(
            functools.partial(serve_step, cfg=cfg))(
                params2, jnp.zeros((2, 1), jnp.int32), cache,
                jnp.int32(0))
    assert np.isfinite(np.asarray(logits)).all()


def test_input_specs_cover_all_cells():
    from repro.configs import ARCH_NAMES, shapes_for
    for name in ARCH_NAMES:
        cfg = get_config(name)
        for shp in shapes_for(cfg):
            specs = input_specs(cfg, shp)
            leaves = jax.tree.leaves(specs)
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
            if shp.kind != "decode":
                total = specs["tokens"].shape[1] + (
                    cfg.frontend_tokens if cfg.frontend != "none" else 0)
                assert total == shp.seq_len
