"""Plan hot-swap equivalence suite (PR 10) — the gate on the closed
self-optimization loop.

Pins the three tentpole pieces end to end:

* **Calibrated re-pricing** — ``map_network(calibration=)`` /
  ``replan``: a measured ``TransitionCalibration`` provably flips the
  PBQP winner, re-solves are deterministic, sub-hysteresis perturbations
  never churn the deployed plan, and the single-channel calibration
  plumbing (``lower_plan`` → ``LoweredProgram.calibration`` →
  ``transition_report``) prices identically to the deprecated direct
  kwarg.
* **Atomic hot-swap** — ``CNNServingEngine.swap_plan``: outputs are
  bitwise identical across the swap boundary for requests completed
  before/during/after the swap (including in-flight ticks at
  ``pipeline_depth=2`` retiring against the old ladder, fault replays
  included), the conserved outcome ledger survives swap × ``FaultPlan``,
  and partial ladders are rejected.
* **The supervisor loop** — ``serving.supervisor.PlanSupervisor``: a
  deterministic end-to-end run where an injected service-time shift
  flips the deployed plan exactly once (and legitimately holds the new
  plan inside hysteresis after recovery), plus probation rollback
  exercised under fault injection (failed ticks never count as
  regression samples).

Timing-sensitive tests ride a ``device_delay_s`` floor that dominates
real kernel wall-time jitter, so every decision the loop makes is
reproducible on a noisy host.
"""
import numpy as np
import pytest

import jax

from repro.cnn.executor import ExecutableCache, init_params
from repro.cnn.models import vgg16
from repro.core.cost_model import TransitionCalibration
from repro.core.dse import identify_parameters
from repro.core.mapper import (lower_plan, map_network, plan_fingerprint,
                               replan, transition_report)
from repro.distributed.fault import FaultPlan, TickFault
from repro.serving.cnn_engine import (OUTCOME_FAILED, CNNRequest,
                                      CNNServingEngine)
from repro.serving.supervisor import (COMPILING, MONITOR, PROBATION,
                                      PlanSupervisor)

RNG = np.random.default_rng(21)
N_IMAGES = 64
IMAGES = [np.asarray(RNG.standard_normal((8, 8, 3)), np.float32)
          for _ in range(N_IMAGES)]


class FakeClock:
    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t


@pytest.fixture(scope="module")
def tiny():
    g = vgg16(res=8, scale=0.05)
    hw = identify_parameters(g)
    params = init_params(g, jax.random.PRNGKey(0))
    return g, hw, params


@pytest.fixture(scope="module")
def plans(tiny):
    """Plan A: the uncalibrated PBQP winner. Plan B: the winner when
    every transition is measured 6x more expensive than modeled (the
    DDR-contention regime) — a genuinely different assignment."""
    g, hw, _ = tiny
    pa = map_network(g, hw=hw, use_on_chip=False)
    pb = map_network(g, hw=hw, use_on_chip=False,
                     calibration=TransitionCalibration(default=6.0))
    assert plan_fingerprint(pa) != plan_fingerprint(pb)
    return pa, pb


@pytest.fixture(scope="module")
def cache():
    return ExecutableCache()


def conserved(eng) -> bool:
    rb = eng.stats()["robustness"]
    return (sum(rb["outcomes"].values()) + rb["pending"]
            == eng.submitted_total)


def submit_batch(eng, clock, start_rid, n=4):
    """Submit n requests with fresh rids; images cycle through the fixed
    pool, so any two engines fed the same rid range see the same bits."""
    for i in range(n):
        rid = start_rid + i
        eng.submit(CNNRequest(rid=rid, image=IMAGES[rid % N_IMAGES],
                              t_submit=clock.t))
    return start_rid + n


def reference_outputs(tiny, plan, cache, n, **engine_kwargs):
    """Serve IMAGES[:n] to completion on a single fixed plan."""
    g, _, params = tiny
    clock = FakeClock()
    eng = CNNServingEngine(g, params, plan, batch_size=4, clock=clock,
                           cache=cache, **engine_kwargs)
    rid = 0
    while rid < n:
        rid = submit_batch(eng, clock, rid)
        eng.step(flush=True)
        clock.t += 1.0
    eng.run_until_done()
    assert set(eng.done) == set(range(n))
    return dict(eng.done)


# ---------------------------------------------------------------------------
# Calibrated re-pricing (replan) semantics.
# ---------------------------------------------------------------------------

class TestCalibratedReplan:
    def test_uncalibrated_replan_is_a_fixed_point(self, tiny, plans):
        g, hw, _ = tiny
        pa, _ = plans
        r = replan(g, pa, calibration=None, hw=hw, use_on_chip=False)
        assert not r.changed and not r.adopted
        assert plan_fingerprint(r.plan) == plan_fingerprint(pa)
        assert r.candidate_cost_s == pytest.approx(r.deployed_cost_s)

    def test_measured_shift_flips_and_clears_hysteresis(self, tiny, plans):
        g, hw, _ = tiny
        pa, pb = plans
        r = replan(g, pa, calibration=TransitionCalibration(default=6.0),
                   hw=hw, use_on_chip=False)
        assert r.changed and r.adopted
        assert plan_fingerprint(r.plan) == plan_fingerprint(pb)
        # Both costs priced on the SAME calibrated graph; adoption means
        # the candidate cleared the 5% gate on it.
        assert r.candidate_cost_s < r.deployed_cost_s * 0.95

    def test_reverting_inside_hysteresis_is_held(self, tiny, plans):
        """After recovery (calibration back to 1.0) plan A prices
        cheaper than deployed B — but by less than the 5% gate, so the
        supervisor legitimately keeps B. Pins the margin so a cost-model
        change that breaks this invariant is caught here, not as a
        mystery plan-flap in serving."""
        g, hw, _ = tiny
        pa, pb = plans
        r = replan(g, pb, calibration=None, hw=hw, use_on_chip=False)
        assert r.changed and not r.adopted
        margin = 1.0 - r.candidate_cost_s / r.deployed_cost_s
        assert 0.0 < margin < 0.05

    def test_resolve_is_deterministic(self, tiny):
        g, hw, _ = tiny
        cal = TransitionCalibration(default=3.7)
        fps = {plan_fingerprint(map_network(g, hw=hw, use_on_chip=False,
                                            calibration=cal))
               for _ in range(3)}
        assert len(fps) == 1

    def test_sub_hysteresis_perturbation_never_churns(self, tiny):
        """Seeded version of the hypothesis property (which skips when
        hypothesis is absent): per-pair scale noise within 1±2% — under
        half the 5% hysteresis, so the deployed/candidate cost ratio
        moves by less than the gate — never triggers adoption."""
        from repro.core.algorithms import Layout
        g, hw, _ = tiny
        base = TransitionCalibration(default=2.0)
        deployed = map_network(g, hw=hw, use_on_chip=False,
                               calibration=base)
        rng = np.random.default_rng(99)
        pairs = [(a, b) for a in Layout for b in Layout]
        for _ in range(20):
            noisy = TransitionCalibration(
                scales={p: 2.0 * (1.0 + rng.uniform(-0.02, 0.02))
                        for p in pairs},
                default=2.0)
            r = replan(g, deployed, calibration=noisy,
                       hw=hw, use_on_chip=False)
            assert not r.adopted


class TestCalibrationSingleChannel:
    """Satellite: one ``calibration=`` kwarg through
    ``map_network``/``lower_plan``; the old ``transition_report``
    side-channel is deprecated but prices identically."""

    def test_lowered_program_carries_calibration(self, tiny, plans):
        g, _, _ = tiny
        pa, _ = plans
        cal = TransitionCalibration(default=3.0)
        low = lower_plan(g, pa, calibration=cal)
        assert low.calibration is cal
        assert lower_plan(g, pa).calibration is None

    def test_both_routes_price_identically(self, tiny, plans):
        g, _, _ = tiny
        pa, _ = plans
        cal = TransitionCalibration(default=3.0)
        rep_new = transition_report(g, lower_plan(g, pa, calibration=cal))
        with pytest.warns(DeprecationWarning, match="deprecated"):
            rep_old = transition_report(g, lower_plan(g, pa),
                                        calibration=cal)
        assert rep_new["predicted_roundtrip_s"] == \
            rep_old["predicted_roundtrip_s"]
        assert rep_new["predicted_elided_s"] == rep_old["predicted_elided_s"]
        assert [e["saving_s"] for e in rep_new["edges"]] == \
            [e["saving_s"] for e in rep_old["edges"]]
        # Non-vacuous: the calibration actually moved the prices.
        rep_uncal = transition_report(g, lower_plan(g, pa))
        assert rep_uncal["predicted_roundtrip_s"] != \
            rep_new["predicted_roundtrip_s"]

    def test_explicit_kwarg_wins_over_carried(self, tiny, plans):
        g, _, _ = tiny
        pa, _ = plans
        low = lower_plan(g, pa,
                         calibration=TransitionCalibration(default=3.0))
        with pytest.warns(DeprecationWarning):
            rep = transition_report(
                g, low, calibration=TransitionCalibration(default=1.0))
        rep_uncal = transition_report(g, lower_plan(g, pa))
        assert rep["predicted_roundtrip_s"] == \
            rep_uncal["predicted_roundtrip_s"]


# ---------------------------------------------------------------------------
# Atomic hot-swap: bitwise equivalence across the boundary.
# ---------------------------------------------------------------------------

class TestSwapBitwise:
    def test_outputs_bitwise_across_swap_boundary(self, tiny, plans, cache):
        g, _, params = tiny
        pa, pb = plans
        ref_a = reference_outputs(tiny, pa, cache, 24)
        ref_b = reference_outputs(tiny, pb, cache, 24)
        # The two plans must disagree somewhere or the test is vacuous
        # (bitwise equality would hold trivially).
        assert any(not np.array_equal(ref_a[r], ref_b[r])
                   for r in range(24))

        clock = FakeClock()
        eng = CNNServingEngine(g, params, pa, batch_size=4, clock=clock,
                               cache=cache)
        rid = 0
        for _ in range(3):                       # ticks 0-2 on plan A
            rid = submit_batch(eng, clock, rid)
            eng.step(flush=True)
            clock.t += 1.0
        pre_swap = set(eng.done)
        assert pre_swap == set(range(12))
        eng.swap_plan(pb)                        # between ticks
        for _ in range(3):                       # ticks 3-5 on plan B
            rid = submit_batch(eng, clock, rid)
            eng.step(flush=True)
            clock.t += 1.0
        eng.run_until_done()

        for r in sorted(pre_swap):
            assert np.array_equal(eng.done[r], ref_a[r])
        for r in range(12, 24):
            assert np.array_equal(eng.done[r], ref_b[r])
        assert conserved(eng)
        assert eng.stats()["plan"] == {"swaps": 1, "rollbacks": 0}

    def test_inflight_ticks_retire_on_old_ladder(self, tiny, plans, cache):
        """pipeline_depth=2: a tick dispatched before the swap but
        retired after it must produce plan-A bits — the executable was
        pinned at dispatch."""
        g, _, params = tiny
        pa, pb = plans
        ref_a = reference_outputs(tiny, pa, cache, 16)
        ref_b = reference_outputs(tiny, pb, cache, 16)

        clock = FakeClock()
        eng = CNNServingEngine(g, params, pa, batch_size=4, clock=clock,
                               cache=cache, pipeline_depth=2)
        rid = submit_batch(eng, clock, 0, n=8)
        eng.step(flush=True)                     # dispatch tick 0 (async)
        eng.step(flush=True)                     # dispatch tick 1
        assert eng.stats()["pipeline"]["inflight"] >= 1
        inflight_rids = set(eng._inflight_rids)
        assert inflight_rids                     # swap with work in flight
        eng.swap_plan(pb)
        rid = submit_batch(eng, clock, rid, n=8)
        eng.step(flush=True)
        eng.step(flush=True)
        eng.run_until_done()

        for r in range(8):                       # dispatched pre-swap
            assert np.array_equal(eng.done[r], ref_a[r])
        for r in range(8, 16):                   # dispatched post-swap
            assert np.array_equal(eng.done[r], ref_b[r])
        assert conserved(eng)

    def test_completion_fault_replays_on_pinned_executable(
            self, tiny, plans, cache):
        """A completion-surfaced fault on an in-flight tick replays on
        the tick's pinned (old-ladder) executable even when the swap
        landed between dispatch and replay — bitwise plan-A output."""
        g, _, params = tiny
        pa, pb = plans
        ref_a = reference_outputs(tiny, pa, cache, 8)

        clock = FakeClock()
        eng = CNNServingEngine(
            g, params, pa, batch_size=4, clock=clock, cache=cache,
            pipeline_depth=2, max_retries=2, retry_backoff_s=0.0,
            fault_plan=FaultPlan({1: TickFault(failures=1)}))
        rid = submit_batch(eng, clock, 0, n=8)
        eng.step(flush=True)
        eng.step(flush=True)                     # tick 1 dispatched, faulty
        eng.swap_plan(pb)                        # swap while it's in flight
        eng.run_until_done()
        assert eng.retries_total >= 1
        for r in range(8):
            assert np.array_equal(eng.done[r], ref_a[r])
        assert conserved(eng)

    def test_ledger_conserved_under_swap_x_faults(self, tiny, plans, cache):
        """FaultPlan.offset pins an event-relative schedule ("the first
        post-swap tick fails hard") to absolute dispatch indices; the
        outcome ledger stays conserved through swap + terminal failure."""
        g, _, params = tiny
        pa, pb = plans
        post_swap_fail = FaultPlan({0: TickFault(failures=5)})
        clock = FakeClock()
        eng = CNNServingEngine(
            g, params, pa, batch_size=4, clock=clock, cache=cache,
            max_retries=1, retry_backoff_s=0.0,
            fault_plan=post_swap_fail.offset(2))
        rid = 0
        for _ in range(2):                       # ticks 0-1: clean, plan A
            rid = submit_batch(eng, clock, rid)
            eng.step(flush=True)
            clock.t += 1.0
        eng.swap_plan(pb)
        for _ in range(2):                       # tick 2 fails terminally
            rid = submit_batch(eng, clock, rid)
            eng.step(flush=True)
            clock.t += 1.0
        eng.run_until_done()
        rb = eng.stats()["robustness"]
        assert rb["outcomes"][OUTCOME_FAILED] == 4
        assert set(range(8, 12)).isdisjoint(eng.done)
        assert set(eng.done) == set(range(8)) | set(range(12, 16))
        assert conserved(eng)

    def test_fault_plan_offset_semantics(self):
        f = TickFault(failures=1)
        p = FaultPlan({0: f, 3: f})
        assert set(p.offset(2).faults) == {2, 5}
        assert set(p.offset(-1).faults) == {2}   # index -1 drops
        assert p.offset(0).faults == p.faults
        assert p.offset(2).faults[2] is f

    def test_swap_rejects_partial_ladder_and_counts(self, tiny, plans,
                                                    cache):
        g, _, params = tiny
        pa, pb = plans
        eng = CNNServingEngine(g, params, pa, batch_size=4,
                               clock=FakeClock(), cache=cache)
        runs = eng.compile_ladder(pb, warm=False)
        some_bucket = next(iter(runs))
        partial = {b: r for b, r in runs.items() if b != some_bucket}
        with pytest.raises(ValueError, match="missing buckets"):
            eng.swap_plan(pb, partial)
        # Clean swap returns the previous deployment; re-arming it books
        # under the rollback counter. Counters survive reset() — they are
        # engine-lifetime deployment history, not per-trace state.
        old_plan, old_runs, old_scales = eng.swap_plan(pb, runs)
        assert plan_fingerprint(old_plan) == plan_fingerprint(pa)
        eng.swap_plan(old_plan, old_runs, act_scales=old_scales,
                      rollback=True)
        assert eng.stats()["plan"] == {"swaps": 1, "rollbacks": 1}
        eng.reset()
        assert eng.stats()["plan"] == {"swaps": 1, "rollbacks": 1}


# ---------------------------------------------------------------------------
# The supervisor loop, end to end.
# ---------------------------------------------------------------------------

def drive(eng, sup, clock, rid, n_ticks):
    for _ in range(n_ticks):
        rid = submit_batch(eng, clock, rid)
        eng.step(flush=True)
        sup.tick()
        clock.t += 1.0
    return rid


class TestSupervisorLoop:
    def test_requires_solved_plan(self, tiny):
        g, _, params = tiny
        eng = CNNServingEngine(g, params, None, batch_size=4,
                               clock=FakeClock())
        with pytest.raises(ValueError, match="no deployed assignment"):
            PlanSupervisor(eng, g)

    def test_shift_flips_plan_deterministically(self, tiny, plans, cache):
        """The acceptance-criteria loop: injected service shift →
        inferred calibration → adopted re-solve → compile → atomic swap
        (exactly one) → healthy probation; after recovery the sticky
        scale telescopes back to ~1 and the new plan is held inside
        hysteresis. The 4ms delay floor dominates kernel jitter, so
        every ratio the loop folds tracks the injected delays."""
        g, hw, params = tiny
        pa, _ = plans
        fp_a = plan_fingerprint(pa)
        clock = FakeClock()
        eng = CNNServingEngine(g, params, pa, batch_size=4, clock=clock,
                               cache=cache, warmup=True)
        eng.device_delay_s = 0.004
        swapped = []
        sup = PlanSupervisor(eng, g,
                             map_kwargs=dict(hw=hw, use_on_chip=False),
                             check_every=4, rollback_ticks=3,
                             on_swap=swapped.append)
        rid = drive(eng, sup, clock, 0, 8)       # settle + clean baseline
        assert sup.swaps == 0 and sup.state == MONITOR

        eng.device_delay_s = 0.024               # 6x service shift
        rid = drive(eng, sup, clock, rid, 24)
        assert sup.swaps == 1 and sup.rollbacks == 0
        assert sup.state == MONITOR              # probation passed
        assert plan_fingerprint(eng.plan) != fp_a
        assert 3.0 < sup._inferred_scale < 10.0
        assert len(swapped) == 1 and swapped[0].adopted
        flipped_fp = plan_fingerprint(eng.plan)

        eng.device_delay_s = 0.004               # recovery
        drive(eng, sup, clock, rid, 28)
        assert sup.swaps == 1 and sup.rollbacks == 0
        # The sticky scale telescopes back to ~1 ...
        assert 0.5 < sup._inferred_scale < 1.5
        # ... and the re-solve holds the deployed plan: reverting is
        # cheaper but inside the 5% gate (TestCalibratedReplan pins it).
        assert plan_fingerprint(eng.plan) == flipped_fp
        assert sup.last_replan is not None and not sup.last_replan.adopted
        assert conserved(eng)
        assert eng.stats()["plan"] == {"swaps": 1, "rollbacks": 0}
        st = sup.stats()
        assert st["state"] == MONITOR and st["swaps"] == 1

    def test_probation_rollback_under_fault_injection(self, tiny, plans,
                                                      cache):
        """A swap whose new ladder regresses is rolled back after N
        measured ticks — and injected fault ticks contribute no probation
        sample (a fault is not a plan regression), so the rollback
        verdict is reached on real measurements only."""
        g, hw, params = tiny
        pa, _ = plans
        fp_a = plan_fingerprint(pa)
        clock = FakeClock()
        # Tick 6 (first post-swap) fails terminally: probation must skip
        # it and still reach its verdict from the following ticks.
        eng = CNNServingEngine(
            g, params, pa, batch_size=4, clock=clock, cache=cache,
            warmup=True, max_retries=0,
            fault_plan=FaultPlan({6: TickFault(failures=5)}))
        eng.device_delay_s = 0.004

        def regress(_result):
            # The moment the new plan lands, its ticks run 50x slower —
            # a hostile deployment the probation window must catch.
            eng.device_delay_s = 0.2
        sup = PlanSupervisor(eng, g,
                             map_kwargs=dict(hw=hw, use_on_chip=False),
                             check_every=3, rollback_ticks=3,
                             rollback_factor=5.0, cooldown_checks=2,
                             calibration_source=lambda:
                                 TransitionCalibration(default=6.0),
                             on_swap=regress)
        rid = 0
        for _ in range(40):
            rid = submit_batch(eng, clock, rid)
            eng.step(flush=True)
            sup.tick()
            clock.t += 1.0
            if sup.rollbacks:
                break
        assert sup.swaps == 1 and sup.rollbacks == 1
        assert plan_fingerprint(eng.plan) == fp_a   # old ladder re-armed
        assert eng.stats()["plan"] == {"swaps": 1, "rollbacks": 1}
        assert sup.state == MONITOR
        assert sup._cooldown == 2                   # no immediate retry
        assert eng.failed_total == 4                # the faulted tick
        assert conserved(eng)

    def test_background_compile_swaps_at_tick_boundary(self, tiny, plans,
                                                       cache):
        """background=True: the ladder compiles off-thread while serving
        continues; the swap still lands between ticks on the serving
        thread, and the result is bitwise-identical to the foreground
        path (same plan, same cache)."""
        g, hw, params = tiny
        pa, pb = plans
        clock = FakeClock()
        eng = CNNServingEngine(g, params, pa, batch_size=4, clock=clock,
                               cache=cache, warmup=True)
        eng.device_delay_s = 0.004
        sup = PlanSupervisor(eng, g,
                             map_kwargs=dict(hw=hw, use_on_chip=False),
                             check_every=2, rollback_ticks=2,
                             settle_checks=0, background=True,
                             calibration_source=lambda:
                                 TransitionCalibration(default=6.0))
        rid = 0
        saw_compiling = False
        for _ in range(400):
            rid = submit_batch(eng, clock, rid)
            eng.step(flush=True)
            sup.tick()
            clock.t += 1.0
            saw_compiling |= sup.state == COMPILING
            if sup.swaps and sup.state == MONITOR:
                break
        assert sup.swaps == 1 and saw_compiling
        assert plan_fingerprint(eng.plan) == plan_fingerprint(pb)
        assert sup._compile_thread is None
        assert conserved(eng)
