"""Dynamic batching serving tier (the bucketed-SLO tick scheduler).

``CNNServingEngine`` compiles one overlay program per batch bucket and
``step()`` picks the smallest bucket covering the queue under a
per-request latency SLO: wait to fill a larger bucket while the oldest
request has deadline budget, dispatch early once it is nearly spent.
Edge cases pinned here: empty ticks, queues smaller than the smallest
bucket, SLO-forced early dispatch, stale-slot zeroing across bucket
switches, and the bucket-keyed tuning-record JSON round trip.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cnn.executor import forward, init_params
from repro.cnn.models import vgg16
from repro.core.autotune import (Binding, LayerTuning, TuningRecord,
                                 autotune_buckets, conv_key, parse_record_key,
                                 record_key)
from repro.core.graph import ConvMeta
from repro.core.mapper import lower_plan
from repro.serving.cnn_engine import (CNNRequest, CNNServingEngine,
                                      batch_buckets)

RNG = np.random.default_rng(11)
CONV = ConvMeta(c_in=4, c_out=6, h1=8, h2=8, k1=3, k2=3, stride=1)


class FakeClock:
    """Deterministic injectable time source."""

    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t


@pytest.fixture(scope="module")
def tiny():
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    return g, params


def img():
    return np.asarray(RNG.standard_normal((8, 8, 3)), np.float32)


def submit_n(eng, n, start_rid=0):
    reqs = [CNNRequest(rid=start_rid + i, image=img()) for i in range(n)]
    for r in reqs:
        eng.submit(r)
    return reqs


# ------------------------------------------------------------ bucket ladder
def test_batch_buckets_ladder():
    assert batch_buckets(8) == [1, 2, 4, 8]
    assert batch_buckets(1) == [1]
    assert batch_buckets(6) == [1, 2, 4, 6]   # non-pow2 cap = top bucket
    with pytest.raises(ValueError, match="max_batch"):
        batch_buckets(0)


# --------------------------------------------------------------- empty tick
def test_empty_tick(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=4)
    assert eng.step() == 0
    assert eng.next_dispatch_at() is None
    assert eng.run_until_done() == {}
    assert eng.last_tick is None


# ------------------------------------------------- covering-bucket dispatch
def test_smallest_covering_bucket_and_correctness(tiny):
    """3 requests cover into bucket 4 (padded); outputs match per-image
    eager forward; the bucket-8 executable is never touched."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=8)
    assert eng.buckets == [1, 2, 4, 8]
    reqs = submit_n(eng, 3)
    assert eng.step() == 3
    assert eng.last_tick["bucket"] == 4
    assert eng.dispatches == {1: 0, 2: 0, 4: 1, 8: 0}
    for r in reqs:
        want = forward(g, params, jnp.asarray(r.image))
        np.testing.assert_allclose(eng.done[r.rid], np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


def test_queue_smaller_than_smallest_bucket(tiny):
    """With the singleton bucket removed, a sub-bucket queue waits under
    the SLO and pads up to the smallest bucket on flush."""
    g, params = tiny
    clock = FakeClock()
    eng = CNNServingEngine(g, params, None, buckets=(4, 8), slo_s=10.0,
                           clock=clock)
    submit_n(eng, 2)
    assert eng.step(now=0.0) == 0          # budget remains → wait
    assert eng.step(now=9.0) == 0          # still inside the SLO budget
    assert eng.step(flush=True) == 2       # drain pads into bucket 4
    assert eng.last_tick["bucket"] == 4
    np.testing.assert_array_equal(eng._batch_buf[2:], 0)


# ------------------------------------------------------------ observability
def test_engine_stats_snapshot(tiny):
    """Per-request submit→dispatch→done accounting lives in the engine now
    (ROADMAP item), not only in the bench replay harness: totals, latency
    aggregates over the request log, SLO-violation counters, and reset()
    semantics (counters clear, measured service estimates survive)."""
    g, params = tiny
    clock = FakeClock()
    eng = CNNServingEngine(g, params, None, batch_size=2, clock=clock)
    s0 = eng.stats()
    assert s0["submitted"] == s0["served"] == s0["queued"] == 0
    assert s0["latency"] is None and s0["slo_violations"] == 0
    submit_n(eng, 3)                        # t_submit = 0.0
    clock.t = 0.5
    assert eng.step() == 2                  # bucket 2, queued 0.5s
    assert eng.step() == 1                  # bucket 1
    s = eng.stats()
    assert s["submitted"] == 3 and s["served"] == 3 and s["queued"] == 0
    assert s["dispatches"] == {1: 1, 2: 1}
    assert s["window"] == 3 and len(eng.request_log) == 3
    for tr in eng.request_log:
        assert tr.t_dispatch == 0.5 and tr.t_submit == 0.0
        assert tr.t_done == pytest.approx(0.5 + tr.service_s)
        assert tr.latency_s == pytest.approx(0.5 + tr.service_s)
        assert tr.slo_ok                    # slo_s=None → never violated
    assert s["slo_violations"] == 0
    assert s["latency"]["p50_ms"] >= 500.0  # 0.5s queueing floor
    assert s["queue_wait"]["max_ms"] == pytest.approx(500.0)
    assert set(s["service_ema_s"]) == {1, 2}
    # an impossible SLO counts violations (latency always exceeds 0)
    eng.slo_s = 0.0
    submit_n(eng, 1, start_rid=3)
    assert eng.step(now=clock.t) == 1
    assert eng.stats()["slo_violations"] == 1
    assert not eng.request_log[-1].slo_ok
    # reset clears accounting but keeps what the device taught us
    emas = dict(eng.stats()["service_ema_s"])
    eng.reset()
    s2 = eng.stats()
    assert s2["submitted"] == s2["served"] == s2["window"] == 0
    assert s2["slo_violations"] == 0 and s2["latency"] is None
    assert s2["service_ema_s"] == emas


# ------------------------------------------------------------ SLO scheduler
def test_slo_forced_early_dispatch(tiny):
    """A lone request dispatches through bucket 1 exactly when its deadline
    budget is spent — not before, and never waiting for batch 8."""
    g, params = tiny
    clock = FakeClock()
    eng = CNNServingEngine(g, params, None, batch_size=8, slo_s=5.0,
                           clock=clock)
    submit_n(eng, 1)                       # t_submit = 0.0
    # no service estimate yet → wait the full SLO budget
    assert eng.next_dispatch_at() == 5.0
    assert eng.step(now=0.1) == 0
    assert eng.step(now=4.9) == 0
    assert eng.step(now=5.0) == 1          # budget spent → forced dispatch
    assert eng.last_tick["bucket"] == 1
    # the measured tick now informs the next deadline: budget shrinks by
    # the bucket's estimated service time
    submit_n(eng, 1, start_rid=1)
    clock.t = 10.0
    eng.queue[0].t_submit = 10.0
    est = eng.service_estimate(1)
    assert est > 0
    assert eng.next_dispatch_at() == pytest.approx(10.0 + 5.0 - est)


def test_waits_to_fill_larger_bucket_until_full(tiny):
    """Under a generous SLO the tick keeps waiting past smaller buckets;
    filling the largest bucket dispatches immediately."""
    g, params = tiny
    clock = FakeClock()
    eng = CNNServingEngine(g, params, None, batch_size=4, slo_s=100.0,
                           clock=clock)
    submit_n(eng, 2)
    assert eng.step(now=1.0) == 0          # bucket 2 would fit — but waits
    submit_n(eng, 2, start_rid=2)          # n == largest bucket
    assert eng.next_dispatch_at() == 0.0   # full batch → dispatch now
    assert eng.step(now=1.0) == 4
    assert eng.last_tick["bucket"] == 4


def test_slo_none_dispatches_immediately(tiny):
    """slo_s=None is the latency-greedy policy: every tick dispatches the
    smallest covering bucket with no waiting (PR-2-compatible)."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=8)
    submit_n(eng, 1)
    assert eng.step() == 1
    assert eng.last_tick["bucket"] == 1


# ------------------------------------------------------- stale-slot zeroing
def test_stale_slot_zeroing_across_bucket_switches(tiny):
    """A bucket-4 tick then a bucket-1 tick: the smaller tick must zero the
    slots the larger one staged, and outputs stay correct throughout."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=4)
    buf0 = eng._batch_buf
    reqs = submit_n(eng, 4)
    assert eng.step() == 4
    assert eng.last_tick["bucket"] == 4
    reqs += submit_n(eng, 1, start_rid=4)
    assert eng.step() == 1
    assert eng.last_tick["bucket"] == 1
    assert eng._batch_buf is buf0          # one staging buffer, ever
    np.testing.assert_array_equal(eng._batch_buf[1:], 0)
    # bucket switch up again: 2 requests through the bucket-2 executable
    reqs += submit_n(eng, 2, start_rid=5)
    assert eng.step() == 2
    assert eng.last_tick["bucket"] == 2
    for r in reqs:
        want = forward(g, params, jnp.asarray(r.image))
        np.testing.assert_allclose(eng.done[r.rid], np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


def test_run_until_done_drains_under_slo(tiny):
    """run_until_done flushes: SLO waits never stall a drain."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=8, slo_s=1e9,
                           clock=FakeClock())
    submit_n(eng, 5)
    out = eng.run_until_done()
    assert sorted(out) == [0, 1, 2, 3, 4]


# ------------------------------------------- bucket-keyed tuning records
def _tuning(backend, batch):
    return LayerTuning(binding=Binding("im2col", "NS", 128, 128, backend),
                       measured_s=1.0, candidates=[], batch=batch)


def test_record_key_and_parse_roundtrip():
    assert record_key(CONV) == conv_key(CONV) + "@b1"
    assert record_key(CONV, 8) == conv_key(CONV) + "@b8"
    assert parse_record_key(record_key(CONV, 4)) == (conv_key(CONV), 4, "bf16")
    assert parse_record_key(record_key(CONV, 4, "int8")) \
        == (conv_key(CONV), 4, "int8")
    with pytest.raises(ValueError, match="unparseable"):
        parse_record_key("garbage")


def test_bucket_keyed_record_roundtrip_json(tmp_path):
    rec = TuningRecord({
        record_key(CONV, 1): _tuning("reference", 1),
        record_key(CONV, 8): _tuning("lax", 8),
    })
    path = tmp_path / "tuning.json"
    rec.save(path)
    rec2 = TuningRecord.load(path)
    assert rec2.entries.keys() == rec.entries.keys()
    assert json.loads(path.read_text())["version"] == 2
    assert rec2.buckets_for(CONV) == [1, 8]
    # exact bucket match
    assert rec2.lookup(CONV, 1).binding.backend == "reference"
    assert rec2.lookup(CONV, 8).binding.backend == "lax"
    assert rec2.lookup(CONV, 8).batch == 8
    # no exact match: largest tuned bucket below, else smallest above
    assert rec2.lookup(CONV, 4).binding.backend == "reference"
    assert rec2.lookup(CONV, 16).binding.backend == "lax"
    other = ConvMeta(c_in=3, c_out=5, h1=8, h2=8, k1=3, k2=3)
    assert rec2.lookup(other, 4) is None


def test_v1_record_migrates_on_load():
    """Version-1 blobs (bare-signature keys) load as bucket entries at the
    record's measured batch size."""
    ent = {"binding": {"algo_key": "im2col", "dataflow": "NS", "p1": 128,
                       "p2": 128, "backend": "lax"},
           "measured_s": 1.0, "candidates": []}
    blob = {"version": 1, "meta": {"batch": 8},
            "entries": {conv_key(CONV): ent}}
    rec = TuningRecord.from_json(blob)
    assert list(rec.entries) == [record_key(CONV, 8)]
    assert rec.lookup(CONV, 8).batch == 8
    # batch=None v1 records land in bucket 1
    blob = {"version": 1, "meta": {"batch": None},
            "entries": {conv_key(CONV): ent}}
    assert list(TuningRecord.from_json(blob).entries) == [record_key(CONV, 1)]


def test_autotune_buckets_and_bucket_matched_lowering(tiny):
    """autotune_buckets fills every (signature, bucket) pair; lower_plan
    consumes the bucket-matched winner per requested batch."""
    g, _ = tiny
    rec = autotune_buckets(g, buckets=(1, 2), backends=("reference",),
                           reps=1)
    sigs = {conv_key(n.conv) for n in g.conv_nodes()}
    assert len(rec.entries) == 2 * len(sigs)
    assert rec.meta["buckets"] == [1, 2]
    for node in g.conv_nodes():
        assert rec.buckets_for(node.conv) == [1, 2]
    low1 = lower_plan(g, None, tuning=rec, batch=1)
    low2 = lower_plan(g, None, tuning=rec, batch=2)
    for node in g.conv_nodes():
        want1 = rec.entries[record_key(node.conv, 1)].binding
        want2 = rec.entries[record_key(node.conv, 2)].binding
        assert low1[node.id].algo == want1.algo
        assert low2[node.id].algo == want2.algo


def test_engine_binds_each_bucket_to_its_tuned_winner(tiny):
    """The engine's per-bucket executables consume the (signature, bucket)
    winner: a record sending bucket 1 to 'reference' and bucket 2 to 'lax'
    must produce backend-distinct lowerings per bucket — and identical
    outputs (the §3 invariant extends across buckets)."""
    g, params = tiny
    entries = {}
    for node in g.conv_nodes():
        entries[record_key(node.conv, 1)] = _tuning("reference", 1)
        entries[record_key(node.conv, 2)] = _tuning("lax", 2)
    rec = TuningRecord(entries)
    from repro.cnn import overlay
    seen = []
    real = overlay.apply_conv

    def spy(x, w, *a, **kw):
        seen.append(kw.get("backend"))
        return real(x, w, *a, **kw)

    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(overlay, "apply_conv", spy)
        eng = CNNServingEngine(g, params, None, buckets=(1, 2), tuning=rec)
        reqs = submit_n(eng, 3)
        assert eng.step() == 2             # traces the bucket-2 executable
        assert eng.step() == 1             # traces the bucket-1 executable
    n_conv = len(g.conv_nodes())
    assert seen[:n_conv] == ["lax"] * n_conv
    assert seen[n_conv:] == ["reference"] * n_conv
    for r in reqs:
        want = forward(g, params, jnp.asarray(r.image))
        np.testing.assert_allclose(eng.done[r.rid], np.asarray(want),
                                   rtol=2e-2, atol=2e-3)


# ------------------------------------------------------------------ warmup
def test_warmup_primes_service_estimates(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=2, warmup=True)
    assert all(eng.service_estimate(b) > 0 for b in eng.buckets)
    assert eng.done == {}                  # warmup results are discarded
