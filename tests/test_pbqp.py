"""PBQP solver: optimality on series-parallel graphs (Theorems 4.1/4.2)."""
import numpy as np
import pytest

from repro.core.pbqp import (PBQP, solve_brute_force,
                             solve_greedy_incremental, solve_greedy_node,
                             solve_series_parallel)


def random_sp_edges(n_ops: int, rng) -> tuple:
    """Grow a series-parallel multigraph from K2 by series/parallel ops."""
    edges = [(0, 1)]
    next_id = 2
    for _ in range(n_ops):
        i = int(rng.integers(len(edges)))
        u, v = edges[i]
        if rng.random() < 0.6:   # series: subdivide
            edges.pop(i)
            edges += [(u, next_id), (next_id, v)]
            next_id += 1
        else:                    # parallel: duplicate
            edges.append((u, v))
    return edges, next_id


def random_instance(edges, n, rng, d_max=4) -> PBQP:
    p = PBQP()
    dims = {i: int(rng.integers(1, d_max)) for i in range(n)}
    for i in range(n):
        p.add_node(i, rng.random(dims[i]) * 10)
    for (u, v) in edges:
        p.add_edge(u, v, rng.random((dims[u], dims[v])) * 10)
    return p


@pytest.mark.parametrize("trial", range(25))
def test_sp_solver_matches_brute_force(trial):
    rng = np.random.default_rng(trial)
    edges, n = random_sp_edges(int(rng.integers(2, 10)), rng)
    p = random_instance(edges, n, rng)
    got = solve_series_parallel(p, allow_heuristic=False)
    want = solve_brute_force(p)
    assert got.exact
    assert got.cost == pytest.approx(want.cost, abs=1e-9)
    # the returned assignment itself evaluates to the reported cost
    assert p.total_cost(got.assignment) == pytest.approx(got.cost)


def test_greedy_is_suboptimal_on_crafted_instance():
    """§6.1.2: greedily picking the min node cost ignores transitions."""
    p = PBQP()
    p.add_node(0, [1.0, 2.0])
    p.add_node(1, [1.0, 2.0])
    # Transition matrix punishes the greedy (0, 0) assignment.
    p.add_edge(0, 1, np.array([[10.0, 5.0], [5.0, 0.0]]))
    opt = solve_series_parallel(p)
    greedy = solve_greedy_node(p)
    assert greedy.assignment == {0: 0, 1: 0}
    assert greedy.cost == pytest.approx(12.0)
    assert opt.cost == pytest.approx(4.0)        # both pick option 1
    assert opt.cost < greedy.cost


def test_greedy_incremental_no_better_than_opt():
    rng = np.random.default_rng(123)
    edges, n = random_sp_edges(8, rng)
    p = random_instance(edges, n, rng)
    opt = solve_series_parallel(p)
    ginc = solve_greedy_incremental(p, order=sorted(p.costs))
    assert opt.cost <= ginc.cost + 1e-9


def test_non_sp_graph_heuristic_fallback():
    """K4 is not series-parallel; the RN heuristic must still answer."""
    rng = np.random.default_rng(7)
    p = PBQP()
    for i in range(4):
        p.add_node(i, rng.random(2))
    for i in range(4):
        for j in range(i + 1, 4):
            p.add_edge(i, j, rng.random((2, 2)))
    with pytest.raises(ValueError):
        solve_series_parallel(p, allow_heuristic=False)
    res = solve_series_parallel(p, allow_heuristic=True)
    assert not res.exact
    assert set(res.assignment) == {0, 1, 2, 3}
    # sanity: heuristic within 2x of optimum on this tiny instance
    want = solve_brute_force(p)
    assert res.cost <= 2 * want.cost + 1e-9


def test_reduction_count_linear_in_nodes():
    """Theorem 4.1: O(N) reduction operations on a chain."""
    rng = np.random.default_rng(0)
    n = 60
    p = PBQP()
    for i in range(n):
        p.add_node(i, rng.random(3))
    for i in range(n - 1):
        p.add_edge(i, i + 1, rng.random((3, 3)))
    res = solve_series_parallel(p, allow_heuristic=False)
    assert res.exact
    assert res.reductions <= 2 * n


def test_lm_strategy_mapping_prefers_homogeneous_assignment():
    """DESIGN.md §3: the generalized technique on a transformer chain. With
    the measured command-r-35b probe terms, 'seq' beats 'heads' per layer
    AND mixing is punished by the resharding transition — PBQP must return
    a homogeneous 'seq' assignment and beat any mixed greedy pick."""
    from repro.core.lm_mapping import (LayerStrategy, map_layer_strategies)
    seq = LayerStrategy("seq", compute_s=0.128, memory_s=0.425,
                        collective_s=0.451, layout="seq")
    heads = LayerStrategy("heads", compute_s=0.129, memory_s=0.908,
                          collective_s=0.353, layout="heads")
    assign, res = map_layer_strategies(
        40, [seq, heads], resid_bytes_per_chip=64e6)
    assert res.exact
    assert set(assign.values()) == {"seq"}
    # and if 'heads' dominated every term it would flip
    cheap = LayerStrategy("heads", compute_s=0.01, memory_s=0.01,
                          collective_s=0.01, layout="heads")
    assign2, _ = map_layer_strategies(40, [seq, cheap], 64e6)
    assert set(assign2.values()) == {"heads"}
