"""End-to-end DYNAMAP flow: Algorithm 1 DSE + cost graph + PBQP mapping."""
from collections import Counter

import pytest

from repro.cnn.models import googlenet, inception_v4, resnet18, vgg16
from repro.core.cost_model import FPGA_LIKE, V5E
from repro.core.dse import (candidate_shapes, identify_parameters,
                            vmem_working_set)
from repro.core.mapper import evaluate_fixed_mapping, map_network


def test_dse_respects_vmem_budget():
    for (p1, p2) in candidate_shapes(V5E, k_panel=512, max_dim=2048):
        assert vmem_working_set(p1, p2, 512, V5E) <= V5E.vmem_budget
        assert p1 % V5E.mxu == 0 and p2 % V5E.mxu == 0


@pytest.fixture(scope="module")
def small_graph():
    return googlenet(res=56, scale=0.25)


def test_dse_and_psi_cover_all_layer_algo_pairs(small_graph):
    hw = identify_parameters(small_graph, max_dim=512)
    convs = small_graph.conv_nodes()
    from repro.core.algorithms import menu_for
    for node in convs:
        for algo in menu_for(node.conv):
            assert (node.id, algo.key) in hw.psi


@pytest.mark.parametrize("spec", [V5E, FPGA_LIKE], ids=["v5e", "fpga-like"])
def test_opt_beats_or_matches_all_fixed_baselines(spec, small_graph):
    """Table 4 direction: OPT ≤ bl3 (im2col), bl4 (kn2row), bl5 (wino)."""
    hw = identify_parameters(small_graph, spec=spec, max_dim=512)
    plan = map_network(small_graph, hw=hw, spec=spec)
    assert plan.solver.exact
    for pol in ("im2col", "kn2row", "winograd"):
        bl = evaluate_fixed_mapping(small_graph, pol, hw=hw, spec=spec)
        assert plan.total_cost_s <= bl + 1e-12, pol


def test_opt_equals_brute_force_on_small_graph():
    from repro.cnn.models import alexnet
    g = alexnet(res=32, scale=0.1)        # 5 convs → tractable state space
    hw = identify_parameters(g, max_dim=256)
    sp = map_network(g, hw=hw, solver="sp")
    bf = map_network(g, hw=hw, solver="brute")
    assert sp.total_cost_s == pytest.approx(bf.total_cost_s, rel=1e-12)


def test_opt_no_worse_than_greedy():
    g = inception_v4(res=75, scale=0.2, n_a=1, n_b=1, n_c=1)
    hw = identify_parameters(g, max_dim=512)
    opt = map_network(g, hw=hw)
    greedy = map_network(g, hw=hw, solver="greedy_node")
    assert opt.total_cost_s <= greedy.total_cost_s + 1e-12


def test_fpga_like_spec_reproduces_paper_regime():
    """On the Alveo-like device the paper's mixes appear: Inception-v4
    assigns kn2row to the 1x7/7x1 memory-bound layers (§6.1.2)."""
    g = inception_v4(res=299)
    hw = identify_parameters(g, spec=FPGA_LIKE, max_dim=512, k_panel=256)
    plan = map_network(g, hw=hw, spec=FPGA_LIKE)
    hist = Counter(a.family.value for a in plan.assignment.values())
    assert hist["kn2row"] >= 8      # the 7x1/1x7 Inception-B chains
    assert hist["winograd"] >= 8    # square-kernel layers
    # and end-to-end latency lands in the paper's ballpark (ms-scale).
    assert 1e-3 < plan.total_cost_s < 1.0


def test_resnet_skip_connections_map_exactly():
    g = resnet18(res=64, scale=0.25)
    hw = identify_parameters(g, max_dim=256)
    plan = map_network(g, hw=hw)
    assert plan.solver.exact
    assert len(plan.assignment) == len(g.conv_nodes())
