"""Robust serving tier (overload + fault handling, PR 7).

Pins the outcome/conservation model of ``CNNServingEngine``'s robustness
knobs: bounded admission (``max_queue``), deadline shedding
(``shed_deadline``), deterministic fault injection
(``distributed.fault.FaultPlan``) with bounded retry-with-backoff, and
the degrade-mode hysteresis controller — plus the satellite fixes
(duplicate-rid rejection at submit, side-effect-free ``poll()`` for
unknown rids) and the ``stats()["robustness"]`` schema. Throughout:
every submitted request ends in exactly one terminal outcome and
``completed + rejected_full + shed_deadline + failed + pending ==
submitted``.
"""
import numpy as np
import pytest

import jax

from repro.cnn.executor import init_params
from repro.cnn.models import vgg16
from repro.distributed.fault import FaultPlan, TickFault, robust_zscore
from repro.serving.cnn_engine import (OUTCOME_COMPLETED, OUTCOME_FAILED,
                                      OUTCOME_REJECTED, OUTCOME_SHED,
                                      CNNRequest, CNNServingEngine,
                                      DegradeConfig)

RNG = np.random.default_rng(7)


class FakeClock:
    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t


@pytest.fixture(scope="module")
def tiny():
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    return g, params


def img():
    return np.asarray(RNG.standard_normal((8, 8, 3)), np.float32)


def submit_n(eng, n, start_rid=0, imgs=None, t=None):
    reqs = [CNNRequest(rid=start_rid + i,
                       image=imgs[i] if imgs is not None else img(),
                       t_submit=t)
            for i in range(n)]
    return [eng.submit(r) for r in reqs], reqs


def conserved(eng) -> bool:
    rb = eng.stats()["robustness"]
    return (sum(rb["outcomes"].values()) + rb["pending"]
            == eng.submitted_total)


# ----------------------------------------------------------- fault plans


def test_fault_plan_seeded_deterministic():
    mk = lambda: FaultPlan.seeded(seed=9, n_ticks=200, fail_rate=0.3,
                                  failures=2, delay_rate=0.2, delay_s=0.5)
    a, b = mk(), mk()
    assert a.faults == b.faults and len(a) > 0
    assert FaultPlan.seeded(seed=10, n_ticks=200,
                            fail_rate=0.3).faults != a.faults
    assert a.get(None) is None          # warmup ticks never consume faults
    assert FaultPlan({}).get(0) is None


def test_robust_zscore_is_median_mad():
    samples = [1.0, 1.0, 2.0, 3.0, 3.0]       # median 2, MAD 1
    assert robust_zscore(2.0, samples) == 0.0
    assert robust_zscore(5.0, samples) == pytest.approx(3.0)
    assert robust_zscore(1.0, []) == 0.0


# ------------------------------------------------------------- admission


def test_submit_verdicts_and_bounded_admission(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=2, max_queue=2)
    verdicts, _ = submit_n(eng, 3)
    assert verdicts == ["queued", "queued", OUTCOME_REJECTED]
    assert eng.rejected_total == 1 and len(eng.queue) == 2
    rej = [t for t in eng.request_log if t.outcome == OUTCOME_REJECTED]
    assert [t.rid for t in rej] == [2]
    assert rej[0].service_s == 0.0 and not rej[0].slo_ok
    assert conserved(eng)
    eng.run_until_done()
    assert set(eng.done) == {0, 1} and conserved(eng)
    # A rejected rid never entered the engine — resubmitting it is legal.
    assert eng.submit(CNNRequest(rid=2, image=img())) == "queued"
    eng.run_until_done()
    assert 2 in eng.done


def test_duplicate_rid_rejected_at_submit(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=2)
    eng.submit(CNNRequest(rid=0, image=img()))
    with pytest.raises(ValueError, match="duplicate rid — already queued"):
        eng.submit(CNNRequest(rid=0, image=img()))
    eng.run_until_done()
    with pytest.raises(ValueError,
                       match="duplicate rid — already completed"):
        eng.submit(CNNRequest(rid=0, image=img()))
    # Failed rids are terminal too — the result they'd overwrite is the
    # failure record itself.
    feng = CNNServingEngine(
        g, params, None, batch_size=2, max_retries=0,
        fault_plan=FaultPlan({0: TickFault(failures=5)}))
    feng.submit(CNNRequest(rid=7, image=img()))
    feng.run_until_done()
    assert 7 in feng.failed
    with pytest.raises(ValueError, match="duplicate rid — already failed"):
        feng.submit(CNNRequest(rid=7, image=img()))


# -------------------------------------------------------------- shedding


def test_deadline_shedding_vs_completion(tiny):
    g, params = tiny
    clk = FakeClock()
    eng = CNNServingEngine(g, params, None, batch_size=2, slo_s=0.05,
                           shed_deadline=True, clock=clk, warmup=True)
    # Request 0 arrives at t=0; by t=0.1 its 50ms budget is unmeetable
    # even by the measured smallest-bucket floor. Request 1 is fresh.
    eng.submit(CNNRequest(rid=0, image=img(), t_submit=0.0))
    eng.submit(CNNRequest(rid=1, image=img(), t_submit=0.1))
    clk.t = 0.1
    eng.step(now=0.1, flush=True)
    assert eng.shed_rids == {0} and eng.shed_total == 1
    assert 0 not in eng.done and 1 in eng.done
    traces = {t.rid: t for t in eng.request_log}
    assert traces[0].outcome == OUTCOME_SHED
    assert traces[0].service_s == 0.0
    assert traces[0].latency_s == pytest.approx(0.1)
    assert traces[1].outcome == OUTCOME_COMPLETED and traces[1].slo_ok
    assert conserved(eng)


def test_no_shed_without_measured_floor(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=2, slo_s=1e-6,
                           shed_deadline=True, clock=FakeClock())
    eng.submit(CNNRequest(rid=0, image=img(), t_submit=0.0))
    eng.step(now=100.0, flush=True)     # no estimate yet → never sheds
    assert eng.shed_total == 0 and 0 in eng.done


# ------------------------------------------------------- retry + failure


def test_completion_fault_retry_recovers_bitwise(tiny):
    g, params = tiny
    im = img()
    clean = CNNServingEngine(g, params, None, batch_size=2)
    clean.submit(CNNRequest(rid=0, image=im))
    clean.run_until_done()
    eng = CNNServingEngine(
        g, params, None, batch_size=2, max_retries=2,
        fault_plan=FaultPlan({0: TickFault(failures=2)}))
    eng.submit(CNNRequest(rid=0, image=im))
    eng.run_until_done()
    assert eng.retries_total == 2 and eng.failed_ticks == 0
    assert np.array_equal(np.asarray(eng.done[0]),
                          np.asarray(clean.done[0]))
    assert conserved(eng)


def test_dispatch_fault_retry_and_exhaustion(tiny):
    g, params = tiny
    ok = CNNServingEngine(
        g, params, None, batch_size=2, max_retries=1,
        fault_plan=FaultPlan(
            {0: TickFault(failures=1, at_dispatch=True)}))
    im = img()
    ok.submit(CNNRequest(rid=0, image=im))
    ok.run_until_done()
    assert ok.retries_total == 1 and 0 in ok.done

    eng = CNNServingEngine(
        g, params, None, batch_size=2, max_retries=1,
        fault_plan=FaultPlan(
            {0: TickFault(failures=5, at_dispatch=True)}))
    submit_n(eng, 2)
    n = eng.step(now=0.0, flush=True)
    assert n == 2                        # consumed, not left queued
    assert eng.failed == {0: 0, 1: 0} and eng.failed_ticks == 1
    assert eng.dispatches[2] == 0        # never successfully dispatched
    traces = {t.rid: t for t in eng.request_log}
    assert all(traces[r].outcome == OUTCOME_FAILED for r in (0, 1))
    assert conserved(eng)
    # The next tick (index 1, unplanned) is untouched by the fault.
    submit_n(eng, 2, start_rid=2)
    eng.run_until_done()
    assert set(eng.done) == {2, 3} and conserved(eng)


def test_hook_not_threaded_without_plan(tiny):
    """fault_plan=None threads NO wrapper: compile_plan's hook shim is
    the identity for a None hook, so a default engine's executables are
    the exact unhooked callables (the zero-overhead guarantee)."""
    from repro.cnn.executor import _with_fault_hook
    sentinel = object()
    assert _with_fault_hook(sentinel, None) is sentinel
    calls = []
    hooked = _with_fault_hook(lambda p, x: (p, x),
                              lambda: calls.append(1))
    assert hooked(1, 2) == (1, 2) and len(calls) == 1


def test_failed_tick_does_not_pollute_service_ema(tiny):
    g, params = tiny
    eng = CNNServingEngine(
        g, params, None, batch_size=2, warmup=True, max_retries=0,
        # The doomed tick also straggles 200ms — if its wall time leaked
        # into the EMA the estimate would jump three orders of magnitude.
        fault_plan=FaultPlan({0: TickFault(failures=5, delay_s=0.2)}))
    ema_before = dict(eng.stats()["service_ema_s"])
    submit_n(eng, 2)
    eng.run_until_done()
    assert eng.failed_ticks == 1
    assert eng.stats()["service_ema_s"] == ema_before


# --------------------------------------------- pipelined faults (depth 2)


def test_depth2_faulted_inflight_drain(tiny):
    """A completion-faulted tick at depth 2 fails cleanly under lazy
    retirement: its requests get terminal outcomes, its pipeline slot and
    staging buffer return to the pool, EMAs stay unpolluted, and the
    surrounding in-flight ticks complete bitwise-correct."""
    g, params = tiny
    imgs = [img() for _ in range(6)]
    clean = CNNServingEngine(g, params, None, batch_size=2,
                             pipeline_depth=2, warmup=True)
    submit_n(clean, 6, imgs=imgs)
    clean.run_until_done()
    eng = CNNServingEngine(
        g, params, None, batch_size=2, pipeline_depth=2, warmup=True,
        max_retries=1, device_delay_s=0.05,
        fault_plan=FaultPlan({1: TickFault(failures=5, delay_s=0.2)}))
    ema_before = dict(eng.stats()["service_ema_s"])[2]
    submit_n(eng, 6, imgs=imgs)
    assert eng.step(now=0.0, flush=True) == 2      # tick 0 in flight
    assert eng.step(now=0.0, flush=True) == 2      # tick 1 (doomed)
    assert len(eng._inflight) == 2
    assert eng.step(now=0.0, flush=True) == 2      # forces tick 0 retire
    eng.drain()
    assert set(eng.done) == {0, 1, 4, 5}
    assert eng.failed == {2: 1, 3: 1}
    assert eng.retries_total == 1 and eng.failed_ticks == 1
    assert len(eng._inflight) == 0
    for r in eng.done:
        assert np.array_equal(np.asarray(eng.done[r]),
                              np.asarray(clean.done[r]))
    # The 200ms fault wall never reaches the scheduler's estimates.
    assert eng.stats()["service_ema_s"][2] < 0.1
    assert ema_before < 0.1
    assert conserved(eng)


def test_depth2_reset_with_faulted_inflight_and_plan_rewind(tiny):
    g, params = tiny
    eng = CNNServingEngine(
        g, params, None, batch_size=2, pipeline_depth=2, warmup=True,
        max_retries=0, device_delay_s=0.05,
        fault_plan=FaultPlan({1: TickFault(failures=5)}))
    submit_n(eng, 4)
    eng.step(now=0.0, flush=True)
    eng.step(now=0.0, flush=True)                  # doomed tick in flight
    assert len(eng._inflight) == 2
    eng.reset()                                    # drains, then clears
    assert len(eng._inflight) == 0 and eng.submitted_total == 0
    assert not eng.failed and not eng.done and not eng._inflight_rids
    assert conserved(eng)
    # reset rewinds the dispatch index, so the plan re-applies from
    # tick 0: the second trace's tick 1 is doomed again.
    submit_n(eng, 4)
    eng.run_until_done()
    assert set(eng.done) == {0, 1} and eng.failed == {2: 1, 3: 1}
    assert conserved(eng)


# ------------------------------------------------------------------ poll


def test_poll_unknown_rid_has_no_side_effects(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=2,
                           pipeline_depth=2, warmup=True,
                           device_delay_s=0.05)
    submit_n(eng, 5)
    eng.step(now=0.0, flush=True)
    eng.step(now=0.0, flush=True)
    assert len(eng._inflight) == 2 and len(eng.queue) == 1
    assert eng.poll(99) is None                    # never submitted
    assert eng.poll(4) is None                     # still queued
    assert len(eng._inflight) == 2                 # nothing retired
    # A genuinely in-flight rid retires only up to its own tick.
    assert eng.poll(0) is not None
    assert len(eng._inflight) == 1
    eng.run_until_done()


def test_poll_failed_rid_returns_none(tiny):
    g, params = tiny
    eng = CNNServingEngine(
        g, params, None, batch_size=2, pipeline_depth=2, max_retries=0,
        fault_plan=FaultPlan({0: TickFault(failures=5)}))
    submit_n(eng, 2)
    eng.step(now=0.0, flush=True)
    eng.drain()
    assert 0 in eng.failed
    assert eng.poll(0) is None                     # terminal, not a hang


# --------------------------------------------------------------- degrade


def test_degrade_config_validation(tiny):
    g, params = tiny
    with pytest.raises(ValueError, match="hysteresis"):
        CNNServingEngine(g, params, None, batch_size=2,
                         degrade=DegradeConfig(enter_queue=2, exit_queue=2))


def test_degrade_enter_exit_hysteresis(tiny):
    g, params = tiny
    clk = FakeClock()
    eng = CNNServingEngine(
        g, params, None, batch_size=4, slo_s=10.0, warmup=True, clock=clk,
        degrade=DegradeConfig(enter_queue=3, exit_queue=1, exit_ticks=2))
    # Below the watermark the SLO scheduler waits to fill a bucket.
    submit_n(eng, 1, t=0.0)
    assert eng.step(now=0.0) == 0
    # Queue pressure trips the entry watermark: dispatch-immediately.
    submit_n(eng, 2, start_rid=1, t=0.0)
    assert eng.step(now=0.0) == 3
    rb = eng.stats()["robustness"]["degrade"]
    assert rb["active"] and rb["entries"] == 1
    # While degraded, even a lone request dispatches with no SLO wait...
    submit_n(eng, 1, start_rid=3, t=0.0)
    assert eng.step(now=0.0) == 1
    # ...and two calm ticks at/below the exit watermark stand it down.
    assert eng.step(now=0.0) == 0
    assert eng.step(now=0.0) == 0
    rb = eng.stats()["robustness"]["degrade"]
    assert not rb["active"] and rb["exits"] == 1
    # Restored: the SLO scheduler waits again.
    submit_n(eng, 1, start_rid=4, t=100.0)
    assert eng.step(now=100.0) == 0
    eng.run_until_done()
    assert conserved(eng)


def test_degrade_straggler_spike_entry(tiny):
    g, params = tiny
    eng = CNNServingEngine(
        g, params, None, batch_size=1, warmup=True,
        # One 80ms straggler against sub-ms ticks is an unambiguous
        # spike; patience=1 arms the mode off a single streak.
        fault_plan=FaultPlan({6: TickFault(delay_s=0.08)}),
        degrade=DegradeConfig(enter_queue=100, exit_queue=10,
                              straggler_k=3.0, straggler_patience=1))
    for i in range(7):
        eng.submit(CNNRequest(rid=i, image=img()))
        eng.step(flush=True)
    assert eng._spike_streak >= 1
    eng.step()                                     # controller sees it
    rb = eng.stats()["robustness"]["degrade"]
    assert rb["active"] and rb["straggler_spikes"] >= 1
    assert conserved(eng)


# ----------------------------------------------------------------- stats


def test_stats_robustness_schema_and_conservation(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=2, max_queue=8)
    rb = eng.stats()["robustness"]
    assert set(rb) == {"max_queue", "shed_deadline", "outcomes",
                       "pending", "retries", "failed_ticks",
                       "queue_high_water", "degrade"}
    assert set(rb["outcomes"]) == {OUTCOME_COMPLETED, OUTCOME_REJECTED,
                                   OUTCOME_SHED, OUTCOME_FAILED}
    assert set(rb["degrade"]) == {"enabled", "active", "entries", "exits",
                                  "straggler_spikes"}
    assert rb["max_queue"] == 8 and not rb["degrade"]["enabled"]
    submit_n(eng, 3)
    rb = eng.stats()["robustness"]
    assert rb["pending"] == 3 and rb["queue_high_water"] == 3
    assert conserved(eng)
    eng.run_until_done()
    rb = eng.stats()["robustness"]
    assert rb["outcomes"][OUTCOME_COMPLETED] == 3 and rb["pending"] == 0
    assert conserved(eng)


def test_latency_window_excludes_non_completed(tiny):
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=2, max_queue=1)
    verdicts, _ = submit_n(eng, 2)
    assert verdicts[1] == OUTCOME_REJECTED
    eng.run_until_done()
    s = eng.stats()
    # One rejected + one completed trace, but aggregates cover only the
    # completed request — a zero-latency rejection must not deflate p99.
    assert len(eng.request_log) == 2 and s["window"] == 1
    assert s["latency"]["p99_ms"] > 0


def test_default_engine_unchanged_by_robustness_plumbing(tiny):
    """Zero-behavior-change guard: a default engine still schedules,
    accounts and reports exactly as before — no outcome but completed,
    verdict plumbing invisible to callers that ignore it."""
    g, params = tiny
    eng = CNNServingEngine(g, params, None, batch_size=4, slo_s=0.5,
                           clock=FakeClock(), warmup=True)
    submit_n(eng, 6, t=0.0)
    eng.step(now=0.0)
    eng.run_until_done()
    assert set(eng.done) == set(range(6))
    assert all(t.outcome == OUTCOME_COMPLETED for t in eng.request_log)
    rb = eng.stats()["robustness"]
    assert rb["max_queue"] is None and not rb["shed_deadline"]
    assert rb["outcomes"][OUTCOME_COMPLETED] == 6
    assert rb["retries"] == 0 and rb["failed_ticks"] == 0
    assert conserved(eng)
