"""End-to-end behaviour tests: the paper's full flow (DSE → PBQP → execute)
and the LM training loop with checkpoint/restart."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cnn.executor import forward as cnn_forward, init_params
from repro.cnn.models import googlenet
from repro.configs import get_config
from repro.core import IM2COL
from repro.core.dse import identify_parameters
from repro.core.mapper import evaluate_fixed_mapping, map_network
from repro.data.pipeline import DataConfig, make_batch
from repro.launch.steps import make_opt_config, train_step
from repro.models.model import init_model
from repro.optim.adamw import init_opt_state

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def mapped_googlenet():
    g = googlenet(res=56, scale=0.25)
    hw = identify_parameters(g, max_dim=512)
    plan = map_network(g, hw=hw)
    return g, hw, plan


def test_dynamap_flow_produces_exact_plan(mapped_googlenet):
    g, hw, plan = mapped_googlenet
    assert plan.solver.exact                      # Theorem 4.1 path
    assert len(plan.assignment) == len(g.conv_nodes())
    for pol in ("im2col", "kn2row", "winograd"):
        assert plan.total_cost_s <= \
            evaluate_fixed_mapping(g, pol, hw=hw) + 1e-12


def test_plan_execution_matches_reference(mapped_googlenet):
    """Algorithm switching is semantically invisible (§3): executing the
    PBQP-optimal plan equals the im2col-only reference network."""
    g, hw, plan = mapped_googlenet
    params = init_params(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (56, 56, 3))
    ref = cnn_forward(g, params, x, plan=None, default_algo=IM2COL)
    opt = cnn_forward(g, params, x, plan=plan)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)


def test_lm_train_loss_decreases():
    import dataclasses
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    # no warmup + brisk LR so 12 same-batch steps visibly overfit
    opt_cfg = dataclasses.replace(make_opt_config(cfg, total_steps=30),
                                  warmup_steps=0, lr=3e-3)
    params = init_model(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(params, opt_cfg)
    dcfg = DataConfig(seed=0, global_batch=4, seq_len=64)
    import functools
    step = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                                     microbatches=2))
    losses = []
    for i in range(12):
        batch = make_batch(dcfg, cfg, step=0)   # same batch → must overfit
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_train_driver_with_resume(tmp_path):
    """The launcher end-to-end: train, checkpoint, resume."""
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root"}
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "mamba2-370m", "--reduced", "--batch", "4", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--log-every", "5"]
    r1 = subprocess.run(base + ["--steps", "6"], env=env, cwd=str(REPO),
                        capture_output=True, text=True, timeout=560)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run(base + ["--steps", "8", "--resume"], env=env,
                        cwd=str(REPO), capture_output=True, text=True,
                        timeout=560)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step" in r2.stdout
