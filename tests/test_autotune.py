"""core.autotune: measured per-layer binding search + tuning record.

The autotuner benchmarks candidate (algorithm, dataflow, p1, p2, backend)
bindings on the actual device and records winners keyed by conv signature;
``lower_plan`` consumes the record to override the cost-model binding.
"""
import jax
import numpy as np
import pytest

from repro.cnn.executor import compile_plan, init_params
from repro.cnn.models import vgg16
from repro.core.algorithms import (IM2COL, KN2ROW, WINO_2_3, WINO_4_3)
from repro.core.autotune import (Binding, TuningRecord, algo_from_key,
                                 autotune_graph, candidate_bindings,
                                 conv_key, record_key, tune_layer)
from repro.core.cost_model import Dataflow
from repro.core.graph import ConvMeta
from repro.core.mapper import lower_plan

CONV = ConvMeta(c_in=4, c_out=6, h1=8, h2=8, k1=3, k2=3, stride=1)


def test_conv_key_identifies_shape():
    assert conv_key(CONV) == "c4x6_h8x8_k3x3_s1_same"
    assert conv_key(CONV) != conv_key(
        ConvMeta(c_in=4, c_out=6, h1=8, h2=8, k1=3, k2=3, stride=2))


@pytest.mark.parametrize("algo", [IM2COL, KN2ROW, WINO_2_3, WINO_4_3])
def test_algo_key_roundtrip(algo):
    assert algo_from_key(algo.key) == algo


def test_algo_from_key_rejects_garbage():
    with pytest.raises(ValueError, match="unparseable"):
        algo_from_key("fft")


def test_candidate_bindings_shape_of_search_space():
    """lax is algorithm-independent (1 candidate); reference ignores the
    block binding (1 candidate/algo); pallas sweeps dataflows × (p1, p2)."""
    cands = candidate_bindings(CONV, p1p2=[(128, 128), (256, 128)])
    lax = [c for c in cands if c.backend == "lax"]
    assert len(lax) == 1
    ref = [c for c in cands if c.backend == "reference"]
    pal = [c for c in cands if c.backend == "pallas"]
    assert len(ref) == len({c.algo_key for c in ref})      # one per algo
    per_algo = {}
    for c in pal:
        per_algo.setdefault(c.algo_key, []).append(c)
    for key, group in per_algo.items():
        assert len(group) == 3 * 2                          # dataflows × p1p2
    # reference-only search space collapses to one candidate per algorithm
    ref_only = candidate_bindings(CONV, backends=("reference",))
    assert all(c.backend == "reference" for c in ref_only)
    assert len(ref_only) == len(ref)


def test_tune_layer_picks_measured_min():
    tuned = tune_layer(CONV, backends=("reference",), reps=1)
    assert tuned.candidates                    # every candidate was timed
    best_label, best_s = min(tuned.candidates, key=lambda c: c[1])
    assert tuned.binding.label() == best_label
    assert tuned.measured_s == best_s
    assert tuned.binding.backend == "reference"


def test_record_roundtrip_and_lowering(tmp_path):
    rec = TuningRecord()
    g = vgg16(res=8, scale=0.05)
    rec = autotune_graph(g, backends=("reference",), reps=1, record=rec)
    assert len(rec.entries) > 0
    path = tmp_path / "tuning.json"
    rec.save(path)
    rec2 = TuningRecord.load(path)
    assert rec2.entries.keys() == rec.entries.keys()
    for key in rec.entries:
        assert rec2.entries[key].binding == rec.entries[key].binding

    # lower_plan consumes the record: every conv binding overridden
    # (entries are bucket-keyed; batch=None tuning lands in bucket 1)
    lowering = lower_plan(g, None, default_algo=KN2ROW, tuning=rec2)
    for node in g.conv_nodes():
        tuned = rec2.entries[record_key(node.conv)]
        low = lowering[node.id]
        assert low.algo == tuned.binding.algo
        assert low.backend == tuned.binding.backend
        assert (low.p1, low.p2) == (tuned.binding.p1, tuned.binding.p2)
        assert low.dataflow is Dataflow[tuned.binding.dataflow]
        assert low.epilogue == "relu"          # tuning never touches epilogue


def test_autotune_incremental_skip_known():
    g = vgg16(res=8, scale=0.05)
    sentinel = Binding("im2col", "NS", 128, 128, "reference")
    rec = TuningRecord()
    rec = autotune_graph(g, backends=("reference",), reps=1, record=rec)
    stamped = {k: t.measured_s for k, t in rec.entries.items()}
    # re-tuning with skip_known leaves existing entries untouched
    rec = autotune_graph(g, backends=("reference",), reps=1, record=rec,
                         skip_known=True)
    assert {k: t.measured_s for k, t in rec.entries.items()} == stamped
    assert sentinel.algo == IM2COL


def test_tuned_compiled_plan_equivalent():
    """A tuned record changes bindings, never the function (the §3
    invariant extends to measured bindings)."""
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    rec = autotune_graph(g, backends=("lax", "reference"), reps=1)
    got = compile_plan(g, tuning=rec)(params, x)
    ref = compile_plan(g)(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)


def test_version_mismatch_rejected():
    with pytest.raises(ValueError, match="version"):
        TuningRecord.from_json({"version": 99, "entries": {}})


# ---------------------------------------------------------------------------
# Regression coverage (PR 10): merge incumbents-win semantics under
# precision-keyed entries combined with v1→v2 migration — PR 8 added
# merge, PR 9 added "#int8" keys, but the combination was untested.
# ---------------------------------------------------------------------------

def _tuning(label_s: float, batch: int = 1,
            precision: str = "bf16") -> "LayerTuning":
    from repro.core.autotune import LayerTuning
    b = Binding("im2col", "NS", 128, 128, "reference")
    return LayerTuning(binding=b, measured_s=label_s,
                       candidates=[(b.label(), label_s)],
                       batch=batch, precision=precision)


class TestMergePrecisionMigration:
    def test_precision_keys_never_collide(self):
        """bf16 and int8 measurements of the same (sig, bucket) are
        distinct keys — merge can adopt one without touching the other."""
        k_bf16 = record_key(CONV, 4)
        k_int8 = record_key(CONV, 4, precision="int8")
        assert k_bf16 != k_int8 and k_int8.endswith("#int8")
        mine = TuningRecord({k_bf16: _tuning(1.0, 4)})
        theirs = TuningRecord({k_bf16: _tuning(9.0, 4),
                               k_int8: _tuning(0.5, 4, "int8")})
        adopted = mine.merge(theirs)
        assert adopted == 1                       # only the int8 entry
        assert mine.entries[k_bf16].measured_s == 1.0   # incumbent wins
        assert mine.entries[k_int8].measured_s == 0.5
        assert mine.entries[k_int8].precision == "int8"

    def test_lookup_bucket_fallback_is_precision_strict(self):
        """Bucket fallback (largest tuned bucket below) never crosses
        precisions: an int8 layer with only bf16 measurements gets None,
        not a silently-wrong bf16 binding."""
        rec = TuningRecord({record_key(CONV, 2): _tuning(1.0, 2),
                            record_key(CONV, 2, "int8"):
                                _tuning(0.5, 2, "int8")})
        assert rec.lookup(CONV, batch=8).measured_s == 1.0
        assert rec.lookup(CONV, batch=8, precision="int8").measured_s == 0.5
        only_bf16 = TuningRecord({record_key(CONV, 2): _tuning(1.0, 2)})
        assert only_bf16.lookup(CONV, batch=8, precision="int8") is None

    def test_v1_migration_then_merge_keeps_incumbents(self):
        """A v1 blob (bare-signature keys, whole record at one batch)
        migrates to "sig@bN" keys; merging it into a v2 record that
        already measured the same bucket adopts nothing, while new
        buckets and int8 entries flow through."""
        v1_blob = {
            "version": 1,
            "meta": {"batch": 4},
            "entries": {
                conv_key(CONV): {
                    "binding": {"algo_key": "kn2row", "dataflow": "WS",
                                "p1": 128, "p2": 128,
                                "backend": "reference"},
                    "measured_s": 7.0,
                    "candidates": [["kn2row|WS|128x128|reference", 7.0]],
                },
            },
        }
        migrated = TuningRecord.from_json(v1_blob)
        key4 = record_key(CONV, 4)
        assert set(migrated.entries) == {key4}    # bare key → "@b4"
        assert migrated.entries[key4].batch == 4
        assert migrated.entries[key4].precision == "bf16"

        # v1 round-trips forward: re-serialized blobs are v2.
        assert migrated.to_json()["version"] == 2
        assert TuningRecord.from_json(
            migrated.to_json()).entries.keys() == {key4}

        mine = TuningRecord({key4: _tuning(1.0, 4),
                             record_key(CONV, 4, "int8"):
                                 _tuning(0.4, 4, "int8")})
        adopted = mine.merge(migrated)
        assert adopted == 0                       # incumbent at @b4 wins
        assert mine.entries[key4].measured_s == 1.0
        # The reverse direction adopts the incumbents-free keys only.
        adopted = migrated.merge(mine)
        assert adopted == 1                       # just the int8 key
        assert migrated.entries[key4].measured_s == 7.0
        assert migrated.entries[record_key(CONV, 4, "int8")].precision \
            == "int8"

    def test_v1_without_batch_meta_lands_in_bucket_1(self):
        v1_blob = {"version": 1, "meta": {}, "entries": {
            conv_key(CONV): {
                "binding": {"algo_key": "im2col", "dataflow": "NS",
                            "p1": 128, "p2": 128, "backend": "reference"},
                "measured_s": 3.0, "candidates": []}}}
        rec = TuningRecord.from_json(v1_blob)
        assert set(rec.entries) == {record_key(CONV, 1)}


# ---------------------------------------------------------------------------
# Live refresh from serving EMAs (PR 10 closed loop).
# ---------------------------------------------------------------------------

class TestRefreshFromService:
    def _graph_record(self):
        from repro.core.autotune import refresh_from_service  # noqa: F401
        g = vgg16(res=8, scale=0.05)
        rec = TuningRecord()
        for node in g.conv_nodes():
            for bucket in (1, 4):
                rec.entries[record_key(node.conv, bucket)] = \
                    _tuning(0.001, bucket)
        return g, rec

    def test_divergent_ema_rescales_exact_bucket_only(self):
        from repro.core.autotune import refresh_from_service
        g, rec = self._graph_record()
        n_convs = len(list(g.conv_nodes()))
        expected = n_convs * 0.001
        applied = refresh_from_service(rec, g, {4: 2.0 * expected})
        assert applied == {4: pytest.approx(2.0)}
        for node in g.conv_nodes():
            assert rec.entries[record_key(node.conv, 4)].measured_s \
                == pytest.approx(0.002)
            # candidates rescale with the winner; bucket 1 untouched
            _, cand_s = rec.entries[record_key(node.conv, 4)].candidates[0]
            assert cand_s == pytest.approx(0.002)
            assert rec.entries[record_key(node.conv, 1)].measured_s \
                == pytest.approx(0.001)
        assert rec.meta["live_refresh"] == {"4": pytest.approx(2.0)}

    def test_sub_hysteresis_divergence_holds_steady(self):
        from repro.core.autotune import refresh_from_service
        g, rec = self._graph_record()
        expected = len(list(g.conv_nodes())) * 0.001
        applied = refresh_from_service(rec, g, {4: 1.03 * expected})
        assert applied == {}
        assert "live_refresh" not in rec.meta
        assert rec.entries[record_key(
            next(iter(g.conv_nodes())).conv, 4)].measured_s \
            == pytest.approx(0.001)

    def test_refresh_scales_accumulate(self):
        from repro.core.autotune import refresh_from_service
        g, rec = self._graph_record()
        expected = len(list(g.conv_nodes())) * 0.001
        refresh_from_service(rec, g, {4: 2.0 * expected})
        # After the rescale the record predicts 2x; a further 1.5x EMA
        # accumulates multiplicatively in the meta log.
        refresh_from_service(rec, g, {4: 3.0 * expected})
        assert rec.meta["live_refresh"]["4"] == pytest.approx(3.0)

    def test_bindings_never_rerank(self):
        """A uniform rescale cannot flip winners — the binding is
        untouched even when measured_s doubles."""
        from repro.core.autotune import refresh_from_service
        g, rec = self._graph_record()
        before = {k: t.binding for k, t in rec.entries.items()}
        expected = len(list(g.conv_nodes())) * 0.001
        refresh_from_service(rec, g, {4: 2.0 * expected})
        assert {k: t.binding for k, t in rec.entries.items()} == before
