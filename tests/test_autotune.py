"""core.autotune: measured per-layer binding search + tuning record.

The autotuner benchmarks candidate (algorithm, dataflow, p1, p2, backend)
bindings on the actual device and records winners keyed by conv signature;
``lower_plan`` consumes the record to override the cost-model binding.
"""
import jax
import numpy as np
import pytest

from repro.cnn.executor import compile_plan, init_params
from repro.cnn.models import vgg16
from repro.core.algorithms import (IM2COL, KN2ROW, WINO_2_3, WINO_4_3)
from repro.core.autotune import (Binding, TuningRecord, algo_from_key,
                                 autotune_graph, candidate_bindings,
                                 conv_key, record_key, tune_layer)
from repro.core.cost_model import Dataflow
from repro.core.graph import ConvMeta
from repro.core.mapper import lower_plan

CONV = ConvMeta(c_in=4, c_out=6, h1=8, h2=8, k1=3, k2=3, stride=1)


def test_conv_key_identifies_shape():
    assert conv_key(CONV) == "c4x6_h8x8_k3x3_s1_same"
    assert conv_key(CONV) != conv_key(
        ConvMeta(c_in=4, c_out=6, h1=8, h2=8, k1=3, k2=3, stride=2))


@pytest.mark.parametrize("algo", [IM2COL, KN2ROW, WINO_2_3, WINO_4_3])
def test_algo_key_roundtrip(algo):
    assert algo_from_key(algo.key) == algo


def test_algo_from_key_rejects_garbage():
    with pytest.raises(ValueError, match="unparseable"):
        algo_from_key("fft")


def test_candidate_bindings_shape_of_search_space():
    """lax is algorithm-independent (1 candidate); reference ignores the
    block binding (1 candidate/algo); pallas sweeps dataflows × (p1, p2)."""
    cands = candidate_bindings(CONV, p1p2=[(128, 128), (256, 128)])
    lax = [c for c in cands if c.backend == "lax"]
    assert len(lax) == 1
    ref = [c for c in cands if c.backend == "reference"]
    pal = [c for c in cands if c.backend == "pallas"]
    assert len(ref) == len({c.algo_key for c in ref})      # one per algo
    per_algo = {}
    for c in pal:
        per_algo.setdefault(c.algo_key, []).append(c)
    for key, group in per_algo.items():
        assert len(group) == 3 * 2                          # dataflows × p1p2
    # reference-only search space collapses to one candidate per algorithm
    ref_only = candidate_bindings(CONV, backends=("reference",))
    assert all(c.backend == "reference" for c in ref_only)
    assert len(ref_only) == len(ref)


def test_tune_layer_picks_measured_min():
    tuned = tune_layer(CONV, backends=("reference",), reps=1)
    assert tuned.candidates                    # every candidate was timed
    best_label, best_s = min(tuned.candidates, key=lambda c: c[1])
    assert tuned.binding.label() == best_label
    assert tuned.measured_s == best_s
    assert tuned.binding.backend == "reference"


def test_record_roundtrip_and_lowering(tmp_path):
    rec = TuningRecord()
    g = vgg16(res=8, scale=0.05)
    rec = autotune_graph(g, backends=("reference",), reps=1, record=rec)
    assert len(rec.entries) > 0
    path = tmp_path / "tuning.json"
    rec.save(path)
    rec2 = TuningRecord.load(path)
    assert rec2.entries.keys() == rec.entries.keys()
    for key in rec.entries:
        assert rec2.entries[key].binding == rec.entries[key].binding

    # lower_plan consumes the record: every conv binding overridden
    # (entries are bucket-keyed; batch=None tuning lands in bucket 1)
    lowering = lower_plan(g, None, default_algo=KN2ROW, tuning=rec2)
    for node in g.conv_nodes():
        tuned = rec2.entries[record_key(node.conv)]
        low = lowering[node.id]
        assert low.algo == tuned.binding.algo
        assert low.backend == tuned.binding.backend
        assert (low.p1, low.p2) == (tuned.binding.p1, tuned.binding.p2)
        assert low.dataflow is Dataflow[tuned.binding.dataflow]
        assert low.epilogue == "relu"          # tuning never touches epilogue


def test_autotune_incremental_skip_known():
    g = vgg16(res=8, scale=0.05)
    sentinel = Binding("im2col", "NS", 128, 128, "reference")
    rec = TuningRecord()
    rec = autotune_graph(g, backends=("reference",), reps=1, record=rec)
    stamped = {k: t.measured_s for k, t in rec.entries.items()}
    # re-tuning with skip_known leaves existing entries untouched
    rec = autotune_graph(g, backends=("reference",), reps=1, record=rec,
                         skip_known=True)
    assert {k: t.measured_s for k, t in rec.entries.items()} == stamped
    assert sentinel.algo == IM2COL


def test_tuned_compiled_plan_equivalent():
    """A tuned record changes bindings, never the function (the §3
    invariant extends to measured bindings)."""
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    rec = autotune_graph(g, backends=("lax", "reference"), reps=1)
    got = compile_plan(g, tuning=rec)(params, x)
    ref = compile_plan(g)(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)


def test_version_mismatch_rejected():
    with pytest.raises(ValueError, match="version"):
        TuningRecord.from_json({"version": 99, "entries": {}})
