"""Substrate: optimizer, data pipeline, checkpoint manager, fault logic,
serving engine."""
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, PrefetchIterator, make_batch
from repro.distributed.fault import (ElasticPlanner, HealthTracker,
                                     StragglerMonitor, run_with_retries)
from repro.models.model import init_model
from repro.optim.adamw import (AdamWConfig, apply_updates, compressed_grad,
                               init_opt_state, schedule)
from repro.serving.engine import Request, ServingEngine


# ---------------------------------------------------------------- optim
def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, clip_norm=100.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, state, m = apply_updates(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(jnp.int32(0), cfg)) == pytest.approx(0.0)
    assert float(schedule(jnp.int32(10), cfg)) == pytest.approx(1.0)
    assert float(schedule(jnp.int32(100), cfg)) == pytest.approx(0.1,
                                                                 abs=1e-6)


def test_grad_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    err = jnp.zeros_like(g)
    total_true, total_sent = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(20):
        sent, err = compressed_grad(g, err)
        total_true += g
        total_sent += sent
    # error feedback keeps the accumulated bias bounded by one quant step
    denom = float(jnp.max(jnp.abs(total_true)))
    assert float(jnp.max(jnp.abs(total_true - total_sent))) / denom < 0.05


# ----------------------------------------------------------------- data
def test_data_determinism_and_host_slicing():
    cfg = DataConfig(seed=1, global_batch=8, seq_len=64)
    model = get_config("qwen2.5-14b", reduced=True)
    b1 = make_batch(cfg, model, step=3)
    b2 = make_batch(cfg, model, step=3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = make_batch(cfg, model, step=4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < model.vocab


def test_prefetch_iterator_orders_steps():
    cfg = DataConfig(seed=0, global_batch=4, seq_len=32)
    model = get_config("mamba2-370m", reduced=True)
    it = PrefetchIterator(cfg, model, start_step=5, depth=2)
    s1, _ = next(it)
    s2, _ = next(it)
    it.close()
    assert (s1, s2) == (5, 6)


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2, async_write=False)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (10, 20, 30):
        mgr.save(step, tree, extra={"step": step})
    assert mgr.all_steps() == [20, 30]           # keep_n=2 GC'd step 10
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, extra = mgr.restore(like)
    assert extra["step"] == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity(tmp_path):
    """A half-written (uncommitted) checkpoint must be invisible."""
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = {"a": jnp.zeros((2,))}
    mgr.save(5, tree)
    # fake a torn write: directory exists but no COMMITTED marker
    (tmp_path / "step_000000007").mkdir()
    assert mgr.latest_step() == 5


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=True)
    tree = {"a": jnp.arange(1000, dtype=jnp.float32)}
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1


# ----------------------------------------------------------------- fault
def test_health_tracker_failure_detection():
    ht = HealthTracker(n_hosts=4, beat_interval_s=1.0, max_missed=3)
    for t in range(1, 10):
        for h in (0, 1, 2):
            ht.beat(h, float(t))
        dead = ht.sweep(float(t))
        if t >= 3:
            assert 3 in dead or 3 not in ht.alive_hosts()
    assert ht.alive_hosts() == [0, 1, 2]


def test_elastic_planner_preserves_model_axis():
    pl = ElasticPlanner(devices_per_host=4, model_axis=16)
    plan, info = pl.plan(n_alive_hosts=64, global_batch=256)   # 256 devices
    assert plan.model == 16 and plan.data == 16
    plan2, info2 = pl.plan(n_alive_hosts=60, global_batch=256)  # 240 devices
    assert plan2.model == 16
    assert plan2.data == 8                        # largest pow2 ≤ 15
    assert info2["dropped_devices"] == 240 - plan2.devices
    with pytest.raises(RuntimeError):
        pl.plan(n_alive_hosts=2, global_batch=256)


def test_straggler_monitor_flags_persistent_offender():
    sm = StragglerMonitor(n_hosts=8, k=3.0, patience=2)
    base = {h: 1.0 for h in range(8)}
    evict = sm.observe({**base, 5: 10.0})
    assert evict == []
    evict = sm.observe({**base, 5: 12.0})
    assert evict == [5]
    # a recovered host resets
    sm.observe(base)
    assert sm.offense[5] == 0


def test_run_with_retries_restores_and_completes():
    log = []
    saved = {"step": 0}
    crashed = {"done": False}

    def step_fn(step):
        log.append(step)

    def save_fn(step):
        saved["step"] = step

    def restore_fn():
        return saved["step"]

    def injector(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")

    stats = run_with_retries(step_fn, save_fn, restore_fn, n_steps=12,
                             checkpoint_every=5, failure_injector=injector)
    assert stats == {"completed": 12, "restarts": 1}
    # steps 5..6 replayed after restore from checkpoint at 5
    assert log.count(5) == 2 and log.count(6) == 2 and log.count(7) == 1


# --------------------------------------------------------------- serving
def test_serving_engine_continuous_batching():
    cfg = get_config("qwen2.5-14b", reduced=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(4):      # 4 requests > 2 slots → queueing
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, 5).astype(
                               np.int32),
                           max_new_tokens=3))
    out = eng.run_until_done()
    assert sorted(out) == [0, 1, 2, 3]
    assert all(len(v) == 3 for v in out.values())
    assert all(0 <= t < cfg.vocab for v in out.values() for t in v)
