"""Property-based tests (hypothesis) on system invariants."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.algorithms import IM2COL, KN2ROW, WINO_2_3, menu_for
from repro.core.cost_model import (ALL_DATAFLOWS, Dataflow, V5E,
                                   best_dataflow, gemm_steps,
                                   gemm_utilization, node_cost)
from repro.core.graph import ConvMeta
from repro.core.pbqp import (PBQP, solve_brute_force, solve_greedy_node,
                             solve_series_parallel)
from repro.kernels.common import ceil_to, pad_to

dims = st.integers(min_value=1, max_value=600)
blocks = st.sampled_from([8, 32, 128, 256])


@given(a=dims, b=dims, c=dims, p1=blocks, p2=blocks)
@settings(max_examples=60, deadline=None)
def test_gemm_steps_lower_bounded_by_work(a, b, c, p1, p2):
    """Eq. 9 invariant: steps·P_SA1·P_SA2 ≥ a·b·c (can't beat the MACs),
    i.e. utilization ≤ 1; and the ceil waste bound holds."""
    for df in ALL_DATAFLOWS:
        steps = gemm_steps(a, b, c, p1, p2, df, i_sa=0)
        assert steps * p1 * p2 >= a * b * c
        assert 0 < gemm_utilization(a, b, c, p1, p2, df) <= 1.0


@given(a=dims, b=dims, c=dims, p1=blocks, p2=blocks)
@settings(max_examples=40, deadline=None)
def test_best_dataflow_is_argmin(a, b, c, p1, p2):
    df, steps = best_dataflow(a, b, c, p1, p2)
    for other in ALL_DATAFLOWS:
        assert steps <= gemm_steps(a, b, c, p1, p2, other)


@given(h=st.integers(4, 64), cin=st.integers(1, 64),
       cout=st.integers(1, 64), k=st.sampled_from([1, 3, 5, 7]),
       stride=st.sampled_from([1, 2]))
@settings(max_examples=40, deadline=None)
def test_algorithm_menu_preserves_macs(h, cin, cout, k, stride):
    """im2col/kn2row always match spatial-conv multiplies; Winograd is a
    strict reduction (when applicable)."""
    conv = ConvMeta(c_in=cin, c_out=cout, h1=h, h2=h, k1=k, k2=k,
                    stride=stride)
    assert IM2COL.multiplies(conv) == KN2ROW.multiplies(conv) == conv.macs
    for algo in menu_for(conv):
        nc = node_cost(conv, algo, 128, 128, spec=V5E)
        assert nc.total > 0 and math.isfinite(nc.total)
    if WINO_2_3.applicable(conv) and k == 3:
        assert WINO_2_3.multiplies(conv) < conv.macs


@st.composite
def sp_pbqp(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    edges = [(0, 1)]
    next_id = 2
    for _ in range(draw(st.integers(1, 7))):
        i = int(rng.integers(len(edges)))
        u, v = edges[i]
        if rng.random() < 0.6:
            edges.pop(i)
            edges += [(u, next_id), (next_id, v)]
            next_id += 1
        else:
            edges.append((u, v))
    p = PBQP()
    d = {i: int(rng.integers(1, 4)) for i in range(next_id)}
    for i in range(next_id):
        p.add_node(i, rng.random(d[i]) * 10)
    for (u, v) in edges:
        p.add_edge(u, v, rng.random((d[u], d[v])) * 10)
    return p


@given(p=sp_pbqp())
@settings(max_examples=30, deadline=None)
def test_pbqp_sp_optimality_property(p):
    got = solve_series_parallel(p, allow_heuristic=False)
    want = solve_brute_force(p)
    assert abs(got.cost - want.cost) < 1e-9
    assert got.cost <= solve_greedy_node(p).cost + 1e-9
    # every node assigned a valid index
    for nid, choice in got.assignment.items():
        assert 0 <= choice < p.costs[nid].size


@given(n=st.integers(1, 300), m=st.sampled_from([1, 8, 128]))
@settings(max_examples=30, deadline=None)
def test_ceil_to_properties(n, m):
    c = ceil_to(n, m)
    assert c >= n and c % m == 0 and c - n < m


@given(rows=st.integers(1, 40), cols=st.integers(1, 40))
@settings(max_examples=20, deadline=None)
def test_pad_to_zero_extends(rows, cols):
    import jax.numpy as jnp
    x = jnp.ones((rows, cols))
    p = pad_to(x, (8, 128))
    assert p.shape == (ceil_to(rows, 8), ceil_to(cols, 128))
    assert float(p.sum()) == rows * cols


# --------------------------------------------------------------------------
# Calibrated re-solve properties (PR 10): determinism + hysteresis
# stability of the closed-loop replan under measured transition scales.
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def replan_env():
    import jax  # noqa: F401  (imported for parity with other suites)
    from repro.cnn.models import vgg16
    from repro.core.dse import identify_parameters
    g = vgg16(res=8, scale=0.05)
    return g, identify_parameters(g)


def _calibration(rng, lo=0.5, hi=6.0, jitter=0.0):
    from repro.core.algorithms import Layout
    from repro.core.cost_model import TransitionCalibration
    scales = {}
    for a in Layout:
        for b in Layout:
            s = float(rng.uniform(lo, hi))
            scales[(a, b)] = s * (1.0 + float(rng.uniform(-jitter, jitter)))
    return TransitionCalibration(scales=scales,
                                 default=float(rng.uniform(lo, hi)))


@given(seed=st.integers(0, 2 ** 31))
@settings(max_examples=10, deadline=None)
def test_calibrated_resolve_is_deterministic(replan_env, seed):
    """Same graph + same calibration scales ⇒ byte-identical plan
    fingerprint — the supervisor's re-solve decisions are replayable."""
    from repro.core.mapper import map_network, plan_fingerprint
    g, hw = replan_env
    rng = np.random.default_rng(seed)
    cal = _calibration(rng)
    fp = {plan_fingerprint(map_network(g, hw=hw, use_on_chip=False,
                                       calibration=cal))
          for _ in range(2)}
    assert len(fp) == 1


@given(seed=st.integers(0, 2 ** 31))
@settings(max_examples=10, deadline=None)
def test_sub_hysteresis_scale_perturbation_never_adopts(replan_env, seed):
    """Per-pair scale noise within 1±2% — under half the 5% adoption
    hysteresis, so the deployed/candidate cost ratio moves by at most
    ~2·2% < 5% — must never flip the deployed plan. Without this band
    the supervisor would flap on measurement noise."""
    from repro.core.algorithms import Layout
    from repro.core.cost_model import TransitionCalibration
    from repro.core.mapper import map_network, replan
    g, hw = replan_env
    rng = np.random.default_rng(seed)
    base_default = float(rng.uniform(0.5, 6.0))
    base = TransitionCalibration(default=base_default)
    deployed = map_network(g, hw=hw, use_on_chip=False, calibration=base)
    noisy = TransitionCalibration(
        scales={(a, b): base_default * (1.0 + float(rng.uniform(-.02, .02)))
                for a in Layout for b in Layout},
        default=base_default)
    r = replan(g, deployed, calibration=noisy, hw=hw, use_on_chip=False)
    assert not r.adopted
