"""Graph IR, series-parallel recognition, and the Eq. 9-13 cost model."""
import math

import pytest

from repro.cnn.models import MODELS
from repro.core.algorithms import (IM2COL, KN2ROW, WINO_2_3, WINO_4_3,
                                   menu_for)
from repro.core.cost_model import (Dataflow, FPGA_LIKE, V5E, best_dataflow,
                                   eff_bandwidth, fits_on_chip, gemm_steps,
                                   gemm_utilization, node_cost,
                                   transition_cost)
from repro.core.graph import ConvMeta, Graph, LayerKind, is_series_parallel


# ----------------------------------------------------------------- graphs
def test_all_model_graphs_are_series_parallel():
    """Lemmas 4.3 / 4.4 for every builder (incl. the branchy Inception-C)."""
    for name, build in MODELS.items():
        g = build(res=64 if name != "inception_v4" else 75, scale=0.2)
        assert is_series_parallel(g), name


def test_k4_is_not_series_parallel():
    g = Graph()
    ids = [g.add_node(LayerKind.CONCAT, out_shape=(1, 1, 1))
           for _ in range(4)]
    # K4 with a source/sink orientation
    g.add_edge(ids[0], ids[1])
    g.add_edge(ids[0], ids[2])
    g.add_edge(ids[1], ids[2])
    g.add_edge(ids[1], ids[3])
    g.add_edge(ids[2], ids[3])
    g.add_edge(ids[0], ids[3])
    assert not is_series_parallel(g)


def test_conv_meta_output_dims():
    m = ConvMeta(c_in=3, c_out=8, h1=15, h2=15, k1=3, k2=3, stride=2,
                 pad="same")
    assert (m.o1, m.o2) == (8, 8)
    m2 = ConvMeta(c_in=3, c_out=8, h1=15, h2=15, k1=3, k2=3, stride=1,
                  pad="valid")
    assert (m2.o1, m2.o2) == (13, 13)


# ---------------------------------------------------------------- Eq. 9
def test_gemm_steps_matches_eq9():
    # paper §3.2 example: 31x31 array, (a,b,c) = (62,124,64)
    a, b, c = 62, 124, 64
    ns = gemm_steps(a, b, c, 31, 31, Dataflow.NS, i_sa=0)
    assert ns == math.ceil(62 / 31) * math.ceil(64 / 31) * 124
    ws = gemm_steps(a, b, c, 31, 31, Dataflow.WS, i_sa=0)
    assert ws == math.ceil(124 / 31) * math.ceil(64 / 31) * 62
    # the paper's utilization claim (§3.2): (a,c)-parallel ≈ 68%;
    # (a,b)-parallel (= IS binding: b→P_SA1, a→P_SA2) hits 100%.
    util_ns = gemm_utilization(a, b, c, 31, 31, Dataflow.NS)
    assert util_ns == pytest.approx(0.688, abs=0.02)
    util_is = gemm_utilization(a, b, c, 31, 31, Dataflow.IS)
    assert util_is == pytest.approx(1.0, abs=1e-6)
    # best_dataflow therefore picks the dataflow the paper advocates
    df, _ = best_dataflow(a, b, c, 31, 31)
    assert df == Dataflow.IS


def test_eff_bandwidth_lane_penalty():
    assert eff_bandwidth(V5E, 128) == V5E.hbm_bw
    assert eff_bandwidth(V5E, 256) == V5E.hbm_bw
    assert eff_bandwidth(V5E, 64) == pytest.approx(V5E.hbm_bw * 0.5)


# ------------------------------------------------------------- node cost
CONV = ConvMeta(c_in=96, c_out=128, h1=28, h2=28, k1=3, k2=3)


def test_winograd_reduces_multiplies():
    assert WINO_2_3.multiplies(CONV) < IM2COL.multiplies(CONV)
    # F(2,3) reduces 3x3 multiplies by 2.25x = (4*9)/16
    ratio = IM2COL.multiplies(CONV) / WINO_2_3.multiplies(CONV)
    assert ratio == pytest.approx(2.25, rel=0.01)


def test_im2col_kn2row_same_multiplies():
    assert IM2COL.multiplies(CONV) == KN2ROW.multiplies(CONV)


def test_winograd_applicability():
    strided = ConvMeta(c_in=3, c_out=8, h1=28, h2=28, k1=3, k2=3, stride=2)
    rect = ConvMeta(c_in=3, c_out=8, h1=28, h2=28, k1=1, k2=7)
    assert not WINO_2_3.applicable(strided)
    assert not WINO_2_3.applicable(rect)
    assert KN2ROW.applicable(rect)
    assert [a.family for a in menu_for(rect)] == \
        [IM2COL.family, KN2ROW.family]


def test_node_cost_decomposition_positive():
    for algo in (IM2COL, KN2ROW, WINO_2_3, WINO_4_3):
        nc = node_cost(CONV, algo, 128, 128, spec=V5E)
        assert nc.total > 0
        assert 0 < nc.utilization <= 1.0
    # kn2row pays pad-and-accumulate, winograd pays transforms
    assert node_cost(CONV, KN2ROW, 128, 128, spec=V5E).transform_s > 0
    assert node_cost(CONV, WINO_2_3, 128, 128, spec=V5E).transform_s > 0
    assert node_cost(CONV, IM2COL, 128, 128, spec=V5E).transform_s == 0


# -------------------------------------------------------------- Table 2
def test_transition_costs_follow_table2_ordering():
    nxt = ConvMeta(c_in=128, c_out=128, h1=28, h2=28, k1=3, k2=3)
    # Toeplitz store duplicates K1K2 > 3-D tensor store.
    to_im2col = transition_cost(KN2ROW, IM2COL, nxt, 128, V5E)
    to_kn2row = transition_cost(IM2COL, KN2ROW, nxt, 128, V5E)
    assert to_im2col > to_kn2row
    # Winograd input layout costs the (m+r-1)^2/m^2 blowup.
    to_wino = transition_cost(IM2COL, WINO_2_3, nxt, 128, V5E)
    assert to_wino > to_kn2row
    # implicit-GEMM mode (beyond-paper) removes the Toeplitz duplication.
    implicit = transition_cost(KN2ROW, IM2COL, nxt, 128, V5E,
                               implicit_im2col=True)
    assert implicit < to_im2col
    # step ⑤: on-chip chaining removes the round trip entirely.
    assert transition_cost(KN2ROW, IM2COL, nxt, 128, V5E, on_chip=True) == 0


def test_fits_on_chip():
    assert fits_on_chip(1000, 1000, V5E)
    assert not fits_on_chip(10 ** 9, 10 ** 9, FPGA_LIKE)
