"""Int8 quantized overlay path: primitives, kernels, the precision PBQP
dimension, the accuracy gate, and cross-precision cache/tuning keying."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cnn import overlay
from repro.cnn.executor import (ExecutableCache, compile_plan,
                                executable_cache_key, forward, init_params)
from repro.cnn.models import vgg16
from repro.core.algorithms import IM2COL, KN2ROW, Algorithm, AlgoFamily
from repro.core.cost_model import V5E, V5E_INT8
from repro.core.graph import ConvMeta, Graph, LayerKind
from repro.core.mapper import lower_plan, map_network
from repro.core.quant import (calibrate_act_scales, layer_errors,
                              plan_mixed_precision)
from repro.kernels.common import (INT8_MAX, apply_epilogue, dequantize,
                                  pad_bias, quantize, requantize,
                                  weight_scales)
from repro.kernels.conv_im2col.ops import conv_im2col
from repro.kernels.gemm.ops import gemm
from repro.kernels.kn2row.ops import conv_kn2row

WINOGRAD = Algorithm(AlgoFamily.WINOGRAD, m=2, r=3)


def chain_graph(h=8, c=8):
    """INPUT -> 3x3 CONV -> 1x1 CONV -> OUTPUT (one fusable conv edge)."""
    g = Graph()
    i = g.add_node(LayerKind.INPUT, out_shape=(h, h, 3))
    c1 = g.add_node(LayerKind.CONV, conv=ConvMeta(3, c, h, h, 3, 3))
    c2 = g.add_node(LayerKind.CONV, conv=ConvMeta(c, c, h, h, 1, 1))
    o = g.add_node(LayerKind.OUTPUT, out_shape=(h, h, c))
    g.chain([i, c1, c2, o])
    return g, c1, c2


def fake_quant(x, scale):
    return dequantize(quantize(x, scale), scale)


# ---------------------------------------------------------------------------
# Primitives: quantize/dequantize/requantize, weight_scales, pad_bias,
# apply_epilogue validation + requantize variants.
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_and_saturation():
    x = jnp.array([-3.0, -0.01, 0.0, 0.01, 2.0, 5.0])
    q = quantize(x, 2.0 / INT8_MAX)
    assert q.dtype == jnp.int8
    assert int(q[0]) == -INT8_MAX and int(q[-1]) == INT8_MAX  # saturate
    err = jnp.abs(dequantize(q, 2.0 / INT8_MAX) - jnp.clip(x, -2.0, 2.0))
    assert float(jnp.max(err)) <= 2.0 / INT8_MAX / 2 + 1e-7


def test_weight_scales_per_output_channel():
    w = jnp.stack([jnp.full((3, 3, 4), 0.5), jnp.full((3, 3, 4), 2.0)],
                  axis=-1)                                  # (3,3,4,2)
    s = weight_scales(w)
    assert s.shape == (2,)
    np.testing.assert_allclose(np.asarray(s),
                               [0.5 / INT8_MAX, 2.0 / INT8_MAX])
    # All-zero channels get the epsilon floor, never a 0 divisor.
    assert float(weight_scales(jnp.zeros((1, 1, 1, 1)))[0]) > 0


def test_pad_bias_shapes_and_validation():
    b = jnp.arange(3.0)
    padded = pad_bias(b, 3, 8)
    assert padded.shape == (1, 8)
    np.testing.assert_allclose(np.asarray(padded)[0, :3], np.asarray(b))
    np.testing.assert_allclose(np.asarray(padded)[0, 3:], 0.0)
    assert pad_bias(None, 3, 8) is None
    with pytest.raises(AssertionError):
        pad_bias(jnp.zeros((4,)), 3, 8)                     # shape mismatch


def test_apply_epilogue_validation():
    y = jnp.ones((2, 2))
    with pytest.raises(ValueError, match="unknown epilogue"):
        apply_epilogue(y, "gelu")
    with pytest.raises(ValueError, match="needs a bias"):
        apply_epilogue(y, "bias")
    with pytest.raises(ValueError, match="needs a bias"):
        apply_epilogue(y, "bias_relu")


def test_apply_epilogue_requantize_variants():
    acc = jnp.array([[-200, 50], [400, -10]], jnp.int32)
    scale = jnp.array([[0.01, 0.02]])
    bias = jnp.array([0.5, -0.5])
    out_scale = 0.05
    got = apply_epilogue(acc, "bias_relu", bias, scale=scale,
                         out_scale=out_scale)
    want = requantize(jnp.maximum(acc * scale + bias, 0), out_scale)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Without out_scale the flush stays f32; scale dequantizes first.
    f32 = apply_epilogue(acc, "relu", scale=scale)
    assert f32.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(f32),
                               np.maximum(np.asarray(acc) * [[0.01, 0.02]], 0))


# ---------------------------------------------------------------------------
# Int8 kernels vs the dequantized f32 reference.
# ---------------------------------------------------------------------------

def test_int8_gemm_matches_dequantized_reference():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (16, 32), jnp.float32)
    b = jax.random.normal(k2, (32, 24), jnp.float32)
    in_scale = float(jnp.max(jnp.abs(a))) / INT8_MAX
    w_scale = weight_scales(b)
    out = gemm(quantize(a, in_scale), quantize(b, w_scale),
               interpret=True, scale=in_scale * w_scale)
    ref = fake_quant(a, in_scale) @ fake_quant(b, w_scale)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # Requantized output is int8 at the requested scale.
    q = gemm(quantize(a, in_scale), quantize(b, w_scale), interpret=True,
             scale=in_scale * w_scale, out_scale=0.1)
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q),
                                  np.asarray(requantize(ref, 0.1)))


@pytest.mark.parametrize("conv_fn", [conv_im2col, conv_kn2row],
                         ids=["im2col", "kn2row"])
def test_int8_conv_kernels_match_dequantized_reference(conv_fn):
    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (6, 6, 8), jnp.float32)
    w = jax.random.normal(kw, (3, 3, 8, 16), jnp.float32)
    in_scale = float(jnp.max(jnp.abs(x))) / INT8_MAX
    w_scale = weight_scales(w)
    from repro.kernels.conv_im2col.ref import conv_ref
    ref = conv_ref(fake_quant(x, in_scale), fake_quant(w, w_scale))
    out = conv_fn(quantize(x, in_scale), w=quantize(w, w_scale),
                  interpret=True, scale=in_scale * w_scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_overlay_int8_pallas_matches_emulation():
    """The true int8 kernels and the fake-quant emulation carry the same
    quantization error — the accuracy gate's measurement assumption."""
    kx, kw = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (6, 6, 8), jnp.float32)
    w = jax.random.normal(kw, (3, 3, 8, 8), jnp.float32)
    in_scale = float(jnp.max(jnp.abs(x))) / INT8_MAX
    for algo in (IM2COL, KN2ROW):
        kw_ = dict(precision="int8", in_scale=in_scale, epilogue="relu")
        got = overlay.apply_conv(x, w, algo, backend="pallas",
                                 interpret=True, **kw_)
        ref = overlay.apply_conv(x, w, algo, backend="lax", **kw_)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_overlay_int8_rejects_winograd_and_missing_scale():
    x = jnp.zeros((6, 6, 4))
    w = jnp.zeros((3, 3, 4, 4))
    with pytest.raises(ValueError, match="bf16-only"):
        overlay.apply_conv(x, w, WINOGRAD, precision="int8", in_scale=0.1)
    with pytest.raises(ValueError, match="in_scale"):
        overlay.apply_conv(x, w, IM2COL, precision="int8")
    with pytest.raises(ValueError, match="unknown precision"):
        overlay.apply_conv(x, w, IM2COL, precision="fp8")


# ---------------------------------------------------------------------------
# Precision as a PBQP dimension + lowering.
# ---------------------------------------------------------------------------

def test_map_network_quantize_emits_precisions():
    g = vgg16(res=8, scale=0.05)
    plan = map_network(g, quantize=True)
    convs = [n.id for n in g.conv_nodes()]
    assert set(plan.precisions) == set(convs)
    assert any(p == "int8" for p in plan.precisions.values())
    # int8 layers must be priced cheaper than their bf16 twin would be:
    # the joint solve only picks int8 when it wins, and V5E_INT8 doubles
    # peak MACs, so the quantized plan can never cost more.
    bf16 = map_network(g)
    assert plan.total_cost_s <= bf16.total_cost_s + 1e-12
    assert not bf16.precisions                 # unquantized plan: empty map


def test_int8_cost_model_predicts_speedup():
    assert V5E_INT8.peak_flops >= 1.5 * V5E.peak_flops
    assert V5E_INT8.dtype_bytes < V5E.dtype_bytes


def test_force_bf16_pins_and_lowering_is_bitwise_stable():
    g = vgg16(res=8, scale=0.05)
    plan = map_network(g, quantize=True)
    int8_nodes = [n for n, p in plan.precisions.items() if p == "int8"]
    demote = int8_nodes[:1]
    pinned = map_network(g, quantize=True, force_bf16=demote)
    for nid in demote:
        assert pinned.precisions[nid] == "bf16"
    # A demoted layer's lowering is identical to the all-bf16 plan's —
    # force_bf16 removes its int8 entries entirely, so its choice vector
    # (and the solved binding) matches the unquantized build.
    all_bf16 = map_network(g)
    for nid in demote:
        assert pinned.assignment[nid] == all_bf16.assignment[nid]
        assert pinned.dataflows[nid] == all_bf16.dataflows[nid]


def test_lower_plan_int8_requires_scales_and_rejects_winograd():
    g, c1, c2 = chain_graph()
    plan = map_network(g)
    plan.precisions = {c1: "int8"}
    with pytest.raises(ValueError, match="act_scales"):
        lower_plan(g, plan)
    plan.assignment[c1] = WINOGRAD
    with pytest.raises(ValueError, match="bf16-only"):
        lower_plan(g, plan, act_scales={c1: 0.1})


def test_fused_precision_edge():
    """int8 -> int8 single-successor NHWC edge: the producer requantizes
    into the consumer's scale, the edge carries int8, and the consumer
    skips its own input quantization."""
    g, c1, c2 = chain_graph()
    plan = map_network(g)
    plan.assignment[c1] = plan.assignment[c2] = IM2COL
    plan.precisions = {c1: "int8", c2: "int8"}
    scales = {c1: 0.02, c2: 0.03}
    # elide=False keeps the edge NHWC — the only store format precision
    # fusion rides (an elided Toeplitz edge stays a per-layer quantize).
    prog = lower_plan(g, plan, act_scales=scales, elide=False)
    assert prog.convs[c1].out_scale == pytest.approx(0.03)
    assert prog.convs[c2].in_quantized
    assert prog.transitions[(c1, c2)].precision == "int8"
    assert (c1, c2) in prog.quantized_edges
    # The compiled fused-edge program still matches the f32 reference to
    # within quantization error.
    params = init_params(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 3), jnp.float32)
    ref = forward(g, params, x)
    run = compile_plan(g, plan, use_pallas=True, interpret=True,
                       act_scales=scales, elide=False)
    got = run(params, x)
    rel = float(jnp.max(jnp.abs(got - ref)) / (jnp.max(jnp.abs(ref)) + 1e-12))
    assert rel < 0.1
    # An elided (Toeplitz) edge never fuses precision: each layer
    # quantizes its own input.
    elided = lower_plan(g, plan, act_scales=scales)
    assert elided.convs[c1].out_scale is None
    assert not elided.quantized_edges
    # Demoting the consumer breaks the fusion: the boundary reverts to a
    # plain f32 edge with no requantized producer output.
    plan.precisions = {c1: "int8", c2: "bf16"}
    prog2 = lower_plan(g, plan, act_scales=scales, elide=False)
    assert prog2.convs[c1].out_scale is None
    assert not prog2.quantized_edges


# ---------------------------------------------------------------------------
# Calibration + the accuracy gate.
# ---------------------------------------------------------------------------

def test_calibrate_act_scales_covers_all_convs():
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3), jnp.float32)
    scales = calibrate_act_scales(g, params, x)
    assert set(scales) == {n.id for n in g.conv_nodes()}
    assert all(s > 0 for s in scales.values())
    # First conv sees the raw input: scale = amax(x) / 127 exactly.
    first = min(scales)
    assert scales[first] == pytest.approx(
        float(jnp.max(jnp.abs(x))) / INT8_MAX)


def test_gate_every_int8_layer_within_tolerance():
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3), jnp.float32)
    report = plan_mixed_precision(g, params, x, tol=0.05)
    int8 = [n for n, p in report.plan.precisions.items() if p == "int8"]
    assert int8, "gate demoted everything on a well-behaved network"
    for nid in int8:
        assert report.errors[nid] <= report.tol
    assert report.precision_mix["int8"] == len(int8)
    # The gated plan compiles and tracks the f32 reference.
    run = compile_plan(g, report.plan, use_pallas=True, interpret=True,
                       act_scales=report.act_scales)
    ref = forward(g, params, x)
    np.testing.assert_allclose(np.asarray(run(params, x)), np.asarray(ref),
                               rtol=0.1, atol=0.05)


def test_gate_demotes_sensitive_layer():
    """An activation-outlier input makes the first conv's per-tensor scale
    useless (everything else quantizes to ~0): the gate must demote it
    back to bf16, bitwise-identically to the bf16 plan."""
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    x = np.array(jax.random.normal(jax.random.PRNGKey(1), (8, 8, 3)))
    x[0, 0, 0] = 1000.0                       # the deliberate outlier
    x = jnp.asarray(x)
    report = plan_mixed_precision(g, params, x, tol=0.05)
    first = min(n.id for n in g.conv_nodes())
    assert first in report.demoted
    assert report.plan.precisions[first] == "bf16"
    assert report.errors[first] > report.tol
    all_bf16 = map_network(g)
    assert report.plan.assignment[first] == all_bf16.assignment[first]
    assert report.plan.dataflows[first] == all_bf16.dataflows[first]


def test_layer_errors_isolated_and_small():
    g, c1, c2 = chain_graph()
    params = init_params(g, jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 8, 3), jnp.float32)
    scales = calibrate_act_scales(g, params, x)
    errs = layer_errors(g, params, x, scales)
    assert set(errs) == {c1, c2}
    assert all(0 <= e < 0.05 for e in errs.values())


# ---------------------------------------------------------------------------
# Cross-precision executable cache + tuning keys.
# ---------------------------------------------------------------------------

def test_executable_cache_distinguishes_precision():
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3), jnp.float32)
    report = plan_mixed_precision(g, params, x, tol=0.05)
    bf16_plan = map_network(g)
    cache = ExecutableCache()
    common = dict(use_pallas=True, interpret=True, cache=cache)
    run_q = compile_plan(g, report.plan, act_scales=report.act_scales,
                         **common)
    run_b = compile_plan(g, bf16_plan, **common)
    assert cache.stats() == {"entries": 2, "hits": 0, "misses": 2}
    # Same (graph, plan, bucket, options) at each precision: exact hits.
    again_q = compile_plan(g, report.plan, act_scales=report.act_scales,
                           **common)
    again_b = compile_plan(g, bf16_plan, **common)
    assert again_q is run_q and again_b is run_b
    assert cache.stats() == {"entries": 2, "hits": 2, "misses": 2}
    # Recalibration alone must recompile (scales are baked into the trace).
    other = {n: s * 2 for n, s in report.act_scales.items()}
    compile_plan(g, report.plan, act_scales=other, **common)
    assert cache.stats() == {"entries": 3, "hits": 2, "misses": 3}
    k_q = executable_cache_key(g, report.plan, use_pallas=True,
                               interpret=True,
                               act_scales=report.act_scales)
    k_b = executable_cache_key(g, bf16_plan, use_pallas=True, interpret=True)
    assert k_q != k_b


def test_tuning_record_precision_keys():
    from repro.core.autotune import (Binding, LayerTuning, TuningRecord,
                                     parse_record_key, record_key)
    conv = ConvMeta(8, 8, 8, 8, 3, 3)
    kb = record_key(conv, 2)
    kq = record_key(conv, 2, "int8")
    assert kq == kb + "#int8" and kb != kq
    assert parse_record_key(kb)[2] == "bf16"
    assert parse_record_key(kq) == parse_record_key(kb)[:2] + ("int8",)
    b = Binding("im2col", "NS", 128, 128, "reference")
    rec = TuningRecord({kq: LayerTuning(binding=b, measured_s=1e-3,
                                        candidates=[], batch=2,
                                        precision="int8")})
    # No cross-precision fallback in either direction.
    assert rec.lookup(conv, 2, "int8") is not None
    assert rec.lookup(conv, 2) is None
    assert rec.buckets_for(conv, "int8") == [2]
    assert rec.buckets_for(conv) == []
    # JSON round trip preserves the precision tag.
    rec2 = TuningRecord.from_json(rec.to_json())
    assert rec2.entries[kq].precision == "int8"
    assert rec2.lookup(conv, 2, "int8").binding == b


def test_engine_stats_report_precision_mix():
    from repro.serving.cnn_engine import CNNRequest, CNNServingEngine
    g = vgg16(res=8, scale=0.05)
    params = init_params(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3), jnp.float32)
    report = plan_mixed_precision(g, params, x, tol=0.05)
    eng = CNNServingEngine(g, params, report.plan, batch_size=2,
                           act_scales=report.act_scales)
    eng.submit(CNNRequest(rid=0, image=np.zeros((8, 8, 3), np.float32)))
    eng.run_until_done()
    mix = eng.stats()["precision"]
    assert mix["mix"] == report.precision_mix
    assert mix["calibrated"]
    assert mix["int8_layers"] == sorted(
        n for n, p in report.plan.precisions.items() if p == "int8")
    # A precision-free plan reports all-bf16, uncalibrated.
    eng2 = CNNServingEngine(g, params, map_network(g), batch_size=2)
    mix2 = eng2.stats()["precision"]
    assert mix2["mix"]["int8"] == 0 and not mix2["calibrated"]
