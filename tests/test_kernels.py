"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import Dataflow
from repro.kernels.conv_im2col.ops import conv_im2col
from repro.kernels.conv_im2col.ref import (conv_ref, conv_via_toeplitz_ref,
                                           toeplitz_ref)
from repro.kernels.gemm.ops import batched_gemm, gemm
from repro.kernels.gemm.ref import batched_gemm_ref, gemm_ref
from repro.kernels.kn2row.ops import conv_kn2row
from repro.kernels.kn2row.ref import kn2row_ref
from repro.kernels.winograd.ops import conv_winograd
from repro.kernels.winograd.ref import winograd_ref

RNG = np.random.default_rng(0)


def rnd(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# ------------------------------------------------------------------ GEMM
@pytest.mark.parametrize("mkn", [(62, 124, 64), (128, 128, 128),
                                 (200, 300, 100), (8, 512, 8),
                                 (1, 256, 131), (257, 129, 63)])
@pytest.mark.parametrize("df", list(Dataflow))
def test_gemm_all_dataflows_match_oracle(mkn, df):
    m, k, n = mkn
    a, b = rnd(m, k), rnd(k, n)
    out = gemm(a, b, dataflow=df, interpret=True)
    np.testing.assert_allclose(out, gemm_ref(a, b), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_dtypes(dtype):
    a, b = rnd(96, 160, dtype=dtype), rnd(160, 72, dtype=dtype)
    out = gemm(a, b, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gemm_ref(a, b), np.float32),
                               rtol=tol, atol=tol)


def test_batched_gemm():
    a, b = rnd(5, 62, 40), rnd(5, 40, 70)
    out = batched_gemm(a, b, interpret=True)
    np.testing.assert_allclose(out, batched_gemm_ref(a, b),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- im2col
CASES = [(14, 14, 8, 16, 3, 3, 1, "SAME"), (28, 28, 4, 8, 5, 5, 1, "SAME"),
         (15, 15, 3, 8, 3, 3, 2, "SAME"), (14, 14, 8, 8, 1, 1, 1, "SAME"),
         (16, 16, 6, 10, 7, 7, 2, "SAME"), (14, 14, 8, 16, 3, 3, 1, "VALID"),
         (10, 10, 6, 10, 1, 7, 1, "SAME")]


@pytest.mark.parametrize("case", CASES)
def test_conv_im2col_matches_lax(case):
    h, w_, ci, co, k1, k2, s, pad = case
    x, w = rnd(h, w_, ci), rnd(k1, k2, ci, co)
    got = conv_im2col(x, w, stride=s, padding=pad, interpret=True)
    want = conv_ref(x, w, stride=s, padding=pad)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_toeplitz_layout_matches_eq2():
    x, w = rnd(9, 9, 4), rnd(3, 3, 4, 6)
    t = toeplitz_ref(x, 3, 3, 1, "SAME")
    assert t.shape == (81, 36)      # (O1O2, K1K2Cin)
    np.testing.assert_allclose(conv_via_toeplitz_ref(x, w),
                               conv_ref(x, w), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- kn2row
@pytest.mark.parametrize("case", CASES)
def test_conv_kn2row_matches_lax(case):
    h, w_, ci, co, k1, k2, s, pad = case
    x, w = rnd(h, w_, ci), rnd(k1, k2, ci, co)
    want = conv_ref(x, w, stride=s, padding=pad)
    np.testing.assert_allclose(kn2row_ref(x, w, stride=s, padding=pad),
                               want, rtol=1e-4, atol=1e-4)
    got = conv_kn2row(x, w, stride=s, padding=pad, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- winograd
@pytest.mark.parametrize("case", [(14, 14, 8, 16, 3, 2, "SAME"),
                                  (12, 12, 4, 8, 3, 4, "SAME"),
                                  (14, 14, 8, 16, 3, 2, "VALID"),
                                  (13, 11, 5, 7, 3, 2, "SAME"),
                                  (14, 14, 4, 8, 5, 2, "SAME"),
                                  (12, 12, 3, 6, 7, 2, "SAME")])
def test_conv_winograd_matches_lax(case):
    h, w_, ci, co, k, m, pad = case
    x, w = rnd(h, w_, ci), rnd(k, k, ci, co)
    want = conv_ref(x, w, stride=1, padding=pad)
    if k == 3:
        np.testing.assert_allclose(winograd_ref(x, w, m=m, padding=pad),
                                   want, rtol=2e-3, atol=2e-3)
    got = conv_winograd(x, w, m=m, padding=pad, interpret=True)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_all_three_algorithms_agree():
    """The executor invariant: any plan computes the same convolution."""
    x, w = rnd(12, 12, 6), rnd(3, 3, 6, 9)
    a = conv_im2col(x, w, interpret=True)
    b = conv_kn2row(x, w, interpret=True)
    c = conv_winograd(x, w, m=2, interpret=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(a, c, rtol=2e-3, atol=2e-3)
