"""Compiled overlay engine: batched plan-vs-reference equivalence and
plan-honoring (the §3 invariant, now enforced on the compiled path).

* ``compile_plan(graph, plan)`` on a batch must match per-image eager
  ``forward`` AND a ``jax.lax.conv_general_dilated``-backed reference.
* The compiled program must invoke the overlay with exactly the algorithm
  and dataflow/(p1, p2) the ExecutionPlan assigned to each conv layer.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cnn import overlay
from repro.cnn.executor import compile_plan, forward, init_params
from repro.cnn.models import googlenet, vgg16
from repro.core.algorithms import IM2COL, KN2ROW, WINO_2_3, menu_for
from repro.core.cost_model import Dataflow
from repro.core.dse import identify_parameters
from repro.core.graph import LayerKind
from repro.core.mapper import lower_plan, map_network
from repro.kernels.common import apply_epilogue
from repro.kernels.conv_im2col.ref import conv_ref

RNG = np.random.default_rng(0)


def rnd(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


@pytest.fixture(scope="module")
def mapped_googlenet():
    g = googlenet(res=56, scale=0.25)
    hw = identify_parameters(g, max_dim=512)
    plan = map_network(g, hw=hw)
    params = init_params(g, jax.random.PRNGKey(0))
    return g, plan, params


@pytest.fixture(scope="module")
def mixed_plan(mapped_googlenet):
    """The mapped plan with algorithm diversity forced: cycle each conv
    through its applicable menu so all three families (and all three
    dataflows) appear — execution must stay semantically identical."""
    g, plan, _ = mapped_googlenet
    assignment, dataflows = {}, {}
    dfs = list(Dataflow)
    for i, nid in enumerate(sorted(plan.assignment)):
        menu = menu_for(g.nodes[nid].conv)
        assignment[nid] = menu[i % len(menu)]
        dataflows[nid] = dfs[i % len(dfs)]
    return dataclasses.replace(plan, assignment=assignment,
                               dataflows=dataflows)


def _lax_forward(graph, params, x):
    """Reference executor: same graph walk, conv replaced by lax.conv.
    Must honor the fused ``epilogue`` the executor now hands every conv;
    ``overlay.nhwc_conv`` adapts the NHWC oracle to the layout-carrying
    call contract (the executor may hand it a staged store format)."""
    @overlay.nhwc_conv
    def lax_conv(xi, w, algo, dataflow=Dataflow.NS, p1=128, p2=128, *,
                 stride=1, padding="SAME", epilogue="none", bias=None, **kw):
        y = conv_ref(xi, w, stride=stride, padding=padding)
        return apply_epilogue(y, epilogue, bias)
    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(overlay, "apply_conv", lax_conv)
        return forward(graph, params, x)


# ------------------------------------------------- batched overlay paths
@pytest.mark.parametrize("algo", [IM2COL, KN2ROW, WINO_2_3])
@pytest.mark.parametrize("df", list(Dataflow))
def test_overlay_batched_matches_lax_all_paths(algo, df):
    """Every algorithm family accepts (B, H, W, C) on both the reference
    and Pallas paths, under every dataflow block binding."""
    x, w = rnd(3, 14, 14, 6), rnd(3, 3, 6, 8)
    want = conv_ref(x, w)
    for use_pallas in (False, True):
        got = overlay.apply_conv(x, w, algo, df, 256, 128,
                                 use_pallas=use_pallas, interpret=True)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-3, atol=5e-3)
    # batch == stacked single images (rank polymorphism is consistent)
    per = jnp.stack([overlay.apply_conv(x[i], w, algo, df, 256, 128)
                     for i in range(x.shape[0])])
    batched = overlay.apply_conv(x, w, algo, df, 256, 128)
    np.testing.assert_allclose(np.asarray(batched), np.asarray(per),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------- compiled plan ≡ eager ≡ lax ref
@pytest.mark.parametrize("algo", [IM2COL, KN2ROW, WINO_2_3])
def test_compile_plan_batched_per_family(algo):
    """A fixed-algorithm "plan" per family: compiled batched execution
    matches per-image eager forward and the lax reference."""
    g = vgg16(res=16, scale=0.05)          # 3x3 stride-1: all families apply
    params = init_params(g, jax.random.PRNGKey(2))
    xb = rnd(3, 16, 16, 3)
    run = compile_plan(g, default_algo=algo)
    got = run(params, xb)
    per = jnp.stack([forward(g, params, xb[i], default_algo=algo)
                     for i in range(xb.shape[0])])
    np.testing.assert_allclose(np.asarray(got), np.asarray(per),
                               rtol=1e-4, atol=1e-5)
    ref = _lax_forward(g, params, xb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)


def test_compile_plan_batched_matches_eager_and_lax(mapped_googlenet):
    """The mapped reduced-GoogleNet plan, batched through one compiled
    program, equals the per-image eager loop and the lax reference."""
    g, plan, params = mapped_googlenet
    xb = rnd(3, 56, 56, 3)
    run = compile_plan(g, plan)
    got = run(params, xb)
    assert got.shape == (3, 1000)
    per = jnp.stack([forward(g, params, xb[i], plan=plan)
                     for i in range(xb.shape[0])])
    np.testing.assert_allclose(np.asarray(got), np.asarray(per),
                               rtol=1e-4, atol=1e-5)
    ref = _lax_forward(g, params, xb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)


def test_compile_plan_mixed_algorithms_still_equivalent(mapped_googlenet,
                                                        mixed_plan):
    """Algorithm AND dataflow switching are semantically invisible on the
    compiled batched path (the §3 invariant)."""
    g, _, params = mapped_googlenet
    xb = rnd(2, 56, 56, 3)
    got = compile_plan(g, mixed_plan)(params, xb)
    ref = _lax_forward(g, params, xb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)


def test_compile_plan_single_image_rank(mapped_googlenet):
    g, plan, params = mapped_googlenet
    x = rnd(56, 56, 3)
    run = compile_plan(g, plan)
    y = run(params, x)
    assert y.shape == (1000,)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(forward(g, params, x, plan=plan)),
                               rtol=1e-4, atol=1e-5)


# ----------------------------------------------------- plan honoring
def test_compiled_execution_honors_plan(mapped_googlenet, mixed_plan,
                                        monkeypatch):
    """Trace the overlay entry point: the compiled program must hit every
    conv layer with exactly the plan-assigned (algorithm, dataflow, p1, p2).
    The trace order is the executor's topo walk, so the call sequence maps
    1:1 onto conv nodes in topological order."""
    g, _, params = mapped_googlenet
    plan = mixed_plan
    seen = []
    real = overlay.apply_conv

    def spy(x, w, algo, dataflow=Dataflow.NS, p1=128, p2=128, **kw):
        seen.append((algo, dataflow, p1, p2))
        return real(x, w, algo, dataflow, p1, p2, **kw)

    monkeypatch.setattr(overlay, "apply_conv", spy)
    run = compile_plan(g, plan)
    run(params, rnd(2, 56, 56, 3))        # first call traces → spy fires

    conv_ids = [nid for nid in g.topo_order()
                if g.nodes[nid].kind is LayerKind.CONV]
    assert len(seen) == len(conv_ids)
    lowering = lower_plan(g, plan)
    for nid, (algo, df, p1, p2) in zip(conv_ids, seen):
        low = lowering[nid]
        assert algo == plan.assignment[nid] == low.algo
        assert df == plan.dataflows[nid] == low.dataflow
        assert (p1, p2) == (plan.p1, plan.p2)
    # the mixed plan exercises algorithm AND dataflow switching for real
    assert len({a.family for (a, _, _, _) in seen}) == 3
    assert len({d for (_, d, _, _) in seen}) == 3


def test_eager_forward_honors_plan(mapped_googlenet, monkeypatch):
    """Same invariant on the eager path (shared lowering spec)."""
    g, plan, params = mapped_googlenet
    seen = []
    real = overlay.apply_conv

    def spy(x, w, algo, dataflow=Dataflow.NS, p1=128, p2=128, **kw):
        seen.append((algo, dataflow))
        return real(x, w, algo, dataflow, p1, p2, **kw)

    monkeypatch.setattr(overlay, "apply_conv", spy)
    forward(g, params, rnd(56, 56, 3), plan=plan)
    conv_ids = [nid for nid in g.topo_order()
                if g.nodes[nid].kind is LayerKind.CONV]
    assert seen == [(plan.assignment[nid], plan.dataflows[nid])
                    for nid in conv_ids]


def test_fc_chain_is_rank_polymorphic():
    """FC→FC graphs must batch too: even ranks carry the batch dim."""
    from repro.cnn.models import _start
    from repro.core.graph import LayerKind as LK
    g, cur = _start(8, 4)
    cur = cur.conv(6, 3, 3, name="c").global_pool().fc(10, name="fc1")
    cur = cur.fc(5, name="fc2")
    out = g.add_node(LK.OUTPUT, name="output", out_shape=(1, 1, 5))
    g.add_edge(cur.node, out)
    params = init_params(g, jax.random.PRNGKey(3))
    xb = rnd(3, 8, 8, 4)
    got = compile_plan(g)(params, xb)
    assert got.shape == (3, 5)
    per = jnp.stack([forward(g, params, xb[i]) for i in range(3)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(per),
                               rtol=1e-4, atol=1e-5)


# ----------------------------------------------------- serving engine
def test_cnn_serving_engine_batches(mapped_googlenet):
    from repro.serving.cnn_engine import CNNRequest, CNNServingEngine
    g, plan, params = mapped_googlenet
    eng = CNNServingEngine(g, params, plan, batch_size=2)
    imgs = [np.asarray(rnd(56, 56, 3)) for _ in range(3)]
    for rid, img in enumerate(imgs):
        eng.submit(CNNRequest(rid=rid, image=img))
    # mismatched requests are rejected at submit (validated against the
    # graph's input shape), never crashing a tick — even as first submit
    for bad in (np.zeros((64, 64, 3), np.float32),
                np.zeros((1, 56, 56, 3), np.float32)):
        with pytest.raises(ValueError, match="graph input shape"):
            eng.submit(CNNRequest(rid=99, image=bad))
    out = eng.run_until_done()
    assert sorted(out) == [0, 1, 2]       # 3 requests > 2 slots → two ticks
    for rid, img in enumerate(imgs):
        want = forward(g, params, jnp.asarray(img), plan=plan)
        np.testing.assert_allclose(out[rid], np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
