"""Activation-sharding policy plumbing.

The model code is mesh-agnostic; launchers install a policy (batch axes +
sequence axis) before tracing, and the per-layer residual stream gets a
with_sharding_constraint so GSPMD keeps saved activations (scan carries,
remat residuals) sequence-sharded — Megatron-style sequence parallelism.
Without this, 64-layer × 12k-wide models save unsharded (B, S, d) residuals
per layer and blow past 16 GB/chip.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ActivationPolicy:
    batch_axes: Tuple[str, ...]      # e.g. ("pod", "data")
    seq_axis: Optional[str]          # "model" for sequence parallelism
    batch_divisor: int               # product of batch axis sizes
    seq_divisor: int                 # size of the seq axis
    model_divisor: int = 1           # size of the model axis (TP)


_POLICY: Optional[ActivationPolicy] = None


def set_activation_policy(policy: Optional[ActivationPolicy]) -> None:
    global _POLICY
    _POLICY = policy


def policy_from_mesh(mesh, seq_parallel: bool = True) -> ActivationPolicy:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bdiv = 1
    for a in batch_axes:
        bdiv *= mesh.shape[a]
    mdiv = mesh.shape.get("model", 1)
    sdiv = mdiv if seq_parallel else 1
    return ActivationPolicy(batch_axes=batch_axes,
                            seq_axis="model" if seq_parallel else None,
                            batch_divisor=bdiv, seq_divisor=sdiv,
                            model_divisor=mdiv)


@contextlib.contextmanager
def activation_policy(policy: Optional[ActivationPolicy]):
    global _POLICY
    prev = _POLICY
    _POLICY = policy
    try:
        yield
    finally:
        _POLICY = prev


def gather_layer_params(layer_params):
    """Streamed-FSDP weight gather: constrain each weight leaf of ONE
    layer's params to be replicated over the data axis (TP sharding on the
    model axis intact) right before use.

    Without this, GSPMD is free to keep the contracting dim data-sharded
    and complete matmuls with activation all-reduces over the data axis —
    measured at ~27 GB/layer/chip on qwen-14b train (§Perf log). With it,
    XLA emits one per-layer weight all-gather (params/model_axis bytes) and
    the activation all-reduces disappear. Memory stays bounded: only the
    current scan step's layer is ever gathered.
    """
    pol = _POLICY
    if pol is None or not pol.batch_axes:
        return layer_params

    def f(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            return leaf
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        nd = leaf.ndim
        spec = [None] * nd
        if any(k in name for k in ("w_gate", "w_up", "w_down")) and nd >= 3:
            if leaf.shape[nd - 3] % pol.model_divisor == 0:
                spec[nd - 3] = "model"       # experts stay EP-sharded
        elif name.endswith("/w"):
            if leaf.shape[nd - 1] % pol.model_divisor == 0:
                spec[nd - 1] = "model"       # TP out-dim intact
            elif leaf.shape[nd - 2] % pol.model_divisor == 0:
                spec[nd - 2] = "model"
        else:
            return leaf
        return jax.lax.with_sharding_constraint(leaf, P(*spec))

    return jax.tree_util.tree_map_with_path(f, layer_params)


def constrain_residual(x: jax.Array) -> jax.Array:
    """Apply the activation policy to a (B, S, d) residual-stream tensor.
    No-op when no policy is installed or dims don't divide."""
    pol = _POLICY
    if pol is None or x.ndim != 3:
        return x
    b, s, _ = x.shape
    b_ax = pol.batch_axes if (pol.batch_axes and
                              b % pol.batch_divisor == 0 and b > 1) else None
    s_ax = pol.seq_axis if (pol.seq_axis and s % pol.seq_divisor == 0
                            and s > 1) else None
    if b_ax is None and s_ax is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(b_ax, s_ax, None))


def constrain_qkv(q, k, v):
    """Attention-strategy switch (REPRO_ATTN_SHARD):

    * "seq" (default/baseline): q/k/v inherit the sequence-sharded residual
      — context-parallel attention; backward emits dk/dv partial-sum
      all-reduces over the model axis (~5.4 GB f32 per layer measured on
      qwen-14b train, §Perf).
    * "heads": shard q on the head dim over the model axis (uneven heads
      padded by GSPMD), replicate k/v heads — attention becomes fully local
      per shard; only the output projection's partial-sum remains.
    """
    pol = _POLICY
    mode = os.environ.get("REPRO_ATTN_SHARD", "seq")
    if pol is None or mode != "heads" or q.ndim != 4:
        return q, k, v
    b, s, h, d = q.shape
    b_ax = pol.batch_axes if (pol.batch_axes and b % pol.batch_divisor == 0
                              and b > 1) else None
    try:
        q = jax.lax.with_sharding_constraint(
            q, P(b_ax, None, "model", None))
        k = jax.lax.with_sharding_constraint(k, P(b_ax, None, None, None))
        v = jax.lax.with_sharding_constraint(v, P(b_ax, None, None, None))
    except Exception:       # uneven-sharding rejection → keep baseline
        pass
    return q, k, v


def constrain_decode_q(q):
    """Decode attention: align q's head_dim sharding with the (head_dim-
    sharded) KV cache so GSPMD contracts hd per-shard and all-reduces the
    small partial scores instead of all-gathering the ~GB cache
    (§Perf hillclimb 5)."""
    pol = _POLICY
    if pol is None or q.ndim != 4 or q.shape[1] != 1:
        return q
    b = q.shape[0]
    b_ax = pol.batch_axes if (pol.batch_axes and b % pol.batch_divisor == 0
                              and b > 1) else None
    if q.shape[-1] % pol.model_divisor:
        return q
    return jax.lax.with_sharding_constraint(
        q, P(b_ax, None, None, "model"))
