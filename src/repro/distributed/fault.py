"""Fault tolerance & straggler mitigation — serving-path primitives plus
the multi-host control-plane logic.

This container has one real device, so every *mechanism* here is
deterministic, unit-testable logic that single-host drivers (today: the
CNN serving engine) exercise for real:

  * ``DeviceFault`` / ``TickFault`` / ``FaultPlan`` — seeded,
    deterministic fault injection for the serving tick loop.
    ``CNNServingEngine(fault_plan=...)`` consults the plan by global
    dispatch index: a planned fault fails a tick's first N attempts
    (surfacing either at dispatch or at completion, like a real async
    accelerator fault) or delays its readiness (a straggling device).
    The engine wraps dispatch in a bounded retry-with-backoff loop; a
    tick that exhausts retries fails its requests cleanly.
  * ``robust_zscore`` — the median/MAD statistic behind
    ``StragglerMonitor``, exported on its own because the serving
    engine's degrade controller reuses it to spot service-time spikes
    (a straggling tick is the single-host analogue of a straggling
    host).
  * ``StragglerMonitor`` — per-host step-time tracking over that
    statistic; persistent offenders are proposed for eviction (which
    then flows through ``ElasticPlanner``).
  * ``HealthTracker`` — heartbeat bookkeeping; hosts that miss
    ``max_missed`` beats are declared dead.
  * ``ElasticPlanner`` — given the surviving host set, produce the
    largest valid (data, model) mesh that preserves the model axis (TP
    must stay intact; data shrinks), plus the restore reshard plan.
  * ``run_with_retries`` — the generic bounded-retry supervisor loop
    (run step; on failure restore from the last commit and replay). The
    serving engine's per-tick retry loop is the same contract scoped to
    one dispatch: bounded attempts, deterministic replay from retained
    state (the pinned staging buffer), clean failure when exhausted.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "DeviceFault", "TickFault", "FaultPlan", "robust_zscore",
    "StragglerMonitor", "HealthTracker", "HostState", "MeshPlan",
    "ElasticPlanner", "run_with_retries",
]


# --------------------------------------------------------- fault injection
class DeviceFault(RuntimeError):
    """An injected (or emulated) device-side failure of one dispatch
    attempt. The serving engine's retry loop catches exactly this type —
    deterministic injection never masks real bugs, which still
    propagate."""


@dataclasses.dataclass(frozen=True)
class TickFault:
    """Fault schedule for ONE tick (one global dispatch index).

    ``failures`` consecutive attempts fail before the tick can succeed;
    whether each failure surfaces at *dispatch* (the launch call raises)
    or at *completion* (the async result turns out bad when blocked on —
    how a real accelerator fault usually presents) is picked by
    ``at_dispatch``. ``delay_s`` postpones the tick's device readiness
    without failing it — a straggler, visible to the engine's
    service-time EMAs and its degrade controller's spike detector."""
    failures: int = 0
    delay_s: float = 0.0
    at_dispatch: bool = False


class FaultPlan:
    """Deterministic fault schedule keyed by global dispatch index.

    Plans are plain data — build one explicitly (``FaultPlan({3:
    TickFault(failures=1)})``), or generate one reproducibly with
    ``FaultPlan.seeded``. The engine asks ``get(tick_index)`` once per
    dispatched tick; warmup ticks never consume indices."""

    def __init__(self, faults: Mapping[int, TickFault]) -> None:
        self.faults: Dict[int, TickFault] = {
            int(k): v for k, v in faults.items()}

    def get(self, tick_index: Optional[int]) -> Optional[TickFault]:
        if tick_index is None:
            return None
        return self.faults.get(tick_index)

    def __len__(self) -> int:
        return len(self.faults)

    def offset(self, n: int) -> "FaultPlan":
        """A copy of this plan shifted ``n`` dispatch indices later
        (negative ``n`` shifts earlier; faults pushed below index 0 drop).
        Lets a schedule authored relative to an event — e.g. "one failure
        on each of the first two ticks after the hot-swap" — be pinned to
        the absolute dispatch index where that event lands in a trace."""
        return FaultPlan({k + n: v for k, v in self.faults.items()
                          if k + n >= 0})

    @classmethod
    def seeded(cls, seed: int, n_ticks: int,
               fail_rate: float = 0.0, failures: int = 1,
               delay_rate: float = 0.0, delay_s: float = 0.0,
               at_dispatch: bool = False) -> "FaultPlan":
        """Reproducible random plan over the first ``n_ticks`` dispatch
        indices: each tick independently fails (``fail_rate``, with
        ``failures`` consecutive bad attempts) and/or straggles
        (``delay_rate`` × ``delay_s``). Same seed ⇒ same plan, so chaos
        benchmarks are replayable."""
        rng = random.Random(seed)
        faults: Dict[int, TickFault] = {}
        for t in range(n_ticks):
            fail = rng.random() < fail_rate
            lag = rng.random() < delay_rate
            if fail or lag:
                faults[t] = TickFault(failures=failures if fail else 0,
                                      delay_s=delay_s if lag else 0.0,
                                      at_dispatch=at_dispatch)
        return cls(faults)


def robust_zscore(value: float, samples: Sequence[float]) -> float:
    """Median/MAD z-score of ``value`` against ``samples`` — the robust
    statistic ``StragglerMonitor`` applies per host, exported standalone
    so the serving engine's degrade controller can apply it to tick
    service times. MAD units (no 1.4826 normal-consistency factor): a
    threshold ``k`` here means exactly ``value > median + k * MAD``."""
    ts = sorted(samples)
    n = len(ts)
    if n == 0:
        return 0.0
    med = ts[n // 2]
    mad = sorted(abs(t - med) for t in ts)[n // 2] or 1e-9
    return (value - med) / mad


# ------------------------------------------------------------ health plane
@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    missed: int = 0
    alive: bool = True


class HealthTracker:
    def __init__(self, n_hosts: int, beat_interval_s: float = 10.0,
                 max_missed: int = 3) -> None:
        now = 0.0
        self.hosts = {i: HostState(i, now) for i in range(n_hosts)}
        self.interval = beat_interval_s
        self.max_missed = max_missed

    def beat(self, host_id: int, t: float) -> None:
        h = self.hosts[host_id]
        h.last_beat = t
        h.missed = 0

    def sweep(self, t: float) -> List[int]:
        """Advance the failure detector; returns newly-dead host ids."""
        newly_dead = []
        for h in self.hosts.values():
            if not h.alive:
                continue
            h.missed = int((t - h.last_beat) // self.interval)
            if h.missed >= self.max_missed:
                h.alive = False
                newly_dead.append(h.host_id)
        return newly_dead

    def alive_hosts(self) -> List[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int
    pods: int = 1

    @property
    def devices(self) -> int:
        return self.data * self.model * self.pods


class ElasticPlanner:
    """Re-mesh policy: model (TP) axis is load-bearing — weights are
    sharded across it — so it is preserved; the data axis shrinks to the
    largest power-of-two supported by surviving hosts. Batch is kept by
    raising per-device microbatches (noted in the plan)."""

    def __init__(self, devices_per_host: int, model_axis: int) -> None:
        self.devices_per_host = devices_per_host
        self.model_axis = model_axis

    def plan(self, n_alive_hosts: int, global_batch: int
             ) -> Tuple[MeshPlan, Dict[str, int]]:
        total = n_alive_hosts * self.devices_per_host
        if total < self.model_axis:
            raise RuntimeError(
                f"{total} devices cannot host model axis {self.model_axis}")
        data = total // self.model_axis
        # largest power of two ≤ data (keeps collectives ring-friendly)
        data = 2 ** int(math.log2(data)) if data else 1
        plan = MeshPlan(data=data, model=self.model_axis)
        micro_scale = max(1, global_batch // max(plan.data, 1))
        return plan, {"microbatch_per_device": micro_scale,
                      "dropped_devices": total - plan.devices}


class StragglerMonitor:
    """Robust per-host step-time tracking over ``robust_zscore``: a host
    is an offender when its step time's z-score against the cohort
    exceeds ``k`` for ``patience`` consecutive steps. The serving
    engine's degrade controller applies the same statistic to its own
    tick service-time history (one "host", spikes over time instead of
    across hosts)."""

    def __init__(self, n_hosts: int, k: float = 4.0, patience: int = 3):
        self.k = k
        self.patience = patience
        self.offense: Dict[int, int] = {i: 0 for i in range(n_hosts)}

    def observe(self, step_times: Dict[int, float]) -> List[int]:
        ts = list(step_times.values())
        evict = []
        for host, t in step_times.items():
            if robust_zscore(t, ts) > self.k:
                self.offense[host] = self.offense.get(host, 0) + 1
                if self.offense[host] >= self.patience:
                    evict.append(host)
            else:
                self.offense[host] = 0
        return evict


def run_with_retries(step_fn: Callable[[int], None],
                     save_fn: Callable[[int], None],
                     restore_fn: Callable[[], int],
                     n_steps: int,
                     checkpoint_every: int = 50,
                     max_restarts: int = 3,
                     failure_injector: Optional[Callable[[int], None]] = None
                     ) -> Dict[str, int]:
    """Bounded-retry supervisor: run ``n_steps``; on exception restore +
    replay from the last commit; give up past ``max_restarts``. This is
    the whole-loop form of the contract the serving engine applies per
    tick (``CNNServingEngine(max_retries=, retry_backoff_s=)``): retained
    state makes the replay exact — a committed checkpoint here, the
    pinned staging buffer there — and exhaustion fails cleanly instead
    of wedging.

    ``restore_fn`` returns the step to resume from (last committed + 1).
    ``failure_injector(step)`` may raise to simulate node loss (tests;
    the serving analogue is ``FaultPlan``).
    """
    restarts = 0
    step = 0
    while step < n_steps:
        try:
            if failure_injector is not None:
                failure_injector(step)
            step_fn(step)
            if (step + 1) % checkpoint_every == 0:
                save_fn(step + 1)
            step += 1
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore_fn()
    return {"completed": step, "restarts": restarts}
