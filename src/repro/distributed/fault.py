"""Fault tolerance & straggler mitigation — the control-plane logic.

This container has one real device, so the *mechanisms* (what a 1000-node
deployment needs) are implemented as deterministic, unit-testable logic
plus single-host drivers:

  * ``HealthTracker`` — heartbeat bookkeeping; hosts that miss
    ``max_missed`` beats are declared dead.
  * ``ElasticPlanner`` — given the surviving host set, produce the largest
    valid (data, model) mesh that preserves the model axis (TP must stay
    intact; data shrinks), plus the checkpoint-restore reshard plan.
  * ``StragglerMonitor`` — per-step duration tracking with a robust
    z-score; persistent offenders are proposed for eviction (which then
    flows through ElasticPlanner).
  * ``run_with_retries`` — the supervisor loop: run step; on simulated/real
    failure, restore from the last committed checkpoint and continue. The
    deterministic data pipeline (pure function of step) makes the replay
    exact.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    missed: int = 0
    alive: bool = True


class HealthTracker:
    def __init__(self, n_hosts: int, beat_interval_s: float = 10.0,
                 max_missed: int = 3) -> None:
        now = 0.0
        self.hosts = {i: HostState(i, now) for i in range(n_hosts)}
        self.interval = beat_interval_s
        self.max_missed = max_missed

    def beat(self, host_id: int, t: float) -> None:
        h = self.hosts[host_id]
        h.last_beat = t
        h.missed = 0

    def sweep(self, t: float) -> List[int]:
        """Advance the failure detector; returns newly-dead host ids."""
        newly_dead = []
        for h in self.hosts.values():
            if not h.alive:
                continue
            h.missed = int((t - h.last_beat) // self.interval)
            if h.missed >= self.max_missed:
                h.alive = False
                newly_dead.append(h.host_id)
        return newly_dead

    def alive_hosts(self) -> List[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int
    pods: int = 1

    @property
    def devices(self) -> int:
        return self.data * self.model * self.pods


class ElasticPlanner:
    """Re-mesh policy: model (TP) axis is load-bearing — weights are
    sharded across it — so it is preserved; the data axis shrinks to the
    largest power-of-two supported by surviving hosts. Batch is kept by
    raising per-device microbatches (noted in the plan)."""

    def __init__(self, devices_per_host: int, model_axis: int) -> None:
        self.devices_per_host = devices_per_host
        self.model_axis = model_axis

    def plan(self, n_alive_hosts: int, global_batch: int
             ) -> Tuple[MeshPlan, Dict[str, int]]:
        total = n_alive_hosts * self.devices_per_host
        if total < self.model_axis:
            raise RuntimeError(
                f"{total} devices cannot host model axis {self.model_axis}")
        data = total // self.model_axis
        # largest power of two ≤ data (keeps collectives ring-friendly)
        data = 2 ** int(math.log2(data)) if data else 1
        plan = MeshPlan(data=data, model=self.model_axis)
        micro_scale = max(1, global_batch // max(plan.data, 1))
        return plan, {"microbatch_per_device": micro_scale,
                      "dropped_devices": total - plan.devices}


class StragglerMonitor:
    """Robust per-host step-time tracking. A host is an offender when its
    step time exceeds median + k·MAD for ``patience`` consecutive steps."""

    def __init__(self, n_hosts: int, k: float = 4.0, patience: int = 3):
        self.k = k
        self.patience = patience
        self.offense: Dict[int, int] = {i: 0 for i in range(n_hosts)}

    def observe(self, step_times: Dict[int, float]) -> List[int]:
        ts = sorted(step_times.values())
        n = len(ts)
        med = ts[n // 2]
        mad = sorted(abs(t - med) for t in ts)[n // 2] or 1e-9
        evict = []
        for host, t in step_times.items():
            if t > med + self.k * mad:
                self.offense[host] = self.offense.get(host, 0) + 1
                if self.offense[host] >= self.patience:
                    evict.append(host)
            else:
                self.offense[host] = 0
        return evict


def run_with_retries(step_fn: Callable[[int], None],
                     save_fn: Callable[[int], None],
                     restore_fn: Callable[[], int],
                     n_steps: int,
                     checkpoint_every: int = 50,
                     max_restarts: int = 3,
                     failure_injector: Optional[Callable[[int], None]] = None
                     ) -> Dict[str, int]:
    """Supervisor: run ``n_steps``; on exception restore + replay.

    ``restore_fn`` returns the step to resume from (last committed + 1).
    ``failure_injector(step)`` may raise to simulate node loss (tests).
    """
    restarts = 0
    step = 0
    while step < n_steps:
        try:
            if failure_injector is not None:
                failure_injector(step)
            step_fn(step)
            if (step + 1) % checkpoint_every == 0:
                save_fn(step + 1)
            step += 1
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore_fn()
    return {"completed": step, "restarts": restarts}
