"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Strategy (the baseline the §Perf hillclimbs start from):
  * weights: TP on the model axis (column-split d_ff / heads / experts) ×
    FSDP on the data axis (row-split) — ZeRO-3-style, so the 100B-400B
    configs fit 16 GB/chip;
  * activations: batch on (pod, data);
  * decode KV caches: batch on data, sequence on model (sequence-parallel
    KV — softmax partial-reductions become all-reduces on the model axis);
  * optimizer states inherit the parameter sharding.

Rules are name-based over the param tree paths, with divisibility-aware
fallbacks (uneven dims still shard — GSPMD pads — but we prefer axes that
divide exactly).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_shard_count(mesh: Mesh) -> int:
    """Number of ways the batch dimension splits on ``mesh`` — the product
    of the data-parallel axis sizes (1 when the mesh has no data axes).
    This is the divisor every data-sharded batch must respect: jit input
    shardings reject uneven partitions, so batch producers (the CNN
    serving engine's bucket ladder, the LM input pipeline) size batches in
    multiples of it."""
    return _axis_size(mesh, data_axes(mesh) or None)


def batch_input_sharding(mesh: Mesh, rank: int = 4) -> NamedSharding:
    """``NamedSharding`` for a rank-``rank`` batched input whose leading
    dimension splits across the mesh's data axes (every other dimension
    replicated) — the placement ``compile_plan(mesh=...)`` pins on its
    batched image input. A mesh with no data axes yields the replicated
    spec.

    Safe to combine with ``jax.jit(..., donate_argnums=)``: a sharded
    donated argument aliases only its *per-chip* buffers, and because
    this sharding fixes both placement and layout at jit time, every tick
    of a serving loop lands its freshly-transferred input in the same
    per-chip arrangement — donation then lets XLA reuse those buffers
    across ticks instead of accumulating one live input per in-flight
    dispatch."""
    dp = data_axes(mesh)
    return NamedSharding(mesh, P(dp if dp else None,
                                 *([None] * (rank - 1))))


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return "/".join(out)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    axes = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def param_spec(path_s: str, shape: Tuple[int, ...], mesh: Mesh,
               fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf. Axes are only assigned when
    the dimension divides the axis size exactly (jit argument shardings
    reject uneven partitions)."""
    axes: list = [None] * len(shape)
    fsdp_axis = "data" if (fsdp and "data" in mesh.axis_names) else None

    def put(dim: int, axis: Optional[str]):
        if axis is not None and 0 <= dim < len(shape) \
                and axes[dim] is None \
                and shape[dim] % _axis_size(mesh, axis) == 0:
            axes[dim] = axis

    nd = len(shape)
    if "embed/table" in path_s or "lm_head/table" in path_s:
        # (vocab, d): vocab → model, d → data (FSDP); fall back to sharding
        # d on model when the vocab doesn't divide (e.g. 50280).
        put(0, "model")
        if axes[0] is None:
            put(1, "model")      # odd vocab (e.g. 50280): TP lands on d
        else:
            put(1, fsdp_axis)
    elif any(k in path_s for k in ("w_gate", "w_up", "w_down")) and nd >= 3:
        # Expert-stacked (E, d, f): E → model (EP), d/f row → data (FSDP).
        put(nd - 3, "model")
        put(nd - 2, fsdp_axis)
    elif path_s.endswith("/w") and nd >= 2:
        # Generic 2-D projection (stacked under L/group dims): last two dims
        # are (in, out): out → model (TP), in → data (FSDP).
        put(nd - 1, "model")
        put(nd - 2, fsdp_axis)
        if axes[nd - 1] is None:       # odd out-dim: TP on the in-dim
            put(nd - 2, "model")
    elif path_s.endswith("conv_w") and nd >= 2:
        put(nd - 1, "model")        # depthwise channels
    elif nd >= 1 and shape[-1] >= 1024:
        put(nd - 1, "model")        # big vectors (norm scales stay small)
    return P(*axes)


def params_shardings(param_shapes: PyTree, mesh: Mesh,
                     fsdp: bool = True) -> PyTree:
    def f(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh, fsdp)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, param_shapes)


def batch_shardings(batch_shapes: PyTree, mesh: Mesh) -> PyTree:
    dp = data_axes(mesh)

    def f(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if leaf.shape[0] % _axis_size(mesh, dp) == 0:
            axes = [dp] + [None] * (leaf.ndim - 1)
        elif len(dp) > 1 and leaf.shape[0] % _axis_size(mesh, dp[:1]) == 0:
            axes = [dp[:1]] + [None] * (leaf.ndim - 1)
        else:
            axes = [None] * leaf.ndim
        return NamedSharding(mesh, P(*axes))
    return jax.tree_util.tree_map_with_path(f, batch_shapes)


def cache_shardings(cache_shapes: PyTree, mesh: Mesh) -> PyTree:
    """Decode caches. Leaves are stacked (L..., B, S, ...) for attention,
    (L..., B, ...) for SSM states. Heuristic: shard the batch dim on data
    (if > 1) and the longest remaining dim on model (sequence-parallel KV /
    state channels)."""
    dp = data_axes(mesh)

    def f(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        axes: list = [None] * len(shape)
        # Find the batch dim: first dim after the leading stack dims.
        # Stack dims come from (ng, attn_every) or (L,) — identified as the
        # leading dims before a dim that matches no stacking… simplest: the
        # caches are built with known layouts; batch is dim 1 for (L, B, …)
        # and dim 2 for (ng, k, B, …).
        if "mamba" in p or "dense" in p:
            b_dim = 2 if len(shape) >= 5 else 1
        else:
            b_dim = 1
        if "attn" in p and "dense" in p:
            b_dim = 2
        # locate batch dim robustly: the first dim ≥ stack prefix whose
        # position precedes the long sequence dim.
        if shape[b_dim] > 1 and shape[b_dim] % _axis_size(mesh, dp) == 0:
            axes[b_dim] = dp
        # Model axis on the largest remaining dim (sequence-parallel KV).
        # A/B'd against head_dim-sharded caches in §Perf hillclimb 5: the
        # S-sharded layout measured strictly better (the partitioner
        # gathers K either way; hd-sharding adds transposed copies).
        cand = [(d, i) for i, d in enumerate(shape)
                if i != b_dim and axes[i] is None
                and d % _axis_size(mesh, "model") == 0]
        if cand:
            d, i = max(cand)
            if d >= 16:
                axes[i] = "model"
        return NamedSharding(mesh, P(*axes))
    return jax.tree_util.tree_map_with_path(f, cache_shapes)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
