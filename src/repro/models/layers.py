"""LM primitives: norms, RoPE, MLPs, embeddings — pure-functional params.

Parameters are nested dicts of arrays; every init_* returns (params, key).
Naming is stable and descriptive because sharding rules match on path names
(repro.distributed.sharding).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- linear
def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.bfloat16, scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ----------------------------------------------------------------- RoPE
def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                         # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., ::2], x32[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


# ----------------------------------------------------------------- MLP
def init_mlp(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d, d_ff, dtype=dtype),
        "up": init_linear(k2, d, d_ff, dtype=dtype),
        "down": init_linear(k3, d_ff, d, dtype=dtype),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    """SwiGLU (the default for all assigned archs)."""
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x))
                  * linear(p["up"], x))


# ------------------------------------------------------------ embeddings
def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16) -> Params:
    return {"table": _normal(key, (vocab, d), 1.0, dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Logits in fp32 for a stable softmax-CE."""
    return x.astype(jnp.float32) @ p["table"].T.astype(jnp.float32)
