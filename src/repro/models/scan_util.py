"""Scan wrapper with an environment-controlled unroll switch.

XLA's cost_analysis counts a while-loop body ONCE regardless of trip count,
which would corrupt the roofline terms. The roofline probes therefore
compile small-L model variants with REPRO_FULL_UNROLL=1 — every lax.scan
fully unrolls, cost_analysis counts every iteration, and the per-layer
terms are recovered exactly by differencing two probe sizes
(launch.roofline). Normal runs keep rolled loops (small HLO, fast
compiles).
"""
from __future__ import annotations

import os

import jax


def full_unroll() -> bool:
    return os.environ.get("REPRO_FULL_UNROLL", "0") not in ("0", "", "false")


def scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if full_unroll() else 1)
