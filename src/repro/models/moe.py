"""Mixture-of-Experts FFN.

Two dispatch algorithms (per-layer algorithm choice — the DYNAMAP idea
applied to MoE):

* ``moe_ffn_dense`` — the classic GShard (T, E, C) one-hot einsum dispatch.
  Simple, but the dispatch/combine tensors are O(T²·k/E)-ish and at 1M
  tokens they dominate memory AND flops (the dry-run showed 365 GB/device
  temps on deepseek-v2 prefill). Kept for comparison and for tiny token
  counts.

* ``moe_ffn`` (default) — sort-based capacity dispatch, batched per
  sequence row so every gather stays inside one data shard:
    1. top-k routing per token;
    2. per-row argsort by expert id → each expert's tokens are contiguous;
    3. (E, C) gather indices from per-expert offsets (capacity-bounded,
       overflow dropped — GShard semantics);
    4. gather → (B, E, C, d), stacked-expert SwiGLU einsum (EP shards E on
       the model axis; GSPMD inserts the all-to-alls), scatter-add back.
  No (T, E, C) tensor ever exists.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, init_linear, init_mlp, linear, mlp


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    mo = cfg.moe
    d = cfg.d_model
    k_router, k_w1, k_w3, k_w2, k_shared = jax.random.split(key, 5)
    scale = d ** -0.5
    p: Params = {
        "router": init_linear(k_router, d, mo.n_experts, dtype=jnp.float32),
        # Expert-stacked SwiGLU weights: (E, d, f) / (E, f, d).
        "w_gate": (jax.random.normal(k_w1, (mo.n_experts, d, mo.d_ff_expert),
                                     jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(k_w3, (mo.n_experts, d, mo.d_ff_expert),
                                   jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(k_w2, (mo.n_experts, mo.d_ff_expert, d),
                                     jnp.float32)
                   * mo.d_ff_expert ** -0.5).astype(dtype),
    }
    if mo.n_shared:
        p["shared"] = init_mlp(k_shared, d,
                               (mo.d_ff_shared or mo.d_ff_expert)
                               * mo.n_shared, dtype=dtype)
    return p


def _router(p: Params, xt: jax.Array, mo) -> Tuple[jax.Array, jax.Array,
                                                   jax.Array]:
    """Per-token routing: (gates (…,k), experts (…,k), probs (…,E))."""
    logits = xt.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    topg, topi = jax.lax.top_k(probs, mo.top_k)
    topg = topg / jnp.maximum(topg.sum(-1, keepdims=True), 1e-9)
    return topg, topi, probs, logits


def _aux(probs: jax.Array, topi: jax.Array, logits: jax.Array, mo
         ) -> Dict[str, jax.Array]:
    me = probs.reshape(-1, mo.n_experts).mean(0)
    sel = jax.nn.one_hot(topi.reshape(-1), mo.n_experts,
                         dtype=jnp.float32).mean(0) * mo.top_k
    lb = mo.n_experts * jnp.sum(me * sel / mo.top_k)
    zl = jnp.mean(jax.scipy.special.logsumexp(
        logits.reshape(-1, mo.n_experts), axis=-1) ** 2)
    return {"load_balance": lb, "router_z": zl}


# ---------------------------------------------------------------------------
# Sort-based dispatch (default).
# ---------------------------------------------------------------------------

def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d). Routing groups = sequence rows, so all gathers are
    intra-row (and therefore intra-data-shard under batch sharding)."""
    mo = cfg.moe
    b, s, d = x.shape
    k = mo.top_k
    e = mo.n_experts
    cap = int(s * k / e * mo.capacity_factor)
    cap = max(4, -(-cap // 4) * 4)

    topg, topi, probs, logits = _router(p, x, mo)     # (B,S,k) ×2, (B,S,E)

    # Flatten routed copies within each row: (B, S·k).
    flat_e = topi.reshape(b, s * k)
    flat_g = topg.reshape(b, s * k)
    tok_of = jnp.repeat(jnp.arange(s), k)[None, :].astype(jnp.int32)
    tok_of = jnp.broadcast_to(tok_of, (b, s * k))

    order = jnp.argsort(flat_e, axis=-1)              # contiguous experts
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    sg = jnp.take_along_axis(flat_g, order, axis=-1)
    st = jnp.take_along_axis(tok_of, order, axis=-1)  # token id per slot

    # Per-row expert counts/offsets via scatter-add (no (T,E) one-hot).
    counts = jnp.zeros((b, e), jnp.int32).at[
        jnp.arange(b)[:, None], flat_e].add(1)
    offsets = jnp.cumsum(counts, axis=-1) - counts    # start of each expert

    slot = offsets[:, :, None] + jnp.arange(cap)[None, None, :]  # (B,E,C)
    valid = jnp.arange(cap)[None, None, :] < counts[:, :, None]
    slot_c = jnp.clip(slot, 0, s * k - 1)

    tok_idx = jnp.take_along_axis(st, slot_c.reshape(b, -1), axis=-1) \
        .reshape(b, e, cap)                            # (B,E,C) token ids
    gate = jnp.take_along_axis(sg, slot_c.reshape(b, -1), axis=-1) \
        .reshape(b, e, cap) * valid

    # Gather: (B, E, C, d) — intra-row, stays in the data shard.
    xe = jnp.take_along_axis(
        x[:, None, :, :], tok_idx[..., None].astype(jnp.int32),
        axis=2) * valid[..., None].astype(x.dtype)

    h = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    ye = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * u, p["w_down"])
    ye = ye * gate[..., None].astype(ye.dtype)

    # Scatter-add back per row.
    y = jnp.zeros((b, s, d), ye.dtype).at[
        jnp.arange(b)[:, None], tok_idx.reshape(b, -1)].add(
        ye.reshape(b, -1, d))
    y = y.astype(x.dtype)

    if "shared" in p:
        y = y + mlp(p["shared"], x.reshape(-1, d)).reshape(b, s, d)
    return y, _aux(probs, topi, logits, mo)


# ---------------------------------------------------------------------------
# Dense GShard dispatch (comparison baseline; see module docstring).
# ---------------------------------------------------------------------------

def moe_ffn_dense(p: Params, x: jax.Array, cfg: ModelConfig
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    topg, topi, probs, logits = _router(p, xt, mo)
    cap = int(t * mo.top_k / mo.n_experts * mo.capacity_factor)
    cap = max(4, -(-cap // 4) * 4)

    combine = jnp.zeros((t, mo.n_experts, cap), jnp.float32)
    prev = jnp.zeros((mo.n_experts,), jnp.int32)
    for kk in range(mo.top_k):
        onehot = jax.nn.one_hot(topi[:, kk], mo.n_experts,
                                dtype=jnp.float32)
        pos = jnp.cumsum(onehot, axis=0) - 1.0 + prev[None, :]
        pos_tok = (pos * onehot).sum(-1)
        keep = pos_tok < cap
        pos_oh = jax.nn.one_hot(pos_tok.astype(jnp.int32), cap,
                                dtype=jnp.float32) * keep[:, None]
        combine = combine + topg[:, kk, None, None] * onehot[:, :, None] \
            * pos_oh[:, None, :]
        prev = prev + onehot.sum(0).astype(jnp.int32)
    dispatch = (combine > 0).astype(x.dtype)

    xe = jnp.einsum("tec,td->ecd", dispatch, xt)
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
    y = jnp.einsum("tec,ecd->td", combine.astype(x.dtype), ye)
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + mlp(p["shared"], xt).reshape(b, s, d)
    return y, _aux(probs, topi, logits, mo)
