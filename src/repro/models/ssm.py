"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Chunked SSD: intra-chunk "attention" (the duality's quadratic branch) plus
inter-chunk state recurrence (linear branch) carried by a lax.scan. Decode
is the O(1) recurrent update on (conv_state, ssm_state) — this is what makes
the 500k-token decode cell trivial for SSM archs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.scan_util import scan as _scan

from repro.configs.base import ModelConfig
from repro.models.layers import Params, init_linear, linear, rmsnorm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim      # x, B, C share the causal conv
    return s, d_in, nh, conv_ch


def init_mamba(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    s, d_in, nh, conv_ch = _dims(cfg)
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # z, x, B, C, dt fused input projection.
        "in_proj": init_linear(k1, d, 2 * d_in + 2 * s.state_dim + nh,
                               dtype=dtype),
        "conv_w": (jax.random.normal(k2, (s.conv_width, conv_ch),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),         # A = -exp(a_log)
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((d_in,), dtype)},
        "out_proj": init_linear(k3, d_in, d, dtype=dtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., Q) → (..., Q, Q) with out[q, k] = Σ_{j=k+1..q} x_j (−inf
    above the diagonal)."""
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    q = x.shape[-1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(xbar: jax.Array, da: jax.Array, b_in: jax.Array,
                c_in: jax.Array, chunk: int) -> jax.Array:
    """xbar: (B, L, H, P) = dt·x;  da: (B, L, H) = dt·A (negative);
    b_in, c_in: (B, L, N). Returns y: (B, L, H, P)."""
    bsz, l, h, p = xbar.shape
    n = b_in.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    nc = xbar.shape[1] // q
    xc = xbar.reshape(bsz, nc, q, h, p)
    dac = da.reshape(bsz, nc, q, h).transpose(0, 3, 1, 2)   # (B,H,nc,Q)
    bc = b_in.reshape(bsz, nc, q, n)
    cc = c_in.reshape(bsz, nc, q, n)

    da_cs = jnp.cumsum(dac, axis=-1)                        # (B,H,nc,Q)
    decay = jnp.exp(_segsum(dac))                           # (B,H,nc,Q,Q)

    # Intra-chunk (quadratic branch).
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)          # (B,nc,Q,Q)
    m = jnp.einsum("bcqk,bhcqk->bhcqk", scores, decay)
    y_diag = jnp.einsum("bhcqk,bckhp->bcqhp", m, xc)

    # Chunk-final states.
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)         # (B,H,nc,Q)
    states = jnp.einsum("bckn,bhck,bckhp->bchnp", bc, decay_states, xc)

    # Inter-chunk recurrence.
    chunk_decay = jnp.exp(da_cs[..., -1])                   # (B,H,nc)

    def body(s_prev, xs):
        s_c, cd = xs                                        # (B,H,N,P),(B,H)
        s_new = s_prev * cd[..., None, None] + s_c
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, prev_states = _scan(
        body, s0, (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
                   chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B,nc,H,N,P)

    # Contribution of carried state into each position.
    state_decay = jnp.exp(da_cs)                            # (B,H,nc,Q)
    y_off = jnp.einsum("bcqn,bchnp,bhcq->bcqhp", cc,
                       prev_states.astype(xc.dtype), state_decay)
    y = (y_diag + y_off).reshape(bsz, nc * q, h, p)
    return y[:, :l]


def mamba_forward(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) → (B, S, d)."""
    s, d_in, nh, conv_ch = _dims(cfg)
    bsz, l, _ = x.shape
    zxbcdt = linear(p["in_proj"], x)
    z, xin, b_in, c_in, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + s.state_dim,
                 2 * d_in + 2 * s.state_dim], axis=-1)
    # Causal depthwise conv over (x, B, C).
    xbc = jnp.concatenate([xin, b_in, c_in], axis=-1)       # (B, L, conv_ch)
    w = p["conv_w"].astype(jnp.float32)
    xbc_p = jnp.pad(xbc.astype(jnp.float32),
                    ((0, 0), (s.conv_width - 1, 0), (0, 0)))
    conv = sum(xbc_p[:, i:i + l] * w[i] for i in range(s.conv_width))
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))
    xin, b_in, c_in = jnp.split(conv, [d_in, d_in + s.state_dim], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    a = -jnp.exp(p["a_log"])                                # (H,)
    xh = xin.reshape(bsz, l, nh, s.head_dim)
    y = ssd_chunked((xh * dt[..., None]).astype(jnp.float32),
                    dt * a, b_in, c_in, s.chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y)


# ---------------------------------------------------------------------------
# Decode (recurrent) path.
# ---------------------------------------------------------------------------

def init_mamba_cache(cfg: ModelConfig, batch: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    s, d_in, nh, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.state_dim), dtype),
    }


def mamba_decode(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
                 cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, d); O(1) state update."""
    s, d_in, nh, conv_ch = _dims(cfg)
    bsz = x.shape[0]
    zxbcdt = linear(p["in_proj"], x[:, 0])
    z, xin, b_in, c_in, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + s.state_dim,
                 2 * d_in + 2 * s.state_dim], axis=-1)
    xbc = jnp.concatenate([xin, b_in, c_in], axis=-1)       # (B, conv_ch)
    hist = jnp.concatenate([cache["conv"],
                            xbc[:, None].astype(cache["conv"].dtype)],
                           axis=1)                           # (B, W, ch)
    w = p["conv_w"].astype(jnp.float32)
    conv = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), w)
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))
    xin, b_in, c_in = jnp.split(conv, [d_in, d_in + s.state_dim], axis=-1)

    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt1 * a)                                   # (B,H)
    xh = xin.reshape(bsz, nh, s.head_dim)
    ssm = cache["ssm"] * da[..., None, None] \
        + jnp.einsum("bhp,bn,bh->bhpn", xh, b_in, dt1)
    y = jnp.einsum("bhpn,bn->bhp", ssm, c_in) \
        + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z[:, None]))
    out = linear(p["out_proj"], y)
    return out, {"conv": hist[:, 1:], "ssm": ssm}
