"""Model assembly: block composition, scan-over-layers stack, losses,
prefill/decode entry points — one code path serving all 10 architectures.

Layer parameters are stacked on a leading L axis and consumed by lax.scan
(small HLO → tractable 512-device compiles); hybrid (Zamba-style) stacks
scan over groups of ``attn_every`` mamba layers followed by ONE shared
attention+MLP block whose parameters are closed over (not scanned) — the
"shared attn" of the assignment.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.scan_util import scan as _scan

from repro.configs.base import BlockType, ModelConfig
from repro.distributed.api import (constrain_residual,
                                   gather_layer_params)
from repro.models import attention as A
from repro.models import ssm as S
from repro.models.layers import (Params, embed, init_embedding, init_linear,
                                 init_mlp, init_rmsnorm, linear, mlp,
                                 rmsnorm, unembed)
from repro.models.moe import init_moe, moe_ffn

PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Per-layer init / apply.
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: ModelConfig, dtype,
                     use_moe: Optional[bool] = None) -> Params:
    use_moe = (cfg.moe is not None) if use_moe is None else use_moe
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {
        "ln_attn": init_rmsnorm(cfg.d_model, dtype),
        "attn": A.init_attention(k1, cfg, dtype),
        "ln_mlp": init_rmsnorm(cfg.d_model, dtype),
    }
    if use_moe:
        p["moe"] = init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k3, cfg.d_model, cfg.d_ff, dtype)
    return p


def _apply_attn_block(p: Params, x: jax.Array, cfg: ModelConfig,
                      q_offset: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, moe_aux_loss). A block is MoE iff its params carry the
    'moe' subtree (interleaved stacks mix dense and MoE blocks)."""
    p = gather_layer_params(p)      # streamed-FSDP weight gather
    aux = jnp.zeros((), jnp.float32)

    def ffn(h):
        nonlocal aux
        if "moe" in p:
            fo, al = moe_ffn(p["moe"], h, cfg)
            aux = aux + al["load_balance"] * 0.01 + al["router_z"] * 1e-4
            return fo
        return mlp(p["mlp"], h)

    if cfg.parallel_block:
        # Command-R: attention and FFN read the same normed input.
        h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
        ao, _ = A.attention_forward(p["attn"], h, cfg, q_offset)
        return x + ao + ffn(h), aux
    h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    ao, _ = A.attention_forward(p["attn"], h, cfg, q_offset)
    x = x + ao
    h = rmsnorm(p["ln_mlp"], x, cfg.norm_eps)
    return x + ffn(h), aux


def _init_mamba_block(key, cfg: ModelConfig, dtype) -> Params:
    return {
        "ln": init_rmsnorm(cfg.d_model, dtype),
        "mamba": S.init_mamba(key, cfg, dtype),
    }


def _apply_mamba_block(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    p = gather_layer_params(p)      # streamed-FSDP weight gather
    return x + S.mamba_forward(p["mamba"], rmsnorm(p["ln"], x, cfg.norm_eps),
                               cfg)


# ---------------------------------------------------------------------------
# Whole-model init.
# ---------------------------------------------------------------------------

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_model(cfg: ModelConfig, key: jax.Array) -> PyTree:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, cfg.n_layers + 4)
    params: Dict[str, PyTree] = {
        "embed": init_embedding(keys[-1], cfg.vocab, cfg.d_model, dtype),
        "ln_f": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(keys[-2], cfg.vocab, cfg.d_model,
                                           dtype)
    if cfg.frontend != "none":
        params["frontend_proj"] = init_linear(keys[-3], cfg.frontend_dim,
                                              cfg.d_model, dtype=dtype)

    if cfg.block_type is BlockType.MAMBA:
        layers = [_init_mamba_block(keys[i], cfg, dtype)
                  for i in range(cfg.n_layers)]
        if cfg.attn_every:
            ng = cfg.n_layers // cfg.attn_every
            grouped = [_stack(layers[i * cfg.attn_every:(i + 1)
                                     * cfg.attn_every]) for i in range(ng)]
            params["layers"] = _stack(grouped)
            params["shared_attn"] = _init_attn_block(keys[-4], cfg, dtype,
                                                     use_moe=False)
        else:
            params["layers"] = _stack(layers)
    elif cfg.moe is not None and cfg.moe_every > 1:
        # Interleaved dense/MoE (Llama-4): groups of (moe_every-1) dense
        # blocks followed by one MoE block.
        ng = cfg.n_layers // cfg.moe_every
        dense, moe_blocks = [], []
        for i in range(ng):
            base = i * cfg.moe_every
            dense.append(_stack([
                _init_attn_block(keys[base + j], cfg, dtype, use_moe=False)
                for j in range(cfg.moe_every - 1)]))
            moe_blocks.append(_init_attn_block(
                keys[base + cfg.moe_every - 1], cfg, dtype, use_moe=True))
        params["layers"] = {"dense": _stack(dense),
                            "moe": _stack(moe_blocks)}
    else:
        params["layers"] = _stack([_init_attn_block(keys[i], cfg, dtype)
                                   for i in range(cfg.n_layers)])
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill).
# ---------------------------------------------------------------------------

def _embed_inputs(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
                  frontend_embeds: Optional[jax.Array]) -> jax.Array:
    x = embed(params["embed"], tokens)
    if cfg.frontend != "none":
        assert frontend_embeds is not None, \
            f"{cfg.name} requires frontend embeddings"
        fe = linear(params["frontend_proj"],
                    frontend_embeds.astype(x.dtype))
        x = jnp.concatenate([fe, x], axis=1)
    return x


def forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            frontend_embeds: Optional[jax.Array] = None,
            remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S_text) → (logits (B, S, vocab) fp32, moe_aux scalar)."""
    x = _embed_inputs(params, cfg, tokens, frontend_embeds)

    if cfg.block_type is BlockType.MAMBA and cfg.attn_every:
        shared = params["shared_attn"]

        def group_body(carry, lp):
            x, aux = carry
            x = constrain_residual(x)

            def mamba_body(xc, mp):
                return _apply_mamba_block(mp, constrain_residual(xc),
                                          cfg), None

            x, _ = _scan(mamba_body, x, lp)
            x, a = _apply_attn_block(shared, x, cfg)
            return (x, aux + a), None

        body = jax.checkpoint(group_body) if remat else group_body
        (x, aux), _ = _scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    elif cfg.block_type is BlockType.MAMBA:
        def m_body(carry, lp):
            return _apply_mamba_block(lp, constrain_residual(carry),
                                      cfg), None

        body = jax.checkpoint(m_body) if remat else m_body
        x, _ = _scan(body, x, params["layers"])
        aux = jnp.zeros((), jnp.float32)
    elif cfg.moe is not None and cfg.moe_every > 1:
        def pair_body(carry, lp):
            x, aux = carry
            x = constrain_residual(x)

            def dense_body(c2, dp):
                x2, a2 = c2
                x2, a = _apply_attn_block(dp, x2, cfg)
                return (x2, a2 + a), None

            (x, aux), _ = _scan(dense_body, (x, aux), lp["dense"])
            x, a = _apply_attn_block(lp["moe"], x, cfg)
            return (x, aux + a), None

        body = jax.checkpoint(pair_body) if remat else pair_body
        (x, aux), _ = _scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    else:
        def a_body(carry, lp):
            x, aux = carry
            x, a = _apply_attn_block(lp, constrain_residual(x), cfg)
            return (x, aux + a), None

        body = jax.checkpoint(a_body) if remat else a_body
        (x, aux), _ = _scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, aux


def logits_from_hidden(params: PyTree, cfg: ModelConfig,
                       x: jax.Array) -> jax.Array:
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(head, x)


def loss_fn(params: PyTree, batch: Dict[str, jax.Array], cfg: ModelConfig,
            ce_chunk: int = 512) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy over the text positions.

    The (B, S, vocab) logits tensor is never materialized: CE is computed in
    sequence chunks inside a lax.scan (fp32 per chunk only) — essential for
    the 150k-250k vocab archs at 4k×256 batch.
    """
    hidden, aux = forward(params, batch["tokens"], cfg,
                          batch.get("frontend_embeds"))
    n_front = cfg.frontend_tokens if cfg.frontend != "none" else 0
    h = hidden[:, n_front:, :]
    b, s, d = h.shape
    h_in = h[:, :-1]
    labels = batch["tokens"][:, 1:]
    n = s - 1
    c = min(ce_chunk, n)
    nc = -(-n // c)
    pad = nc * c - n
    if pad:
        h_in = jnp.pad(h_in, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = h_in.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]

    def body(acc, xs):
        h_i, l_i = xs
        logits = unembed(head, h_i)                     # (B, c, V) fp32
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        # Gold logit via one-hot contraction: keeps the vocab dim sharded
        # (take_along_axis over a sharded dim would force GSPMD to gather
        # the full logits tensor — TB-scale collectives at 250k vocab).
        onehot = jax.nn.one_hot(jnp.maximum(l_i, 0), logits.shape[-1],
                                dtype=logits.dtype)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot)
        valid = (l_i >= 0).astype(jnp.float32)
        ce_sum, cnt = acc
        return (ce_sum + jnp.sum((logz - gold) * valid),
                cnt + valid.sum()), None

    # Recompute logits in the backward pass instead of saving (B, c, V)
    # fp32 chunks per step.
    (ce_sum, cnt), _ = _scan(
        jax.checkpoint(body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    ce = ce_sum / jnp.maximum(cnt, 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode.
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    """Decode cache. Attention archs: (L, B, S_cache, KvH, D) KV (S_cache =
    sliding window if set); MLA: latent cache; SSM: conv+ssm states."""
    dtype = _dtype(cfg)
    s_cache = min(max_len, cfg.sliding_window) if cfg.sliding_window \
        else max_len

    def attn_cache():
        if cfg.mla is not None:
            m = cfg.mla
            return {
                "c_kv": jnp.zeros((batch, s_cache, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, s_cache, m.qk_rope_dim), dtype),
            }
        return {
            "k": jnp.zeros((batch, s_cache, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((batch, s_cache, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
        }

    if cfg.block_type is BlockType.MAMBA and cfg.attn_every:
        ng = cfg.n_layers // cfg.attn_every
        return {
            "mamba": jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x, (ng, cfg.attn_every) + x.shape),
                S.init_mamba_cache(cfg, batch)),
            "attn": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (ng,) + x.shape), attn_cache()),
        }
    if cfg.block_type is BlockType.MAMBA:
        return {"mamba": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
            S.init_mamba_cache(cfg, batch))}
    if cfg.moe is not None and cfg.moe_every > 1:
        ng = cfg.n_layers // cfg.moe_every
        return {"attn": {
            "dense": jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x, (ng, cfg.moe_every - 1) + x.shape), attn_cache()),
            "moe": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (ng,) + x.shape),
                attn_cache()),
        }}
    return {"attn": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
        attn_cache())}


def decode_step(params: PyTree, tokens: jax.Array, cache: PyTree,
                pos: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, PyTree]:
    """tokens: (B, 1) — one new token per sequence; pos: scalar int32 count
    of tokens already in the cache. Returns (logits (B, vocab), new cache).
    """
    x = embed(params["embed"], tokens)

    if cfg.block_type is BlockType.MAMBA and cfg.attn_every:
        shared = params["shared_attn"]

        def group_body(x, xs):
            lp, mcache, acache = xs

            def inner(x, xs2):
                mp, mc = xs2
                h = rmsnorm(mp["ln"], x, cfg.norm_eps)
                y, mc2 = S.mamba_decode(mp["mamba"], h, mc, cfg)
                return x + y, mc2

            x, mcache2 = _scan(inner, x, (lp, mcache))
            h = rmsnorm(shared["ln_attn"], x, cfg.norm_eps)
            ao, acache2 = A.attention_decode(shared["attn"], h, acache, pos,
                                             cfg)
            x = x + ao
            h = rmsnorm(shared["ln_mlp"], x, cfg.norm_eps)
            x = x + mlp(shared["mlp"], h)
            return x, (mcache2, acache2)

        x, (mc, ac) = _scan(group_body, x,
                                   (params["layers"], cache["mamba"],
                                    cache["attn"]))
        new_cache = {"mamba": mc, "attn": ac}
    elif cfg.block_type is BlockType.MAMBA:
        def m_body(x, xs):
            lp, mc = xs
            h = rmsnorm(lp["ln"], x, cfg.norm_eps)
            y, mc2 = S.mamba_decode(lp["mamba"], h, mc, cfg)
            return x + y, mc2

        x, mc = _scan(m_body, x, (params["layers"], cache["mamba"]))
        new_cache = {"mamba": mc}
    else:
        def a_body(x, xs):
            lp, ac = xs

            def ffn(h):
                return mlp(lp["mlp"], h) if "mlp" in lp \
                    else moe_ffn(lp["moe"], h, cfg)[0]

            if cfg.parallel_block:
                h = rmsnorm(lp["ln_attn"], x, cfg.norm_eps)
                ao, ac2 = A.attention_decode(lp["attn"], h, ac, pos, cfg)
                return x + ao + ffn(h), ac2
            h = rmsnorm(lp["ln_attn"], x, cfg.norm_eps)
            ao, ac2 = A.attention_decode(lp["attn"], h, ac, pos, cfg)
            x = x + ao
            h = rmsnorm(lp["ln_mlp"], x, cfg.norm_eps)
            return x + ffn(h), ac2

        if cfg.moe is not None and cfg.moe_every > 1:
            def pair_body(x, xs):
                lp, ac = xs

                def inner(x2, xs2):
                    return a_body(x2, xs2)

                x, dc = _scan(inner, x, (lp["dense"], ac["dense"]))
                x, mc = a_body(x, (lp["moe"], ac["moe"]))
                return x, {"dense": dc, "moe": mc}

            x, ac = _scan(pair_body, x,
                                 (params["layers"], cache["attn"]))
            new_cache = {"attn": ac}
        else:
            x, ac = _scan(a_body, x,
                                 (params["layers"], cache["attn"]))
            new_cache = {"attn": ac}

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(head, x)[:, 0]
    return logits, new_cache


def prefill(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            frontend_embeds: Optional[jax.Array] = None) -> jax.Array:
    """Prefill forward; returns last-position logits (B, vocab) — the full
    (B, S, vocab) tensor is never formed."""
    hidden, _ = forward(params, tokens, cfg, frontend_embeds)
    return logits_from_hidden(params, cfg, hidden[:, -1:, :])[:, 0]
