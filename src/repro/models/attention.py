"""Attention: GQA/MHA with RoPE, sliding-window, chunked-softmax (flash
style) prefill/train, KV-cache decode, and DeepSeek-V2 MLA (decompress-per-
chunk prefill; absorbed-matmul decode).

The chunked online-softmax keeps the (Sq × Skv) score matrix out of memory:
scores exist only per (Sq × chunk) block inside a lax.scan — this is what
lets the 32k-prefill cells compile within HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import (constrain_decode_q, constrain_qkv)
from repro.models.scan_util import scan as _uscan

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import (Params, apply_rope, init_linear, linear,
                                 rmsnorm)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init.
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is not None:
        return _init_mla(key, cfg, dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_linear(k1, d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(k2, d, kvh * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(k3, d, kvh * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(k4, h * hd, d, dtype=dtype),
    }


def _init_mla(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    keys = jax.random.split(key, 6)
    q_in = m.q_lora_rank or d
    p: Params = {
        # joint compressed KV + shared rope key: d → kv_lora + rope
        "w_dkv": init_linear(keys[0], d, m.kv_lora_rank + m.qk_rope_dim,
                             dtype=dtype),
        "w_uk": init_linear(keys[1], m.kv_lora_rank, h * m.qk_nope_dim,
                            dtype=dtype),
        "w_uv": init_linear(keys[2], m.kv_lora_rank, h * m.v_dim,
                            dtype=dtype),
        "wq": init_linear(keys[3], q_in, h * (m.qk_nope_dim + m.qk_rope_dim),
                          dtype=dtype),
        "wo": init_linear(keys[4], h * m.v_dim, d, dtype=dtype),
    }
    if m.q_lora_rank:
        p["w_dq"] = init_linear(keys[5], d, m.q_lora_rank, dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Chunked online-softmax attention core.
# ---------------------------------------------------------------------------

def _chunk_scan(q: jax.Array, k_chunks: jax.Array, v_chunks: jax.Array,
                q_pos: jax.Array, k_pos_chunks: jax.Array,
                window: int, scale: float) -> jax.Array:
    """q: (B, Sq, H, D); k/v_chunks: (n, B, C, KvH, Dk/Dv);
    k_pos_chunks: (n, C). Causal (+ optional sliding window)."""
    b, sq, h, dq = q.shape
    n, _, c, kvh, dv = v_chunks.shape
    rep = h // kvh
    q32 = (q * scale).astype(q.dtype)

    def body(carry, xs):
        m_prev, l_prev, o_prev = carry
        k_c, v_c, kp = xs                                 # (B,C,KvH,D), (C,)
        if rep > 1:
            k_c = jnp.repeat(k_c, rep, axis=2)
            v_c = jnp.repeat(v_c, rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, k_c,
                       preferred_element_type=jnp.float32)
        msk = kp[None, :] > q_pos[:, None]                # future → mask
        if window > 0:
            msk = msk | (q_pos[:, None] - kp[None, :] >= window)
        s = jnp.where(msk[None, None], NEG_INF, s)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        o_new = o_prev * alpha + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, h, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    o0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    # Recompute-in-backward (flash-attention semantics): per-chunk scores/
    # probabilities are never saved.
    (m, l, o), _ = _uscan(jax.checkpoint(body), (m0, l0, o0),
                          (k_chunks, v_chunks, k_pos_chunks))
    out = o / jnp.maximum(l, 1e-20)
    return out.transpose(0, 2, 1, 3)                      # (B, Sq, H, Dv)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_offset: int = 0, window: int = 0,
                      chunk: int = 1024) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KvH, D); causal."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    c = min(chunk, skv)
    n = -(-skv // c)
    pad = n * c - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k_pos = jnp.concatenate([jnp.arange(skv),
                             jnp.full((pad,), 2 ** 30)]) if pad \
        else jnp.arange(skv)
    kc = k.reshape(b, n, c, *k.shape[2:]).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n, c, *v.shape[2:]).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(n, c)
    q_pos = q_offset + jnp.arange(sq)
    scale = q.shape[-1] ** -0.5
    return _chunk_scan(q, kc, vc, q_pos, kpc, window, scale)


# ---------------------------------------------------------------------------
# GQA forward (train/prefill) and decode.
# ---------------------------------------------------------------------------

def attention_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                      q_offset: int = 0,
                      return_cache: bool = False
                      ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B, S, d) → (B, S, d); optionally the KV cache for serving."""
    if cfg.mla is not None:
        return _mla_forward(p, x, cfg, q_offset, return_cache)
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(b, s, h, hd)
    k = linear(p["wk"], x).reshape(b, s, kvh, hd)
    v = linear(p["wv"], x).reshape(b, s, kvh, hd)
    pos = q_offset + jnp.arange(s)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    q, k, v = constrain_qkv(q, k, v)
    out = chunked_attention(q, k, v, q_offset=q_offset,
                            window=cfg.sliding_window)
    out = linear(p["wo"], out.reshape(b, s, h * hd).astype(x.dtype))
    cache = {"k": k, "v": v} if return_cache else None
    return out, cache


def attention_decode(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
                     pos: jax.Array, cfg: ModelConfig
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B, 1, d); cache k/v: (B, S, KvH, D) ring-buffer
    (S = window for SWA archs, full context otherwise); pos: scalar count of
    tokens already in context."""
    if cfg.mla is not None:
        return _mla_decode(p, x, cache, pos, cfg)
    b, _, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s_cache = cache["k"].shape[1]
    q = linear(p["wq"], x).reshape(b, 1, h, hd)
    k_new = linear(p["wk"], x).reshape(b, 1, kvh, hd)
    v_new = linear(p["wv"], x).reshape(b, 1, kvh, hd)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[None], cfg.rope_theta)
    slot = jnp.mod(pos, s_cache)        # ring buffer (wraps only for SWA)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))

    # Positions of cache slots (ring-aware): slot i holds token
    # pos - ((slot - i) mod S)  for filled slots.
    idx = jnp.arange(s_cache)
    tok_pos = pos - jnp.mod(slot - idx, s_cache)
    valid = tok_pos >= 0
    if h // kvh > 1:
        k_r = jnp.repeat(k, h // kvh, axis=2)
        v_r = jnp.repeat(v, h // kvh, axis=2)
    else:
        k_r, v_r = k, v
    scale = hd ** -0.5
    k_r = apply_rope_cache(k_r)
    s_ = jnp.einsum("bqhd,bkhd->bhqk", (q * scale), k_r,
                    preferred_element_type=jnp.float32)
    msk = ~valid
    if cfg.sliding_window > 0:
        msk = msk | (pos - tok_pos >= cfg.sliding_window)
    s_ = jnp.where(msk[None, None, None, :], NEG_INF, s_)
    w_ = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w_.astype(v_r.dtype), v_r)
    out = linear(p["wo"], o.reshape(b, 1, h * hd))
    return out, {"k": k, "v": v}


def apply_rope_cache(k: jax.Array) -> jax.Array:
    """Cache stores post-RoPE keys (positions are absolute), so this is the
    identity; kept as an explicit hook for rope-rescaling schemes."""
    return k


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2).
# ---------------------------------------------------------------------------

def _mla_q(p: Params, x: jax.Array, cfg: ModelConfig, pos) -> Tuple[jax.Array, jax.Array]:
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    xq = linear(p["w_dq"], x) if "w_dq" in p else x
    q = linear(p["wq"], xq).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    return q_nope, q_rope


def _mla_forward(p: Params, x: jax.Array, cfg: ModelConfig, q_offset: int,
                 return_cache: bool):
    """Prefill/train: decompress K/V per chunk inside the scan (the latent
    cache never expands to full per-head K/V in memory at once)."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    pos = q_offset + jnp.arange(s)
    q_nope, q_rope = _mla_q(p, x, cfg, pos)
    ckv_full = linear(p["w_dkv"], x)             # (B, S, kv_lora + rope)
    c_kv, k_rope = ckv_full[..., :m.kv_lora_rank], \
        ckv_full[..., m.kv_lora_rank:]
    k_rope = apply_rope(k_rope[..., None, :], pos, cfg.rope_theta)[..., 0, :]

    chunk = min(1024, s)
    n = -(-s // chunk)
    pad = n * chunk - s
    if pad:
        c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    k_pos = (jnp.concatenate([pos, jnp.full((pad,), 2 ** 30)]) if pad
             else pos).reshape(n, chunk)
    ckv_c = c_kv.reshape(b, n, chunk, -1).transpose(1, 0, 2, 3)
    krope_c = k_rope.reshape(b, n, chunk, -1).transpose(1, 0, 2, 3)

    w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, h, m.v_dim)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    q_pos = pos

    def body(carry, xs):
        m_prev, l_prev, o_prev = carry
        ckv_i, kr_i, kp = xs
        k_nope = jnp.einsum("bkl,lhd->bkhd", ckv_i, w_uk)   # decompress
        v_i = jnp.einsum("bkl,lhd->bkhd", ckv_i, w_uv)
        s_ = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, kr_i,
                           preferred_element_type=jnp.float32)) * scale
        msk = kp[None, :] > q_pos[:, None]
        s_ = jnp.where(msk[None, None], NEG_INF, s_)
        m_new = jnp.maximum(m_prev, s_.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        pw = jnp.exp(s_ - m_new)
        l_new = l_prev * alpha + pw.sum(axis=-1, keepdims=True)
        o_new = o_prev * alpha + jnp.einsum(
            "bhqk,bkhd->bhqd", pw.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, h, s, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, s, 1), jnp.float32)
    o0 = jnp.zeros((b, h, s, m.v_dim), jnp.float32)
    (mx, l, o), _ = _uscan(jax.checkpoint(body), (m0, l0, o0),
                           (ckv_c, krope_c, k_pos))
    out = (o / jnp.maximum(l, 1e-20)).transpose(0, 2, 1, 3)
    out = linear(p["wo"], out.reshape(b, s, h * m.v_dim).astype(x.dtype))
    cache = None
    if return_cache:
        cache = {"c_kv": c_kv[:, :s], "k_rope": k_rope[:, :s]}
    return out, cache


def _mla_decode(p: Params, x: jax.Array, cache, pos, cfg: ModelConfig):
    """Absorbed-matmul decode: scores via q̃ = W_uk^T q_nope against the
    latent cache — the cache stays (kv_lora + rope)-wide (paper's 93.3%
    KV-cache reduction is this)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    s_cache = cache["c_kv"].shape[1]
    q_nope, q_rope = _mla_q(p, x, cfg, pos[None])
    ckv_full = linear(p["w_dkv"], x)
    c_new, kr_new = ckv_full[..., :m.kv_lora_rank], \
        ckv_full[..., m.kv_lora_rank:]
    kr_new = apply_rope(kr_new[..., None, :], pos[None],
                        cfg.rope_theta)[..., 0, :]
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new,
                                          (0, pos, 0))
    w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, h, m.v_dim)
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk)    # (B,1,H,kv_lora)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s_ = (jnp.einsum("bqhl,bkl->bhqk", q_abs, c_kv,
                     preferred_element_type=jnp.float32)
          + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                       preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(s_cache) <= pos
    s_ = jnp.where(~valid[None, None, None, :], NEG_INF, s_)
    w_ = jax.nn.softmax(s_, axis=-1)
    o_lat = jnp.einsum("bhqk,bkl->bqhl", w_.astype(c_kv.dtype), c_kv)
    o = jnp.einsum("bqhl,lhd->bqhd", o_lat, w_uv)
    out = linear(p["wo"], o.reshape(b, 1, h * m.v_dim))
    return out, {"c_kv": c_kv, "k_rope": k_rope}
