"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192(expert) vocab=202048, MoE 128 experts top-1 + 1 shared expert
[hf:meta-llama/Llama-4; unverified]. Optimizer states in bf16 so the
single-pod (256-chip) training cell fits 16 GB/chip (see EXPERIMENTS.md).
"""
from repro.configs.base import ModelConfig, MoEConfig, register

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=16384, vocab=202048,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192,
                  n_shared=1, d_ff_shared=8192),
    moe_every=2,        # alternating dense / MoE layers (Llama-4)
    opt_dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=128,
                  n_shared=1, d_ff_shared=128),
    moe_every=2,
)

register(FULL, REDUCED)
