"""GoogleNet (the paper's first evaluation network) as a selectable config."""
from repro.cnn.models import googlenet as build_graph


def graph(res: int = 224, scale: float = 1.0):
    return build_graph(res=res, scale=scale)
