"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias, parallel block
[hf:CohereForAI/c4ai-command-r-plus; unverified]."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256000,
    parallel_block=True, tie_embeddings=True,
    opt_dtype="bfloat16",   # fits 16 GB/chip on one pod (EXPERIMENTS.md)
)

REDUCED = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    parallel_block=True, tie_embeddings=True,
)

register(FULL, REDUCED)
