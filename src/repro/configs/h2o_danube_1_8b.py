"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf]. SWA ⇒ runs the long_500k cell."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000,
    sliding_window=4096,
)

REDUCED = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    sliding_window=64,
)

register(FULL, REDUCED)
