"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 blocks + ONE shared attention block
applied every 6 mamba layers [arXiv:2411.15242; hf]. Sub-quadratic ⇒ runs
long_500k."""
from repro.configs.base import BlockType, ModelConfig, SSMConfig, register

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    block_type=BlockType.MAMBA, attn_every=6, shared_attn=True,
    ssm=SSMConfig(state_dim=64, head_dim=64, conv_width=4, expand=2),
)

REDUCED = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    block_type=BlockType.MAMBA, attn_every=2, shared_attn=True,
    ssm=SSMConfig(state_dim=16, head_dim=16, conv_width=4, expand=2,
                  chunk=32),
)

register(FULL, REDUCED)
