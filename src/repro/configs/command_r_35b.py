"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias, parallel attention+FFN block, tied embeddings
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000,
    parallel_block=True, tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    parallel_block=True, tie_embeddings=True,
)

register(FULL, REDUCED)
