"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060; unverified].
Attention-free ⇒ runs long_500k with O(1) decode state."""
from repro.configs.base import BlockType, ModelConfig, SSMConfig, register

FULL = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=50280, tie_embeddings=True,
    block_type=BlockType.MAMBA,
    ssm=SSMConfig(state_dim=128, head_dim=64, conv_width=4, expand=2),
)

REDUCED = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=256, tie_embeddings=True,
    block_type=BlockType.MAMBA,
    ssm=SSMConfig(state_dim=16, head_dim=16, conv_width=4, expand=2,
                  chunk=32),
)

register(FULL, REDUCED)
