"""musicgen-medium [audio]: 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

The EnCodec/conditioning frontend is a STUB per the assignment:
input_specs() provides precomputed frame embeddings for a conditioning
prefix; the decoder operates on EnCodec token codes (vocab 2048).
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    frontend="audio", frontend_tokens=256, frontend_dim=1024,
)

REDUCED = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=128,
    frontend="audio", frontend_tokens=8, frontend_dim=32,
)

register(FULL, REDUCED)
