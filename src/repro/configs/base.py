"""Model / shape configuration schema and the architecture registry.

Every assigned architecture is a ``ModelConfig`` built from the exact table
in the assignment; ``reduced()`` derives the CPU-runnable smoke config with
identical topology. ``input_specs`` produces ShapeDtypeStruct stand-ins for
the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


class BlockType(enum.Enum):
    ATTN = "attn"          # attention + MLP block
    MAMBA = "mamba"        # Mamba2 / SSD block
    MOE = "moe"            # attention + MoE block
    SHARED_ATTN = "shared_attn"  # Zamba-style shared attention block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 = full-rank Q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD."""
    state_dim: int = 128
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 256
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0               # 0 → d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0       # 0 = full attention
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    moe_every: int = 1            # 2 = alternate dense/MoE (Llama-4 style)
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # Hybrid stacking: attn block every `attn_every` blocks (Zamba-like);
    # 0 = homogeneous stack of `block_type`.
    block_type: BlockType = BlockType.ATTN
    attn_every: int = 0
    shared_attn: bool = False     # Zamba: ONE attention param set, reused
    # Modality frontend stub: number of prefix embedding tokens & their dim.
    frontend: str = "none"        # none | vision | audio
    frontend_tokens: int = 0
    frontend_dim: int = 0
    # Parallel residual (attention and MLP from same input) — Command-R.
    parallel_block: bool = False
    dtype: str = "bfloat16"
    # Optimizer-state dtype (fp32 default; bf16 for the 400B-class configs
    # so single-pod training fits 16 GB/chip — see EXPERIMENTS.md).
    opt_dtype: str = "float32"

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        return self.block_type is BlockType.MAMBA and self.attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token decode shape?"""
        return (self.block_type is BlockType.MAMBA or self.sliding_window > 0)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        n_attn, n_mamba = self._block_counts()
        hd = self.head_dim
        if self.mla is not None:
            m = self.mla
            q_in = m.q_lora_rank or d
            attn_p = (d * (m.kv_lora_rank + m.qk_rope_dim)
                      + (d * m.q_lora_rank if m.q_lora_rank else 0)
                      + q_in * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                      + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_dim)
                      + self.n_heads * m.v_dim * d)
        else:
            attn_p = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                      + self.n_heads * hd * d)
        if self.moe is not None:
            mo = self.moe
            moe_p = (mo.n_experts * 3 * d * mo.d_ff_expert
                     + mo.n_shared * 3 * d * (mo.d_ff_shared or mo.d_ff_expert)
                     + d * mo.n_experts)
            n_moe = n_attn // self.moe_every
            n_dense = n_attn - n_moe
            ffn_total = n_moe * moe_p + n_dense * 3 * d * self.d_ff
        else:
            ffn_total = n_attn * 3 * d * self.d_ff
        total += n_attn * attn_p + ffn_total
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            mamba_p = (d * (2 * d_in + 2 * s.state_dim + nh)
                       + d_in * d + s.conv_width * (d_in + 2 * s.state_dim)
                       + 2 * nh)
            total += n_mamba * mamba_p
        return int(total)

    def active_param_count(self) -> int:
        """Active (per-token) params for MoE — 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        d = self.d_model
        n_attn, _ = self._block_counts()
        n_moe = n_attn // self.moe_every
        dead = (mo.n_experts - mo.top_k) * 3 * d * mo.d_ff_expert * n_moe
        return int(self.param_count() - dead)

    def _block_counts(self) -> Tuple[int, int]:
        """(#attention-bearing blocks, #mamba blocks)."""
        if self.block_type is BlockType.MAMBA:
            if self.attn_every:
                n_attn = self.n_layers // self.attn_every
                return n_attn, self.n_layers - n_attn
            return 0, self.n_layers
        return self.n_layers, 0


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str       # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")
ALL_SHAPES = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]
SHAPES = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ModelConfig) -> List[ShapeSpec]:
    """The assigned 4 shapes, with long_500k only for sub-quadratic archs
    (skip recorded in DESIGN.md §Arch-applicability)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return out


# ---------------------------------------------------------------------------
# Registry (populated by repro.configs package import).
# ---------------------------------------------------------------------------

REGISTRY: Dict[str, "ArchEntry"] = {}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    full: ModelConfig
    reduced: ModelConfig


def register(full: ModelConfig, reduced: ModelConfig) -> ModelConfig:
    REGISTRY[full.name] = ArchEntry(full=full, reduced=reduced)
    return full


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401 — populate registry
    e = REGISTRY[name]
    return e.reduced if reduced else e.full
