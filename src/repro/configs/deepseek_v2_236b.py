"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536(expert)
vocab=102400 — MLA kv_lora=512, 2 shared + 160 routed experts top-6
[arXiv:2405.04434; hf]. bf16 optimizer states for single-pod fit."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register

FULL = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400, d_head=192,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                  n_shared=2, d_ff_shared=3072),
    opt_dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, d_head=48,
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                  qk_nope_dim=32, qk_rope_dim=16, v_dim=32),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                  n_shared=1, d_ff_shared=128),
)

register(FULL, REDUCED)
