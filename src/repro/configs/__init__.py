"""Architecture registry: importing this package registers all configs."""
from repro.configs import (command_r_35b, command_r_plus_104b,
                           deepseek_v2_236b, h2o_danube_1_8b,
                           internvl2_2b, llama4_maverick_400b,
                           mamba2_370m, musicgen_medium, qwen2_5_14b,
                           zamba2_2_7b)
from repro.configs.base import (ALL_SHAPES, REGISTRY, SHAPES, ModelConfig,
                                ShapeSpec, get_config, shapes_for)

ARCH_NAMES = sorted(REGISTRY)
