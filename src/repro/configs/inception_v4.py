"""Inception-v4 (the paper's second evaluation network) as a config."""
from repro.cnn.models import inception_v4 as build_graph


def graph(res: int = 299, scale: float = 1.0):
    return build_graph(res=res, scale=scale)
