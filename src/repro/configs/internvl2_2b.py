"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT frontend + InternLM2 decoder [arXiv:2404.16821; hf].
The ViT is a STUB: input_specs() provides precomputed patch embeddings
(1024 tokens × 1024 dims) projected into the decoder."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553,
    frontend="vision", frontend_tokens=1024, frontend_dim=1024,
)

REDUCED = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    frontend="vision", frontend_tokens=8, frontend_dim=32,
)

register(FULL, REDUCED)
