"""Checkpointing: async, atomic, shard-aware, elastic-restore.

Layout of one checkpoint:
    <dir>/step_000120/
        manifest.json          # tree structure, shapes, dtypes, mesh info
        arrays/<leaf-id>.npy   # one file per leaf (addressable shards
                               # gathered per-leaf; on multi-host each host
                               # writes only shards it owns — here 1 host)
    <dir>/step_000120.COMMITTED   # atomic publish marker

Fault-tolerance properties:
  * writes go to a temp dir + atomic rename, then the COMMITTED marker is
    placed last → a crash mid-write never corrupts a restorable state;
  * ``restore`` takes the *target* mesh/shardings — restoring onto a
    different device count re-shards automatically (elastic down/up-scale);
  * async mode runs the serialization on a worker thread so the train loop
    is not blocked (double-buffered device→host copies);
  * keep_n garbage-collects old steps only after the newer one commits.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

try:
    import ml_dtypes
    _EXT_DTYPES = {"bfloat16": ml_dtypes.bfloat16}
except Exception:  # pragma: no cover
    _EXT_DTYPES = {}

PyTree = Any


def _to_storable(arr: np.ndarray):
    """npy can't round-trip ml_dtypes (bf16 → void); store as uint16 view
    + the dtype name in the manifest."""
    name = str(arr.dtype)
    if name in _EXT_DTYPES:
        return arr.view(np.uint16), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[dtype_name])
    return arr


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_n: int = 3,
                 async_write: bool = True) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None
             ) -> None:
        # Device→host copy happens synchronously (cheap, sharded), the
        # file I/O goes to the worker thread.
        host_leaves = []
        for name, leaf in _flatten_with_paths(tree):
            arr, dtype_name = _to_storable(np.asarray(leaf))
            host_leaves.append((name, arr, dtype_name))
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": step,
            "extra": extra or {},
            "leaves": [
                {"name": n, "shape": list(a.shape), "dtype": dn}
                for n, a, dn in host_leaves],
        }

        def write():
            tmp = self.dir / f".tmp_step_{step:09d}"
            final = self.dir / f"step_{step:09d}"
            marker = self.dir / f"step_{step:09d}.COMMITTED"
            if tmp.exists():
                shutil.rmtree(tmp)
            (tmp / "arrays").mkdir(parents=True)
            for i, (name, arr, _dn) in enumerate(host_leaves):
                np.save(tmp / "arrays" / f"{i:05d}.npy", arr)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            marker.touch()                      # atomic publish
            self._gc()

        self.wait()
        if self.async_write:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
            (self.dir / f"step_{s:09d}.COMMITTED").unlink(missing_ok=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for m in sorted(self.dir.glob("step_*.COMMITTED")):
            out.append(int(m.stem.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> Tuple[PyTree, Dict]:
        """Restore into the structure of ``tree_like`` (ShapeDtypeStructs or
        arrays). ``shardings`` (same structure) re-shards onto the *current*
        mesh — this is the elastic-restart path: a checkpoint written on a
        256-chip mesh restores cleanly onto 512 chips or 1 CPU."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        final = self.dir / f"step_{step:09d}"
        manifest = json.loads((final / "manifest.json").read_text())
        flat_like, treedef = jax.tree_util.tree_flatten(tree_like)
        assert len(flat_like) == len(manifest["leaves"]), \
            (len(flat_like), len(manifest["leaves"]))
        shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                      if shardings is not None else [None] * len(flat_like))
        leaves = []
        for i, (like, sh) in enumerate(zip(flat_like, shard_flat)):
            expect = manifest["leaves"][i]
            arr = _from_storable(np.load(final / "arrays" / f"{i:05d}.npy"),
                                 expect["dtype"])
            assert list(arr.shape) == expect["shape"]
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), \
            manifest["extra"]
