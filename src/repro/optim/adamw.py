"""AdamW with global-norm clipping, cosine schedule, optional int8
gradient compression with error feedback (distributed-optimization trick
for bandwidth-bound multi-pod gradient reduction)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"


class OptState(NamedTuple):
    m: PyTree
    v: PyTree
    step: jax.Array


def init_opt_state(params: PyTree, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return OptState(m=zeros,
                    v=jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
                    step=jnp.zeros((), jnp.int32))


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params: PyTree, grads: PyTree, state: OptState,
                  cfg: AdamWConfig) -> Tuple[PyTree, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback (for DCI-bound pods).
# ---------------------------------------------------------------------------

def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_grad(g: jax.Array, err: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback compression: quantize (g + carried error), carry the
    quantization residual to the next step."""
    g32 = g.astype(jnp.float32) + err
    q, scale = compress_int8(g32)
    deq = decompress_int8(q, scale)
    return deq.astype(g.dtype), g32 - deq
