import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Roofline-term extraction (deliverable g).

XLA's cost_analysis counts while-loop bodies once, so full-size compiles
undercount per-layer work. We therefore compile two PROBE variants of every
(arch × shape) cell — n_units and 2·n_units scan units — with
REPRO_FULL_UNROLL=1 (every lax.scan unrolled → every iteration counted),
and recover

    per_unit = probe(2u) − probe(u)          (exact per-layer terms)
    base     = probe(u) − per_unit           (embed + CE + caches)
    total    = base + n_units_full · per_unit

for FLOPs, HBM bytes and collective bytes. Probes run on the production
16×16 mesh with microbatches=1 (same per-step math).

Terms (per chip, TPU v5e):
    compute_s    = flops / 197e12
    memory_s     = hbm_bytes / 819e9
    collective_s = collective_bytes / 50e9 (per-link)
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, SHAPES, get_config, shapes_for
from repro.configs.base import BlockType, ModelConfig, ShapeSpec
from repro.distributed.api import activation_policy, policy_from_mesh
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        params_shardings, replicated)
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (input_specs, make_opt_config, model_shapes,
                                opt_shapes, prefill_step, serve_step,
                                train_step)

RESULT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "roofline"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def scan_unit(cfg: ModelConfig) -> int:
    """Layers per scan step (group size)."""
    if cfg.block_type is BlockType.MAMBA and cfg.attn_every:
        return cfg.attn_every
    if cfg.moe is not None and cfg.moe_every > 1:
        return cfg.moe_every
    return 1


def probe_cfg(cfg: ModelConfig, units: int) -> ModelConfig:
    return dataclasses.replace(cfg, n_layers=units * scan_unit(cfg))


def compile_cell(cfg: ModelConfig, shape: ShapeSpec, mesh):
    params_sds = model_shapes(cfg)
    # Serving-sharding strategy: decode wants weights RESIDENT (model-axis
    # TP only) — per-step FSDP re-gathers dominate the decode collective
    # term. Keep FSDP only when the bf16 weights don't fit 14 GB/chip at
    # TP=16 (llama4-400b, deepseek-236b).
    resident = (shape.kind == "decode"
                and cfg.param_count() * 2 / 16 <= 14e9)
    p_sh = params_shardings(params_sds, mesh, fsdp=not resident)
    specs = input_specs(cfg, shape)
    with mesh, activation_policy(
            policy_from_mesh(mesh, seq_parallel=shape.kind != "decode")):
        if shape.kind == "train":
            opt_sds = opt_shapes(cfg, params_sds)
            o_sh = params_shardings(opt_sds, mesh)
            b_sh = batch_shardings(specs, mesh)
            fn = functools.partial(train_step, cfg=cfg,
                                   opt_cfg=make_opt_config(cfg),
                                   microbatches=1)
            lowered = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                              out_shardings=(p_sh, o_sh, None)
                              ).lower(params_sds, opt_sds, specs)
        elif shape.kind == "prefill":
            b_sh = batch_shardings(specs, mesh)
            fn = functools.partial(prefill_step, cfg=cfg)
            lowered = jax.jit(fn, in_shardings=(p_sh, b_sh),
                              out_shardings=replicated(mesh)
                              ).lower(params_sds, specs)
        else:
            c_sh = cache_shardings(specs["cache"], mesh)
            tok_sh = batch_shardings({"tokens": specs["tokens"]},
                                     mesh)["tokens"]
            fn = functools.partial(serve_step, cfg=cfg)
            lowered = jax.jit(fn, in_shardings=(p_sh, tok_sh, c_sh,
                                                replicated(mesh)),
                              out_shardings=(replicated(mesh), c_sh)
                              ).lower(params_sds, specs["tokens"],
                                      specs["cache"],
                                      jax.ShapeDtypeStruct((), jnp.int32))
    return lowered.compile()


def probe_terms(cfg: ModelConfig, shape: ShapeSpec, units: int, mesh):
    c = compile_cell(probe_cfg(cfg, units), shape, mesh)
    cost = c.cost_analysis() or {}
    coll, by_op, counts = collective_bytes(c.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll),
        "coll_by_op": by_op,
    }


def analyze_cell(arch: str, shape_name: str) -> dict:
    os.environ["REPRO_FULL_UNROLL"] = "1"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    n_units_full = cfg.n_layers // scan_unit(cfg)
    t0 = time.time()
    r1 = probe_terms(cfg, shape, 1, mesh)
    r2 = probe_terms(cfg, shape, 2, mesh)
    per_unit = {k: r2[k] - r1[k] for k in ("flops", "bytes", "coll")}
    base = {k: r1[k] - per_unit[k] for k in per_unit}
    total = {k: max(0.0, base[k]) + n_units_full * max(0.0, per_unit[k])
             for k in per_unit}

    # Per-chip roofline terms (cost_analysis is per-device SPMD module).
    compute_s = total["flops"] / PEAK_FLOPS
    memory_s = total["bytes"] / HBM_BW
    collective_s = total["coll"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bound = max(terms, key=terms.get).replace("_s", "")

    # MODEL_FLOPS: 6·N·D (dense) / 6·N_active·D (MoE); decode D = new tokens.
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * cfg.active_param_count() * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * cfg.active_param_count() * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * cfg.active_param_count() * tokens
    model_flops_per_chip = model_flops / 256
    hlo_flops = total["flops"]
    ratio = model_flops_per_chip / hlo_flops if hlo_flops else float("nan")

    out = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "n_units": n_units_full,
        "per_unit": per_unit, "base": base, "total": total,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "bound": bound,
        "roofline_total_s": max(compute_s, memory_s, collective_s),
        "model_flops_per_chip": model_flops_per_chip,
        "hlo_flops_per_chip": hlo_flops,
        "useful_flops_ratio": ratio,
        "probe_wall_s": round(time.time() - t0, 1),
        "ok": True,
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    RESULT_DIR.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shp in shapes_for(get_config(arch)):
                cells.append((arch, shp.name))
    else:
        cells = [(args.arch, args.shape)]

    for arch, shp in cells:
        fname = RESULT_DIR / f"{arch}__{shp}.json"
        if args.skip_existing and fname.exists() and \
                json.loads(fname.read_text()).get("ok"):
            print(f"[skip] {arch} × {shp}", flush=True)
            continue
        try:
            r = analyze_cell(arch, shp)
            fname.write_text(json.dumps(r, indent=2))
            print(f"[OK] {arch} × {shp}: bound={r['bound']} "
                  f"compute={r['compute_s']*1e3:.2f}ms "
                  f"mem={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms "
                  f"ratio={r['useful_flops_ratio']:.2f} "
                  f"[{r['probe_wall_s']}s]", flush=True)
        except Exception as e:
            fname.write_text(json.dumps(
                {"arch": arch, "shape": shp, "ok": False, "error": repr(e),
                 "traceback": traceback.format_exc()[-3000:]}, indent=2))
            print(f"[FAIL] {arch} × {shp}: {e!r}", flush=True)


if __name__ == "__main__":
    main()
