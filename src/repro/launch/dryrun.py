import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against 512 placeholder host devices, record memory_analysis /
cost_analysis / collective bytes for §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all          # every cell
"""
import argparse
import functools
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, get_config, shapes_for
from repro.distributed.api import (activation_policy, policy_from_mesh)
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        data_axes, params_shardings,
                                        replicated)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (input_specs, make_opt_config, model_shapes,
                                opt_shapes, prefill_step, serve_step,
                                train_step)

RESULT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str):
    """Sum operand sizes of every collective op in the optimized HLO.

    Operand shapes appear inline in the op's argument list; the eventual
    result shape is the first typed token on the line — we count operands
    (falling back to the result for fused/variadic forms).
    """
    per_op = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*[a-z0-9\[\],\s()]*?\s([a-z-]+)\(", stripped)
        op = None
        for c in _COLLECTIVES:
            if f" {c}(" in stripped or f"{c}-start(" in stripped \
                    or f"{c}-done(" in stripped:
                op = c
                break
        if op is None:
            continue
        if f"{op}-done(" in stripped:
            continue  # counted at -start
        shapes = _SHAPE_RE.findall(stripped)
        if not shapes:
            continue
        # first match(es) before the op name are result types; operands
        # follow inside the parens. Split on the op occurrence.
        idx = stripped.find(op + "(")
        if idx == -1:
            idx = stripped.find(op + "-start(")
        operand_part = stripped[idx:] if idx >= 0 else stripped
        operand_shapes = _SHAPE_RE.findall(operand_part)
        use = operand_shapes if operand_shapes else shapes[:1]
        per_op[op] += sum(_shape_bytes(d, s) for d, s in use)
        counts[op] += 1
    total = sum(per_op.values())
    return total, per_op, counts


def _spec_leaves(tree):
    return jax.tree.leaves(tree)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path = RESULT_DIR, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    t0 = time.time()

    params_sds = model_shapes(cfg)
    # Serving-sharding strategy: decode wants weights RESIDENT (model-axis
    # TP only) — per-step FSDP re-gathers dominate the decode collective
    # term. Keep FSDP only when the bf16 weights don't fit 14 GB/chip at
    # TP=16 (llama4-400b, deepseek-236b).
    resident = (shape.kind == "decode"
                and cfg.param_count() * 2 / 16 <= 14e9)
    p_sh = params_shardings(params_sds, mesh, fsdp=not resident)
    specs = input_specs(cfg, shape)

    with mesh, activation_policy(
            policy_from_mesh(mesh, seq_parallel=shape.kind != "decode")):
        if shape.kind == "train":
            opt_sds = opt_shapes(cfg, params_sds)
            o_sh = params_shardings(opt_sds, mesh)
            b_sh = batch_shardings(specs, mesh)
            n_data = 1
            for a in data_axes(mesh):
                n_data *= mesh.shape[a]
            micro = max(1, min(16, shape.global_batch // n_data))
            fn = functools.partial(train_step, cfg=cfg,
                                   opt_cfg=make_opt_config(cfg),
                                   microbatches=micro)
            jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None))
            lowered = jitted.lower(params_sds, opt_sds, specs)
        elif shape.kind == "prefill":
            b_sh = batch_shardings(specs, mesh)
            fn = functools.partial(prefill_step, cfg=cfg)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh),
                             out_shardings=replicated(mesh))
            lowered = jitted.lower(params_sds, specs)
        else:  # decode
            c_sh = cache_shardings(specs["cache"], mesh)
            tok_sh = batch_shardings({"tokens": specs["tokens"]},
                                     mesh)["tokens"]
            fn = functools.partial(serve_step, cfg=cfg)
            jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, c_sh,
                                               replicated(mesh)),
                             out_shardings=(replicated(mesh), c_sh))
            lowered = jitted.lower(params_sds, specs["tokens"],
                                   specs["cache"],
                                   jax.ShapeDtypeStruct((), jnp.int32))
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_in_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception:
        mem_d = {}

    hlo = compiled.as_text()
    coll_total, coll_by_op, coll_counts = collective_bytes(hlo)

    n_dev = mesh.devices.size
    # Per-device parameter/optimizer bytes from the sharding specs.
    def sharded_bytes(sds_tree, sh_tree):
        total = 0
        for sds, sh in zip(jax.tree.leaves(sds_tree),
                           jax.tree.leaves(sh_tree)):
            shard_elems = 1
            spec = sh.spec
            for i, dim in enumerate(sds.shape):
                ax = spec[i] if i < len(spec) else None
                if ax is None:
                    shard_elems *= dim
                else:
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    size = 1
                    for a in axes:
                        size *= mesh.shape[a]
                    shard_elems *= -(-dim // size)
            total += shard_elems * sds.dtype.itemsize
        return total

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "n_devices": n_dev,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_total": cost.get("flops"),
        "bytes_accessed_total": cost.get("bytes accessed"),
        "cost_analysis_keys": sorted(cost)[:40],
        "memory_analysis": mem_d,
        "collective_bytes_total": coll_total,
        "collective_bytes_by_op": coll_by_op,
        "collective_op_counts": coll_counts,
        "param_bytes_per_device": sharded_bytes(params_sds, p_sh),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "ok": True,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    fname.write_text(json.dumps(result, indent=2, default=str))
    if verbose:
        print(f"[OK] {arch} × {shape_name} × {mesh_name}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops={cost.get('flops', 0):.3g} "
              f"coll={coll_total/1e9:.2f}GB", flush=True)
        print("  memory_analysis:", mem_d, flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            cfg = get_config(arch)
            for shp in shapes_for(cfg):
                for mp in ((False, True) if args.mesh == "both"
                           else ((args.mesh == "multipod"),)):
                    cells.append((arch, shp.name, mp))
    else:
        assert args.arch and args.shape
        meshes = ((False, True) if args.mesh == "both"
                  else ((args.mesh == "multipod"),))
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    failures = 0
    for arch, shp, mp in cells:
        mesh_name = "multipod_2x16x16" if mp else "pod_16x16"
        fname = RESULT_DIR / f"{arch}__{shp}__{mesh_name}.json"
        if args.skip_existing and fname.exists():
            prev = json.loads(fname.read_text())
            if prev.get("ok"):
                print(f"[skip] {arch} × {shp} × {mesh_name}", flush=True)
                continue
        try:
            run_cell(arch, shp, mp)
        except Exception as e:  # record failure for triage
            failures += 1
            RESULT_DIR.mkdir(parents=True, exist_ok=True)
            fname.write_text(json.dumps({
                "arch": arch, "shape": shp, "mesh": mesh_name, "ok": False,
                "error": repr(e),
                "traceback": traceback.format_exc()[-4000:]}, indent=2))
            print(f"[FAIL] {arch} × {shp} × {mesh_name}: {e!r}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
