"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --reduced \
      --requests 6 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_model(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_size=args.batch,
                           max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=args.prompt_len).astype(np.int32)
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new))
    out = engine.run_until_done()
    dt = time.time() - t0
    total_new = sum(len(v) for v in out.values())
    for rid in sorted(out):
        print(f"request {rid}: {out[rid]}")
    print(f"{args.requests} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s, batch={args.batch})")


if __name__ == "__main__":
    main()
