"""Jittable train / serve step functions and dry-run input specs."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.model import (decode_step, forward, init_cache, init_model,
                                loss_fn, logits_from_hidden, prefill)
from repro.optim.adamw import (AdamWConfig, OptState, apply_updates,
                               init_opt_state)

PyTree = Any


def make_opt_config(cfg: ModelConfig, total_steps: int = 10000) -> AdamWConfig:
    return AdamWConfig(state_dtype=cfg.opt_dtype, total_steps=total_steps)


def train_step(params: PyTree, opt_state: OptState,
               batch: Dict[str, jax.Array], *, cfg: ModelConfig,
               opt_cfg: AdamWConfig, microbatches: int = 1
               ) -> Tuple[PyTree, OptState, Dict[str, jax.Array]]:
    """One optimizer step, optionally microbatched.

    Microbatching bounds the live activation set to one microbatch (the
    64-layer × 12k-wide archs need this to stay under 16 GB/chip) and lets
    XLA overlap each microbatch's gradient reduce-scatter with the next
    microbatch's compute — the classic accumulation/overlap trick.
    """
    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, dict(metrics, loss=loss, **opt_metrics)

    mb_batch = jax.tree.map(
        lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                            *x.shape[1:]), batch)

    def body(acc, batch_mb):
        acc_g, acc_loss, acc_ce = acc
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch_mb, cfg)
        acc_g = jax.tree.map(
            lambda a, g: a + g.astype(a.dtype) / microbatches, acc_g, grads)
        return (acc_g, acc_loss + loss / microbatches,
                acc_ce + metrics["ce"] / microbatches), None

    acc_dt = jnp.dtype(opt_cfg.state_dtype)
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
    (grads, loss, ce), _ = jax.lax.scan(
        body, (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        mb_batch)
    params, opt_state, opt_metrics = apply_updates(params, grads, opt_state,
                                                   opt_cfg)
    return params, opt_state, dict(loss=loss, ce=ce, **opt_metrics)


def prefill_step(params: PyTree, batch: Dict[str, jax.Array], *,
                 cfg: ModelConfig) -> jax.Array:
    return prefill(params, batch["tokens"], cfg,
                   batch.get("frontend_embeds"))


def serve_step(params: PyTree, tokens: jax.Array, cache: PyTree,
               pos: jax.Array, *, cfg: ModelConfig
               ) -> Tuple[jax.Array, PyTree]:
    """One decode step: new token for every sequence in the batch."""
    return decode_step(params, tokens, cache, pos, cfg)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins (no allocation) for every model input.
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Dry-run inputs for the given (arch × shape) cell.

    train/prefill: {'tokens': (B, S_text) i32 [, 'frontend_embeds']}
    decode:        {'tokens': (B, 1) i32, 'pos': scalar i32, 'cache': pytree}
    """
    b, s = shape.global_batch, shape.seq_len
    n_front = cfg.frontend_tokens if cfg.frontend != "none" else 0
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, s - n_front), jnp.int32)}
        if n_front:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, n_front, cfg.frontend_dim), jnp.dtype(cfg.dtype))
        return specs
    # decode: cache holds `s` tokens of context, one new token comes in.
    cache = jax.eval_shape(functools.partial(init_cache, cfg, b, s))
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }


def model_shapes(cfg: ModelConfig) -> PyTree:
    """Parameter ShapeDtypeStructs without allocating."""
    return jax.eval_shape(functools.partial(init_model, cfg),
                          jax.random.PRNGKey(0))


def opt_shapes(cfg: ModelConfig, params_sds: PyTree) -> PyTree:
    return jax.eval_shape(
        functools.partial(init_opt_state, cfg=make_opt_config(cfg)),
        params_sds)
