"""End-to-end training driver.

CPU-runnable example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --reduced \
      --steps 50 --batch 8 --seq 128

On a real cluster the same driver runs with --mesh pod/multipod (the mesh
helper builds the production meshes) and the checkpoint manager provides
restart/elastic-resume; the supervisor loop retries through failures.
"""
from __future__ import annotations

import argparse
import functools
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_batch
from repro.distributed.api import activation_policy, policy_from_mesh
from repro.distributed.fault import run_with_retries
from repro.distributed.sharding import batch_shardings, params_shardings
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.launch.steps import make_opt_config, train_step
from repro.models.model import init_model
from repro.optim.adamw import init_opt_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", choices=["smoke", "pod", "multipod"],
                    default="smoke")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = (make_smoke_mesh() if args.mesh == "smoke"
            else make_production_mesh(multi_pod=args.mesh == "multipod"))
    opt_cfg = make_opt_config(cfg, total_steps=args.steps)
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq)

    params = init_model(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(params, opt_cfg)
    p_sh = params_shardings(params, mesh)
    o_sh = params_shardings(opt_state, mesh)
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    mgr = CheckpointManager(Path(args.ckpt_dir) / cfg.name)
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore(
            (params, opt_state), shardings=(p_sh, o_sh))
        start_step = int(extra.get("step", mgr.latest_step()))
        print(f"resumed from step {start_step}")

    step_jit = jax.jit(
        functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                          microbatches=args.microbatches),
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1))

    state = {"params": params, "opt": opt_state}

    def one_step(step: int) -> None:
        batch = make_batch(dcfg, cfg, step, mesh)
        t0 = time.time()
        with mesh, activation_policy(policy_from_mesh(mesh)):
            state["params"], state["opt"], metrics = step_jit(
                state["params"], state["opt"], batch)
        if step % args.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            print(f"step {step:5d}  loss {loss:8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):7.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"dt {time.time() - t0:6.2f}s", flush=True)

    def save(step: int) -> None:
        mgr.save(step, (state["params"], state["opt"]),
                 extra={"step": step})

    def restore() -> int:
        (state["params"], state["opt"]), extra = mgr.restore(
            (state["params"], state["opt"]), shardings=(p_sh, o_sh))
        return int(extra["step"])

    stats = run_with_retries(one_step, save, restore,
                             n_steps=args.steps,
                             checkpoint_every=args.ckpt_every)
    mgr.wait()
    print(f"done: {stats}")


if __name__ == "__main__":
    main()
