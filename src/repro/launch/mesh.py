"""Production meshes. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2,
    data=16, model=16) = 512 chips; the pod axis composes with data for
    gradient reduction (hierarchical: reduce-scatter in-pod over ICI, then
    inter-pod all-reduce over DCI)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the same axis names, for CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_devices=None):
    """Pure data-parallel mesh over the first ``n_devices`` devices (all by
    default) — the CNN serving shape: params are replicated, the batch dim
    shards on the single "data" axis. One executable per batch bucket stays
    one executable; only its batch placement changes
    (``cnn.executor.compile_plan(..., mesh=...)``)."""
    import numpy as np
    from jax.sharding import Mesh
    devices = jax.devices()
    if n_devices is not None:
        if not 1 <= n_devices <= len(devices):
            raise ValueError(f"n_devices={n_devices} not in "
                             f"[1, {len(devices)}]")
        devices = devices[:n_devices]
    return Mesh(np.asarray(devices), ("data",))
