"""Production meshes. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2,
    data=16, model=16) = 512 chips; the pod axis composes with data for
    gradient reduction (hierarchical: reduce-scatter in-pod over ICI, then
    inter-pod all-reduce over DCI)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the same axis names, for CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
