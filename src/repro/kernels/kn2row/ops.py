"""Public wrapper: full kn2row convolution = batched unit-conv GEMMs
(Pallas) + pad-and-accumulate (Pallas).

The unit-conv GEMM is (H·W, Cin) × (Cin, Cout); the plan's dataflow binds
(p1, p2) straight onto the (bm, bn, bk) block dims via Eq. 9 — kn2row is the
one algorithm whose GEMM shape matches the binding with no translation.
Accepts (H, W, Cin) or batched (B, H, W, Cin) inputs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.cost_model import Dataflow
from repro.kernels.common import (batchable, ceil_to, default_interpret,
                                  pad_bias)
from repro.kernels.gemm.ops import dataflow_blocks
from repro.kernels.kn2row.kn2row import pad_accumulate, unit_conv_gemms
from repro.kernels.layouts import materialize, restore


@batchable
@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "dataflow", "p1", "p2", "interpret", "epilogue",
    "in_layout", "out_layout", "out_scale"))
def conv_kn2row(x: jax.Array, w: jax.Array, stride: int = 1,
                padding: str = "SAME",
                dataflow: Dataflow = Dataflow.NS,
                p1: int = 128, p2: int = 128,
                interpret: Optional[bool] = None,
                epilogue: str = "none",
                bias: Optional[jax.Array] = None,
                in_layout=None, out_layout=None,
                scale: Optional[jax.Array] = None,
                out_scale: Optional[float] = None) -> jax.Array:
    """Convolution via kn2row. x: (H, W, Cin) or (B, H, W, Cin),
    w: (K1, K2, Cin, Cout) → (…, O1, O2, Cout). ``epilogue`` fuses the
    post-GEMM auxiliary unit into the final pad-accumulate flush.

    kn2row's input layout IS the 3-D tensor (§3.3), so a matched
    ``in_layout`` is simply NHWC; other layouts are restored on entry
    (converting load), and ``out_layout`` emits a consumer's store format."""
    interpret = default_interpret() if interpret is None else interpret
    x = restore(x, in_layout)
    h, w_dim, c_in = x.shape
    k1, k2, _, c_out = w.shape
    if padding == "SAME":
        o1, o2 = -(-h // stride), -(-w_dim // stride)
        ph = max((o1 - 1) * stride + k1 - h, 0)
        pw = max((o2 - 1) * stride + k2 - w_dim, 0)
        pt, pl_ = ph // 2, pw // 2
    else:
        o1 = (h - k1) // stride + 1
        o2 = (w_dim - k2) // stride + 1
        pt = pl_ = 0

    # Phase 1: (H*W, Cin) @ (K1K2, Cin, Cout) under the plan's block binding.
    bm, bn, bk = dataflow_blocks(dataflow, p1, p2)
    m = h * w_dim
    # int8 blocks need the (32, 128) minimum tile on real hardware.
    bm_ = min(bm, ceil_to(m, 32 if x.dtype == jnp.int8 else 8))
    bn_ = min(bn, ceil_to(c_out, 128))
    bk_ = min(bk, ceil_to(c_in, 128))
    mp, np_, kp = ceil_to(m, bm_), ceil_to(c_out, bn_), ceil_to(c_in, bk_)
    x2d = jnp.pad(x.reshape(m, c_in), ((0, mp - m), (0, kp - c_in)))
    wk = jnp.pad(w.reshape(k1 * k2, c_in, c_out),
                 ((0, 0), (0, kp - c_in), (0, np_ - c_out)))
    p = unit_conv_gemms(x2d, wk, bm=bm_, bn=bn_, bk=bk_,
                        interpret=interpret)          # (K1K2, mp, np_)
    p = p[:, :m, :].reshape(k1 * k2, h, w_dim, np_)

    # Phase 2: zero-pad so every (k1,k2) shift is a plain slice, then
    # accumulate on-chip.
    p = jnp.pad(p, ((0, 0), (pt, k1), (pl_, k2), (0, 0)))
    out = pad_accumulate(p, k1=k1, k2=k2, o1=o1, o2=o2, stride=stride,
                         interpret=interpret, epilogue=epilogue,
                         bias=pad_bias(bias, c_out, np_),
                         scale=pad_bias(scale, c_out, np_),
                         out_scale=out_scale)
    return materialize(out[:, :, :c_out], out_layout)
