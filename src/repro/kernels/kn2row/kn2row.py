"""kn2row convolution: K1·K2 unit-conv GEMMs + Pad-and-Accumulate (§2.1.2).

Phase 1 ("unit-CONV GEMM", Eq. 3): each (k1, k2) kernel offset is a 1×1
convolution — a (H1H2, Cin) × (Cin, Cout) GEMM. We run all K1K2 of them as
one batched Pallas GEMM whose input block index map ignores the batch
coordinate, so the feature-map block is fetched once and stays VMEM-resident
across offsets (the paper's pipelining of the two phases).

Phase 2 ("Pad-and-Accumulate", Eq. 4): each intermediate patch p_{k1,k2} is
shifted by its offset w.r.t. the center patch and Hadamard-added. The Pallas
kernel walks the K1K2 patches with the output block resident in VMEM
(contiguous revisits), which is the accumulation-buffer design of §3.1 —
bank conflicts become a non-issue because the partial sums never leave VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import apply_epilogue

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


# ---------------------------------------------------------------------------
# Phase 1 — unit-conv GEMMs, batched over kernel offsets.
# ---------------------------------------------------------------------------

def unit_conv_gemms(x2d: jax.Array, w: jax.Array, *, bm: int, bn: int,
                    bk: int, interpret: bool = True) -> jax.Array:
    """x2d: (H1H2, Cin); w: (K1K2, Cin, Cout) → p: (K1K2, H1H2, Cout)."""
    m, k = x2d.shape
    g, k2, n = w.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    # Int8 phase 1 keeps exact int32 partial products; dequantization
    # waits for the phase-2 flush (scale is constant across offsets).
    quantized = x2d.dtype == jnp.int8
    acc_dtype = jnp.int32 if quantized else jnp.float32
    out_dtype = jnp.int32 if quantized else x2d.dtype

    def kernel(x_ref, w_ref, o_ref, acc_ref):
        kk = pl.program_id(3)

        @pl.when(kk == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                                preferred_element_type=acc_dtype)

        @pl.when(kk == nk - 1)
        def _flush():
            o_ref[0] = acc_ref[...].astype(o_ref.dtype)

    scratch = (pltpu.VMEM((bm, bn), acc_dtype) if _VMEM is not None
               else pl.ANY)  # pragma: no cover
    return pl.pallas_call(
        kernel,
        grid=(g, m // bm, n // bn, nk),
        in_specs=[
            # Note: index map ignores g → the X block is re-used across all
            # K1K2 unit convolutions without re-fetch.
            pl.BlockSpec((bm, bk), lambda gg, i, j, kk: (i, kk)),
            pl.BlockSpec((1, bk, bn), lambda gg, i, j, kk: (gg, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, m, n), out_dtype),
        scratch_shapes=[scratch],
        interpret=interpret,
    )(x2d, w)


# ---------------------------------------------------------------------------
# Phase 2 — Pad-and-Accumulate.
# ---------------------------------------------------------------------------

def pad_accumulate(p: jax.Array, *, k1: int, k2: int, o1: int, o2: int,
                   stride: int = 1, pad_top: int = 0, pad_left: int = 0,
                   interpret: bool = True, epilogue: str = "none",
                   bias: jax.Array = None, scale: jax.Array = None,
                   out_scale: float = None) -> jax.Array:
    """p: (K1K2, H1p, H2p, Cout) — patches already zero-padded so that the
    (k1, k2) shift is a pure dynamic_slice; returns (O1, O2, Cout).

    Eq. 4: z[y, x] = Σ_{k1,k2} p_{k1,k2}[S·y + k1 - pt, S·x + k2 - pl],
    realized as slice(start=(k1, k2)) on the padded patch tensor. As the
    final kn2row stage, it owns the fused epilogue: the accumulated output
    streams through ReLU/bias at the flush, before ever leaving VMEM.

    Int8 path: ``p`` holds exact int32 unit-conv partials; accumulation
    stays int32 and the flush dequantizes with ``scale`` ((1, C) per-
    output-channel), then bias/relu, then the optional ``out_scale``
    requantize — the whole chain in one VMEM-resident pass.
    """
    g, h1p, h2p, c = p.shape
    assert g == k1 * k2
    span_r = (o1 - 1) * stride + 1
    span_c = (o2 - 1) * stride + 1
    assert h1p >= span_r + k1 - 1 and h2p >= span_c + k2 - 1, \
        (p.shape, span_r, span_c)
    quantized = p.dtype == jnp.int32
    acc_dtype = jnp.int32 if quantized else jnp.float32
    out_dtype = (jnp.int8 if out_scale is not None
                 else jnp.float32 if quantized else p.dtype)
    has_scale = scale is not None

    def kernel(p_ref, *rest):
        rest = list(rest)
        scale_ref = rest.pop(0) if has_scale else None
        o_ref, acc_ref = rest[-2], rest[-1]
        bias_ref = rest[0] if len(rest) == 3 else None
        gg = pl.program_id(0)

        @pl.when(gg == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        dk1 = gg // k2
        dk2 = gg % k2
        patch = p_ref[0]                              # (H1p, H2p, C)
        sl = jax.lax.dynamic_slice(patch, (dk1, dk2, 0), (span_r, span_c, c))
        acc_ref[...] += sl[::stride, ::stride, :].astype(acc_dtype)

        @pl.when(gg == g - 1)
        def _flush():
            acc = apply_epilogue(
                acc_ref[...], epilogue,
                bias_ref[0] if bias_ref is not None else None,
                scale=scale_ref[0] if scale_ref is not None else None,
                out_scale=out_scale)
            o_ref[...] = acc.astype(o_ref.dtype)

    scratch = (pltpu.VMEM((o1, o2, c), acc_dtype) if _VMEM is not None
               else pl.ANY)  # pragma: no cover
    in_specs = [pl.BlockSpec((1, h1p, h2p, c), lambda gg: (gg, 0, 0, 0))]
    operands = [p]
    if scale is not None:
        assert scale.shape == (1, c), (scale.shape, c)
        in_specs.append(pl.BlockSpec((1, c), lambda gg: (0, 0)))
        operands.append(scale)
    if bias is not None:
        assert bias.shape == (1, c), (bias.shape, c)
        in_specs.append(pl.BlockSpec((1, c), lambda gg: (0, 0)))
        operands.append(bias)
    return pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((o1, o2, c), lambda gg: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((o1, o2, c), out_dtype),
        scratch_shapes=[scratch],
        interpret=interpret,
    )(*operands)
