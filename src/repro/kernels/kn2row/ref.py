"""Pure-jnp oracle for kn2row (Eq. 3 + Eq. 4), independent of lax.conv."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import batchable


@batchable
def kn2row_ref(x: jax.Array, w: jax.Array, stride: int = 1,
               padding: str = "SAME") -> jax.Array:
    """x: (H, W, Cin) or (B, H, W, Cin); w: (K1, K2, Cin, Cout)."""
    h, w_dim, c_in = x.shape
    k1, k2, _, c_out = w.shape
    if padding == "SAME":
        o1, o2 = -(-h // stride), -(-w_dim // stride)
        ph = max((o1 - 1) * stride + k1 - h, 0)
        pw = max((o2 - 1) * stride + k2 - w_dim, 0)
        pt, pl_ = ph // 2, pw // 2
    else:
        o1 = (h - k1) // stride + 1
        o2 = (w_dim - k2) // stride + 1
        pt = pl_ = 0
    # Phase 1: unit convs p_{k1,k2} = X · W[k1,k2]  (Eq. 3) at input res.
    x32 = x.astype(jnp.float32)
    acc = jnp.zeros((o1, o2, c_out), jnp.float32)
    # Phase 2: shift + Hadamard-add (Eq. 4).
    xp = jnp.pad(x32, ((pt, k1), (pl_, k2), (0, 0)))
    for dk1 in range(k1):
        for dk2 in range(k2):
            p = xp @ w[dk1, dk2].astype(jnp.float32)       # (Hp, Wp, Cout)
            sl = p[dk1:dk1 + (o1 - 1) * stride + 1:stride,
                   dk2:dk2 + (o2 - 1) * stride + 1:stride, :]
            acc = acc + sl
    return acc.astype(x.dtype)
