"""Shared helpers for the Pallas kernels."""
from __future__ import annotations

import functools
import os
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def pad_to(x: jax.Array, multiples: Sequence[int]) -> jax.Array:
    """Zero-pad each dim of ``x`` up to the given multiple (0 = leave alone)."""
    pads = []
    for dim, mult in zip(x.shape, multiples):
        pads.append((0, (ceil_to(dim, mult) - dim) if mult else 0))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def batchable(fn):
    """Lift a single-image conv ``fn(x: (H, W, C), ...)`` to also accept a
    batched ``(B, H, W, C)`` input by vmapping over the leading axis.

    Pallas kernels batch via ``pallas_call``'s batching rule (an extra outer
    grid dimension), so one compiled program serves the whole batch; the
    jnp reference paths batch for free.
    """
    @functools.wraps(fn)
    def wrapper(x, *args, **kwargs):
        if x.ndim == 4:
            return jax.vmap(lambda xi: fn(xi, *args, **kwargs))(x)
        return fn(x, *args, **kwargs)
    return wrapper


def default_interpret() -> bool:
    """Kernels run in interpret mode unless a real TPU backend is present.

    This container is CPU-only; TPU v5e is the compilation *target*. The env
    var REPRO_PALLAS_INTERPRET=0 forces compiled mode (on real hardware).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"
