"""Shared helpers for the Pallas kernels."""
from __future__ import annotations

import os
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def pad_to(x: jax.Array, multiples: Sequence[int]) -> jax.Array:
    """Zero-pad each dim of ``x`` up to the given multiple (0 = leave alone)."""
    pads = []
    for dim, mult in zip(x.shape, multiples):
        pads.append((0, (ceil_to(dim, mult) - dim) if mult else 0))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def default_interpret() -> bool:
    """Kernels run in interpret mode unless a real TPU backend is present.

    This container is CPU-only; TPU v5e is the compilation *target*. The env
    var REPRO_PALLAS_INTERPRET=0 forces compiled mode (on real hardware).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"
