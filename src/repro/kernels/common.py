"""Shared helpers for the Pallas kernels."""
from __future__ import annotations

import functools
import os
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


# Post-GEMM epilogues the Computing Unit can fuse into a kernel's output
# flush (§3's in-pipeline auxiliary units: the conv output streams through
# ReLU/bias without a DRAM round trip). "none" is the identity.
EPILOGUES = ("none", "relu", "bias", "bias_relu")

# Symmetric int8: zero-point 0, range [-127, 127] (−128 excluded so the
# range is sign-symmetric and |q|·|q| accumulation bounds stay tight).
INT8_MAX = 127
_SCALE_EPS = 1e-12

# Per-layer precisions the mapper can assign. Winograd is bf16-only (its
# input/output transforms amplify quantization error), which the cost
# graph encodes by never emitting an int8 label for Winograd algorithms.
PRECISIONS = ("bf16", "int8")


def apply_epilogue(y: jax.Array, epilogue: str,
                   bias: jax.Array = None, *,
                   scale: jax.Array = None,
                   out_scale: float = None) -> jax.Array:
    """Apply a named epilogue; ``bias`` broadcasts over the minor dim.

    Quantized variants: ``scale`` (broadcasting over the minor dim, the
    per-output-channel ``in_scale * w_scale`` product) dequantizes an
    int32 accumulator to f32 *before* bias/relu; ``out_scale`` (a static
    per-tensor float) requantizes the epilogue result back to int8
    *after* bias/relu — so CONV+bias+ReLU+requant is one fused flush.
    """
    if epilogue not in EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}; want {EPILOGUES}")
    if scale is not None:
        y = y.astype(jnp.float32) * scale
    if epilogue.startswith("bias"):
        if bias is None:
            raise ValueError(f"epilogue {epilogue!r} needs a bias array")
        y = y + bias.astype(y.dtype)
    if epilogue.endswith("relu"):
        y = jnp.maximum(y, 0)
    if out_scale is not None:
        y = requantize(y, out_scale)
    return y


def quantize(x: jax.Array, scale) -> jax.Array:
    """f32 → symmetric int8 with the given scale (array or python float).

    ``scale`` broadcasts, so a per-tensor scalar and a per-output-channel
    vector both work; values round to nearest and saturate at ±INT8_MAX.
    """
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)


def dequantize(q: jax.Array, scale) -> jax.Array:
    """int8 (or int32 accumulator) → f32: multiply by the scale."""
    return q.astype(jnp.float32) * scale


def requantize(y: jax.Array, out_scale: float) -> jax.Array:
    """f32 epilogue output → int8 at the consumer's activation scale."""
    q = jnp.round(y.astype(jnp.float32) / out_scale)
    return jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)


def weight_scales(w: jax.Array) -> jax.Array:
    """Per-output-channel symmetric scales for a weight tensor whose LAST
    axis is the output channel (both (K1,K2,Cin,Cout) and (K,Cout) 2-D
    GEMM operands qualify). Returns an f32 vector of shape (Cout,)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)),
                   axis=tuple(range(w.ndim - 1)))
    return jnp.maximum(amax, _SCALE_EPS) / INT8_MAX


def pad_bias(bias, n: int, n_padded: int):
    """Prep a fused-epilogue bias for a Pallas kernel: (N,) → (1, N_padded),
    zero-padded channels (they are sliced away with the padded output)."""
    if bias is None:
        return None
    assert bias.shape == (n,), (bias.shape, n)
    return jnp.pad(bias, (0, n_padded - n)).reshape(1, n_padded)


def ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def pad_to(x: jax.Array, multiples: Sequence[int]) -> jax.Array:
    """Zero-pad each dim of ``x`` up to the given multiple (0 = leave alone)."""
    pads = []
    for dim, mult in zip(x.shape, multiples):
        pads.append((0, (ceil_to(dim, mult) - dim) if mult else 0))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def batchable(fn):
    """Lift a single-image conv ``fn(x, ...)`` to also accept a batched
    input by vmapping over the leading axis.

    The un-batched rank of ``x`` depends on the input layout the call
    carries (``in_layout`` kwarg, a ``core.layouts.LayoutSpec``): NHWC is
    rank 3, a Toeplitz matrix rank 2, Winograd tiles rank 4 — one extra
    dim means a batch. Pallas kernels batch via ``pallas_call``'s batching
    rule (an extra outer grid dimension), so one compiled program serves
    the whole batch; the jnp reference paths batch for free.
    """
    @functools.wraps(fn)
    def wrapper(x, *args, **kwargs):
        spec = kwargs.get("in_layout")
        base = 3 if spec is None or spec.kind == "nhwc" else spec.base_rank
        if x.ndim == base + 1:
            return jax.vmap(lambda xi: fn(xi, *args, **kwargs))(x)
        return fn(x, *args, **kwargs)
    return wrapper


def default_interpret() -> bool:
    """Kernels run in interpret mode unless a real TPU backend is present.

    This container is CPU-only; TPU v5e is the compilation *target*. The env
    var REPRO_PALLAS_INTERPRET=0 forces compiled mode (on real hardware).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"
