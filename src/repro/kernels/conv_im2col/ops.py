"""Public wrapper for the implicit-GEMM im2col convolution."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import ceil_to, default_interpret
from repro.kernels.conv_im2col.conv_im2col import conv_im2col_call


@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "bo1", "bc", "interpret"))
def conv_im2col(x: jax.Array, w: jax.Array, stride: int = 1,
                padding: str = "SAME", bo1: int = 8, bc: int = 128,
                interpret: Optional[bool] = None) -> jax.Array:
    """Convolution via the im2col algorithm. x: (H, W, Cin),
    w: (K1, K2, Cin, Cout) → (O1, O2, Cout)."""
    interpret = default_interpret() if interpret is None else interpret
    h, w_dim, c_in = x.shape
    k1, k2, _, c_out = w.shape
    if padding == "SAME":
        o1, o2 = -(-h // stride), -(-w_dim // stride)
        ph = max((o1 - 1) * stride + k1 - h, 0)
        pw = max((o2 - 1) * stride + k2 - w_dim, 0)
        xp = jnp.pad(x, ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2),
                         (0, 0)))
    else:
        o1 = (h - k1) // stride + 1
        o2 = (w_dim - k2) // stride + 1
        xp = x
    bo1 = min(bo1, o1)
    o1p = ceil_to(o1, bo1)
    # Extra bottom/right rows so the last block's window slices stay in
    # bounds (they produce rows we slice off afterwards).
    need_r = (o1p - 1) * stride + k1
    need_c = (o2 - 1) * stride + k2
    xp = jnp.pad(xp, ((0, max(0, need_r - xp.shape[0])),
                      (0, max(0, need_c - xp.shape[1])), (0, 0)))
    bc = min(bc, ceil_to(c_out, 128))
    c_outp = ceil_to(c_out, bc)
    wm = w.reshape(k1 * k2 * c_in, c_out)
    wm = jnp.pad(wm, ((0, 0), (0, c_outp - c_out)))
    out = conv_im2col_call(xp, wm, k1=k1, k2=k2, stride=stride,
                           o1=o1p, o2=o2, bo1=bo1, bc=bc,
                           interpret=interpret)
    return out[:o1, :, :c_out]
