"""Public wrapper for the implicit-GEMM im2col convolution.

The induced GEMM is (O1·O2, K1K2·Cin) × (K1K2·Cin, Cout); the plan's
dataflow binds (p1, p2) onto two of those dims (Eq. 9) and this wrapper
translates that binding into the kernel's (output-row, C_out) tiling:
the M-dim block covers ~bm GEMM rows (bo1 = bm // O2 output rows), the
N-dim block is bn. The K panel is held entirely in VMEM by construction
(the whole feature map is kernel-resident), so the streamed dim needs no
tile. Accepts (H, W, Cin) or batched (B, H, W, Cin) inputs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.cost_model import Dataflow
from repro.kernels.common import (batchable, ceil_to, default_interpret,
                                  pad_bias)
from repro.kernels.conv_im2col.conv_im2col import conv_im2col_call
from repro.kernels.gemm.ops import dataflow_blocks, toeplitz_gemm
from repro.kernels.layouts import materialize, restore


@batchable
@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "dataflow", "p1", "p2", "interpret", "epilogue",
    "in_layout", "out_layout", "out_scale"))
def conv_im2col(x: jax.Array, w: jax.Array, stride: int = 1,
                padding: str = "SAME",
                dataflow: Dataflow = Dataflow.NS,
                p1: int = 128, p2: int = 128,
                interpret: Optional[bool] = None,
                epilogue: str = "none",
                bias: Optional[jax.Array] = None,
                in_layout=None, out_layout=None,
                scale: Optional[jax.Array] = None,
                out_scale: Optional[float] = None) -> jax.Array:
    """Convolution via the im2col algorithm. x: (H, W, Cin) or (B, H, W, Cin),
    w: (K1, K2, Cin, Cout) → (…, O1, O2, Cout). ``epilogue`` fuses the
    post-GEMM auxiliary unit (ReLU / bias) into the kernel's output flush.

    ``in_layout``/``out_layout`` (``core.layouts.LayoutSpec``) realize the
    plan's store formats: a "toeplitz" ``in_layout`` means ``x`` IS the
    layer's Toeplitz matrix — the window gather was paid once at the
    producer's store, so the layer is a plain dataflow-bound GEMM; a
    non-NHWC ``out_layout`` emits the consumer's store format directly.

    Int8 path: ``x``/``w`` already quantized (overlay does it), ``scale``
    is the per-output-channel dequant vector and ``out_scale`` (static)
    requantizes the fused epilogue's result to an int8 output."""
    interpret = default_interpret() if interpret is None else interpret
    if in_layout is not None and in_layout.kind == "toeplitz":
        out = toeplitz_gemm(x, w.reshape(-1, w.shape[-1]), in_layout,
                            dataflow, p1, p2, interpret=interpret,
                            epilogue=epilogue, bias=bias, scale=scale,
                            out_scale=out_scale)
        return materialize(out, out_layout)
    x = restore(x, in_layout)
    h, w_dim, c_in = x.shape
    k1, k2, _, c_out = w.shape
    if padding == "SAME":
        o1, o2 = -(-h // stride), -(-w_dim // stride)
        ph = max((o1 - 1) * stride + k1 - h, 0)
        pw = max((o2 - 1) * stride + k2 - w_dim, 0)
        xp = jnp.pad(x, ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2),
                         (0, 0)))
    else:
        o1 = (h - k1) // stride + 1
        o2 = (w_dim - k2) // stride + 1
        xp = x
    bm, bn, _ = dataflow_blocks(dataflow, p1, p2)
    bo1 = min(max(1, bm // o2), o1)
    o1p = ceil_to(o1, bo1)
    # Extra bottom/right rows so the last block's window slices stay in
    # bounds (they produce rows we slice off afterwards).
    need_r = (o1p - 1) * stride + k1
    need_c = (o2 - 1) * stride + k2
    xp = jnp.pad(xp, ((0, max(0, need_r - xp.shape[0])),
                      (0, max(0, need_c - xp.shape[1])), (0, 0)))
    bc = min(bn, ceil_to(c_out, 128))
    c_outp = ceil_to(c_out, bc)
    wm = w.reshape(k1 * k2 * c_in, c_out)
    wm = jnp.pad(wm, ((0, 0), (0, c_outp - c_out)))
    out = conv_im2col_call(xp, wm, k1=k1, k2=k2, stride=stride,
                           o1=o1p, o2=o2, bo1=bo1, bc=bc,
                           interpret=interpret, epilogue=epilogue,
                           bias=pad_bias(bias, c_out, c_outp),
                           scale=pad_bias(scale, c_out, c_outp),
                           out_scale=out_scale)
    return materialize(out[:o1, :, :c_out], out_layout)
