"""Oracle: spatial convolution via jax.lax plus an explicit Toeplitz
construction matching Eq. 2 (used to validate layouts, not just values)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import batchable


def conv_ref(x: jax.Array, w: jax.Array, stride: int = 1,
             padding: str = "SAME") -> jax.Array:
    """x: (H, W, Cin) or (B, H, W, Cin); w: (K1, K2, Cin, Cout)."""
    single = x.ndim == 3
    xb = x[None] if single else x
    out = jax.lax.conv_general_dilated(
        xb.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return (out[0] if single else out).astype(x.dtype)


def toeplitz_ref(x: jax.Array, k1: int, k2: int, stride: int = 1,
                 padding: str = "SAME") -> jax.Array:
    """The explicit im2col matrix (O1*O2, K1*K2*Cin) of §2.1.1."""
    h, w_, c = x.shape
    if padding == "SAME":
        o1 = -(-h // stride)
        o2 = -(-w_ // stride)
        ph = max((o1 - 1) * stride + k1 - h, 0)
        pw = max((o2 - 1) * stride + k2 - w_, 0)
        x = jnp.pad(x, ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2),
                        (0, 0)))
    else:
        o1 = (h - k1) // stride + 1
        o2 = (w_ - k2) // stride + 1
    cols = []
    for dk1 in range(k1):
        for dk2 in range(k2):
            sl = x[dk1:dk1 + (o1 - 1) * stride + 1:stride,
                   dk2:dk2 + (o2 - 1) * stride + 1:stride, :]
            cols.append(sl.reshape(o1 * o2, c))
    return jnp.concatenate(cols, axis=1)


def conv_from_toeplitz_ref(t: jax.Array, w: jax.Array, o1: int,
                           o2: int) -> jax.Array:
    """Eq. 2 GEMM on a pre-materialized Toeplitz operand (matched-layout
    load): t (O1·O2, K1K2·Cin) or (B, …), w (K1, K2, Cin, Cout)."""
    c_out = w.shape[-1]
    out = t.astype(jnp.float32) @ w.reshape(-1, c_out).astype(jnp.float32)
    return out.reshape(*t.shape[:-2], o1, o2, c_out).astype(t.dtype)


@batchable
def conv_via_toeplitz_ref(x: jax.Array, w: jax.Array, stride: int = 1,
                          padding: str = "SAME") -> jax.Array:
    k1, k2, c_in, c_out = w.shape
    t = toeplitz_ref(x, k1, k2, stride, padding)
    out = t.astype(jnp.float32) @ w.reshape(-1, c_out).astype(jnp.float32)
    h, w_, _ = x.shape
    if padding == "SAME":
        o1, o2 = -(-h // stride), -(-w_ // stride)
    else:
        o1 = (h - k1) // stride + 1
        o2 = (w_ - k2) // stride + 1
    return out.reshape(o1, o2, c_out).astype(x.dtype)
