"""im2col convolution as an *implicit-GEMM* Pallas kernel.

The paper's im2col (§2.1.1) stretches input windows into a Toeplitz matrix in
DRAM and runs one big GEMM (Eq. 2). A mechanical port would materialize the
Toeplitz matrix in HBM — pure bandwidth waste on TPU. The TPU-native
adaptation gathers the windows **in VMEM** inside the kernel and feeds the
MXU directly: the Toeplitz tile exists only on-chip, so HBM sees each input
element once while the GEMM still runs at full MXU occupancy.

Feature maps of the paper's networks (GoogleNet/Inception-v4) are ≤ a few MB
at bf16, so the whole input map is held as a single VMEM block; outputs and
weights are tiled on (output-rows × C_out) — the (P_SA1, P_SA2) binding of
the NS dataflow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import apply_epilogue


def _conv_kernel(x_ref, w_ref, *rest, k1: int, k2: int, stride: int,
                 bo1: int, o2: int, c_in: int, epilogue: str):
    """One grid step = (one block of output rows) × (one block of C_out)."""
    if len(rest) == 2:            # fused bias operand present
        bias_ref, o_ref = rest
    else:
        (o_ref,), bias_ref = rest, None
    i = pl.program_id(0)
    x = x_ref[...]                                   # (Hp, Wp, Cin) in VMEM
    row0 = i * bo1 * stride
    span_r = (bo1 - 1) * stride + 1
    span_c = (o2 - 1) * stride + 1
    patches = []
    for dk1 in range(k1):          # static unroll — k1,k2 are layer consts
        for dk2 in range(k2):
            sl = jax.lax.dynamic_slice(
                x, (row0 + dk1, dk2, 0), (span_r, span_c, c_in))
            patches.append(sl[::stride, ::stride, :])  # (bo1, o2, Cin)
    # The Toeplitz tile — VMEM-only (this is the whole point).
    toep = jnp.stack(patches, axis=2).reshape(bo1 * o2, k1 * k2 * c_in)
    acc = jnp.dot(toep, w_ref[...], preferred_element_type=jnp.float32)
    # Epilogue on the GEMM output block while it is still VMEM-resident —
    # the §3 in-pipeline auxiliary unit.
    acc = apply_epilogue(acc, epilogue,
                         bias_ref[0] if bias_ref is not None else None)
    o_ref[...] = acc.reshape(bo1, o2, -1).astype(o_ref.dtype)


def conv_im2col_call(x: jax.Array, w: jax.Array, *, k1: int, k2: int,
                     stride: int, o1: int, o2: int, bo1: int, bc: int,
                     interpret: bool = True, epilogue: str = "none",
                     bias: jax.Array = None) -> jax.Array:
    hp, wp, c_in = x.shape
    kkc, c_out = w.shape
    assert kkc == k1 * k2 * c_in, (kkc, k1, k2, c_in)
    assert c_out % bc == 0 and o1 % bo1 == 0
    grid = (o1 // bo1, c_out // bc)
    in_specs = [
        pl.BlockSpec((hp, wp, c_in), lambda i, j: (0, 0, 0)),
        pl.BlockSpec((kkc, bc), lambda i, j: (0, j)),
    ]
    operands = [x, w]
    if bias is not None:
        assert bias.shape == (1, c_out), (bias.shape, c_out)
        in_specs.append(pl.BlockSpec((1, bc), lambda i, j: (0, j)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_conv_kernel, k1=k1, k2=k2, stride=stride,
                          bo1=bo1, o2=o2, c_in=c_in, epilogue=epilogue),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bo1, o2, bc), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((o1, o2, c_out), x.dtype),
        interpret=interpret,
    )(*operands)
