"""im2col convolution as an *implicit-GEMM* Pallas kernel.

The paper's im2col (§2.1.1) stretches input windows into a Toeplitz matrix in
DRAM and runs one big GEMM (Eq. 2). A mechanical port would materialize the
Toeplitz matrix in HBM — pure bandwidth waste on TPU. The TPU-native
adaptation gathers the windows **in VMEM** inside the kernel and feeds the
MXU directly: the Toeplitz tile exists only on-chip, so HBM sees each input
element once while the GEMM still runs at full MXU occupancy.

Feature maps of the paper's networks (GoogleNet/Inception-v4) are ≤ a few MB
at bf16, so the whole input map is held as a single VMEM block; outputs and
weights are tiled on (output-rows × C_out) — the (P_SA1, P_SA2) binding of
the NS dataflow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import apply_epilogue


def _conv_kernel(x_ref, w_ref, *rest, k1: int, k2: int, stride: int,
                 bo1: int, o2: int, c_in: int, epilogue: str,
                 has_scale: bool = False, out_scale: float = None):
    """One grid step = (one block of output rows) × (one block of C_out).

    Operand order after (x, w): [scale?][bias?] o_ref. Int8 inputs
    accumulate exactly in int32; the fused ``scale`` row dequantizes the
    GEMM block before bias/relu and ``out_scale`` requantizes after.
    """
    rest = list(rest)
    scale_ref = rest.pop(0) if has_scale else None
    o_ref = rest[-1]
    bias_ref = rest[0] if len(rest) == 2 else None
    i = pl.program_id(0)
    x = x_ref[...]                                   # (Hp, Wp, Cin) in VMEM
    row0 = i * bo1 * stride
    span_r = (bo1 - 1) * stride + 1
    span_c = (o2 - 1) * stride + 1
    patches = []
    for dk1 in range(k1):          # static unroll — k1,k2 are layer consts
        for dk2 in range(k2):
            sl = jax.lax.dynamic_slice(
                x, (row0 + dk1, dk2, 0), (span_r, span_c, c_in))
            patches.append(sl[::stride, ::stride, :])  # (bo1, o2, Cin)
    # The Toeplitz tile — VMEM-only (this is the whole point).
    toep = jnp.stack(patches, axis=2).reshape(bo1 * o2, k1 * k2 * c_in)
    acc_dtype = jnp.int32 if x.dtype == jnp.int8 else jnp.float32
    acc = jnp.dot(toep, w_ref[...], preferred_element_type=acc_dtype)
    # Epilogue on the GEMM output block while it is still VMEM-resident —
    # the §3 in-pipeline auxiliary unit (dequant/bias/relu/requant).
    acc = apply_epilogue(acc, epilogue,
                         bias_ref[0] if bias_ref is not None else None,
                         scale=scale_ref[0] if scale_ref is not None else None,
                         out_scale=out_scale)
    o_ref[...] = acc.reshape(bo1, o2, -1).astype(o_ref.dtype)


def conv_im2col_call(x: jax.Array, w: jax.Array, *, k1: int, k2: int,
                     stride: int, o1: int, o2: int, bo1: int, bc: int,
                     interpret: bool = True, epilogue: str = "none",
                     bias: jax.Array = None, scale: jax.Array = None,
                     out_scale: float = None) -> jax.Array:
    hp, wp, c_in = x.shape
    kkc, c_out = w.shape
    assert kkc == k1 * k2 * c_in, (kkc, k1, k2, c_in)
    assert c_out % bc == 0 and o1 % bo1 == 0
    quantized = x.dtype == jnp.int8
    out_dtype = (jnp.int8 if out_scale is not None
                 else jnp.float32 if quantized else x.dtype)
    grid = (o1 // bo1, c_out // bc)
    in_specs = [
        pl.BlockSpec((hp, wp, c_in), lambda i, j: (0, 0, 0)),
        pl.BlockSpec((kkc, bc), lambda i, j: (0, j)),
    ]
    operands = [x, w]
    if scale is not None:
        assert scale.shape == (1, c_out), (scale.shape, c_out)
        in_specs.append(pl.BlockSpec((1, bc), lambda i, j: (0, j)))
        operands.append(scale)
    if bias is not None:
        assert bias.shape == (1, c_out), (bias.shape, c_out)
        in_specs.append(pl.BlockSpec((1, bc), lambda i, j: (0, j)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_conv_kernel, k1=k1, k2=k2, stride=stride,
                          bo1=bo1, o2=o2, c_in=c_in, epilogue=epilogue,
                          has_scale=scale is not None, out_scale=out_scale),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bo1, o2, bc), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((o1, o2, c_out), out_dtype),
        interpret=interpret,
    )(*operands)
