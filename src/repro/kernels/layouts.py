"""Runtime layout conversions — the store/load legs of Table 2 in software.

``materialize`` converts a producer's NHWC output into the DRAM store
format an edge carries (``core.layouts.LayoutSpec``); ``restore`` is the
exact inverse, used when a consumer at a split fan-out needs a different
representation than the one stored (the Table 2 "converting load").

Both ends are pure gathers with indices precomputed in numpy at trace
time, so XLA sees a single static gather per conversion and can fuse it
with the neighboring kernels — the software analogue of the paper's
pipelined Data Layout Transformation units. Overlapping positions in the
Toeplitz and Winograd-tile layouts hold bitwise-identical copies, so
``restore(materialize(x)) == x`` exactly (no tolerance needed).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layouts import LayoutSpec, invertible, is_nhwc
from repro.kernels.conv_im2col.ref import toeplitz_ref


def materialize(x: jax.Array, spec: Optional[LayoutSpec]) -> jax.Array:
    """NHWC ``(…, H, W, C)`` → the ``spec`` store format (batch preserved)."""
    if is_nhwc(spec):
        return x
    if x.ndim == 4:
        return jax.vmap(lambda xi: materialize(xi, spec))(x)
    if x.shape != (spec.h, spec.w, spec.c):
        raise ValueError(f"cannot materialize {x.shape} as {spec.key}")
    if spec.kind == "toeplitz":
        return toeplitz_ref(x, spec.k1, spec.k2, spec.stride, spec.padding)
    return _winograd_tiles(x, spec)


def restore(v: jax.Array, spec: Optional[LayoutSpec]) -> jax.Array:
    """Exact inverse of ``materialize`` — the converting-load leg."""
    if is_nhwc(spec):
        return v
    if v.ndim == spec.base_rank + 1:
        return jax.vmap(lambda vi: restore(vi, spec))(v)
    if not invertible(spec):
        raise ValueError(f"layout {spec.key} is not invertible; "
                         "lower_plan should not have stored it")
    if spec.kind == "toeplitz":
        row, tap = _toeplitz_restore_indices(spec)
        t3 = v.reshape(spec.o1 * spec.o2, spec.k1 * spec.k2, spec.c)
        return t3[jnp.asarray(row), jnp.asarray(tap), :]
    tile, a, b = _winograd_restore_indices(spec)
    return v[jnp.asarray(tile), jnp.asarray(a), jnp.asarray(b), :]


# ---------------------------------------------------------------------------
# Winograd scattered-tile layout: overlapping T×T input tiles, stride m.
# ---------------------------------------------------------------------------

def _winograd_tiles(x: jax.Array, spec: LayoutSpec) -> jax.Array:
    """(H, W, C) → (tiles_y·tiles_x, T, T, C), padded exactly as the
    single-round F(m,r) conv core pads (SAME halo + bottom/right fill so
    every tile slice is in range)."""
    t, m = spec.t, spec.m
    ty, tx = spec.tiles_y, spec.tiles_x
    pt, pl_ = spec.pad_top, spec.pad_left
    need_r, need_c = ty * m + spec.r - 1, tx * m + spec.r - 1
    xp = jnp.pad(x, ((pt, max(0, need_r - spec.h - pt)),
                     (pl_, max(0, need_c - spec.w - pl_)), (0, 0)))
    r_idx = np.arange(ty)[:, None] * m + np.arange(t)[None, :]   # (ty, t)
    c_idx = np.arange(tx)[:, None] * m + np.arange(t)[None, :]   # (tx, t)
    tiles = xp[jnp.asarray(r_idx[:, None, :, None]),
               jnp.asarray(c_idx[None, :, None, :]), :]
    return tiles.reshape(ty * tx, t, t, spec.c)


@functools.lru_cache(maxsize=None)
def _winograd_restore_indices(spec: LayoutSpec
                              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-pixel (tile, row-in-tile, col-in-tile) gather indices: pixel
    (y, x) lives at padded (y+pt, x+pl), inside tile (min(p//m, tiles-1))
    at local offset p - tile·m (< T because tiles overlap by r-1)."""
    m, ty, tx = spec.m, spec.tiles_y, spec.tiles_x
    ys = np.arange(spec.h) + spec.pad_top
    xs = np.arange(spec.w) + spec.pad_left
    iy = np.minimum(ys // m, ty - 1)
    ix = np.minimum(xs // m, tx - 1)
    a, b = ys - iy * m, xs - ix * m
    assert a.max() < spec.t and b.max() < spec.t
    tile = iy[:, None] * tx + ix[None, :]                 # (H, W)
    return tile, a[:, None] + np.zeros_like(tile), \
        b[None, :] + np.zeros_like(tile)


# ---------------------------------------------------------------------------
# Toeplitz layout: (O1·O2, K1·K2·C) — recoverable while stride ≤ kernel.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _toeplitz_restore_indices(spec: LayoutSpec
                              ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-pixel (gemm-row, kernel-tap) gather indices: padded coord p is
    sampled by output position min(p//s, O-1) at tap p - pos·s (< K by the
    ``invertible`` guard)."""
    s, o1, o2 = spec.stride, spec.o1, spec.o2
    ys = np.arange(spec.h) + spec.pad_top
    xs = np.arange(spec.w) + spec.pad_left
    oy = np.minimum(ys // s, o1 - 1)
    ox = np.minimum(xs // s, o2 - 1)
    dk1, dk2 = ys - oy * s, xs - ox * s
    assert dk1.max() < spec.k1 and dk2.max() < spec.k2
    row = oy[:, None] * o2 + ox[None, :]                  # (H, W)
    tap = dk1[:, None] * spec.k2 + dk2[None, :]
    return row, tap
