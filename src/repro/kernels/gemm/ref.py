"""Pure-jnp oracle for the GEMM kernel."""
import jax
import jax.numpy as jnp


def gemm_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)
                   ).astype(out_dtype)


def batched_gemm_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.einsum("gmk,gkn->gmn", a.astype(jnp.float32),
                      b.astype(jnp.float32)).astype(out_dtype)
