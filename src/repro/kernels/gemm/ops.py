"""Jit'd public wrapper: dataflow → block-dim binding (Eq. 9), padding,
and the interpret/compile switch."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.cost_model import Dataflow
from repro.kernels.common import ceil_to, default_interpret, pad_bias, pad_to
from repro.kernels.gemm.gemm import batched_gemm_pallas, gemm_pallas

_STREAM_TILE = 128   # native MXU granularity on the streamed dim


def dataflow_blocks(dataflow: Dataflow, p1: int, p2: int
                    ) -> Tuple[int, int, int]:
    """(bm, bn, bk) binding for a given dataflow — §3.2 mapping.

    NS: (a→p1, c→p2) ⇒ blocks on (M, N), K streams at 128.
    WS: (b→p1, c→p2) ⇒ blocks on (K, N), M streams at 128.
    IS: (b→p1, a→p2) ⇒ blocks on (K, M), N streams at 128.
    """
    if dataflow is Dataflow.NS:
        return p1, p2, _STREAM_TILE
    if dataflow is Dataflow.WS:
        return _STREAM_TILE, p2, p1
    return p2, _STREAM_TILE, p1


@functools.partial(jax.jit, static_argnames=(
    "dataflow", "p1", "p2", "interpret", "out_dtype", "epilogue",
    "out_scale"))
def gemm(a: jax.Array, b: jax.Array,
         dataflow: Dataflow = Dataflow.NS,
         p1: int = 128, p2: int = 128,
         interpret: Optional[bool] = None,
         out_dtype=None, epilogue: str = "none",
         bias: Optional[jax.Array] = None,
         scale: Optional[jax.Array] = None,
         out_scale: Optional[float] = None) -> jax.Array:
    """C = epilogue(A @ B [+ bias]) on the dataflow-switchable Computing
    Unit; the epilogue is fused into the kernel's output flush.

    Int8 operands accumulate in int32; ``scale`` ((N,) per-output-channel
    dequant factors) and the static ``out_scale`` (requantize-to-int8)
    ride the same fused flush as bias/relu."""
    interpret = default_interpret() if interpret is None else interpret
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = dataflow_blocks(dataflow, p1, p2)
    # int8 blocks need the (32, 128) minimum tile on real hardware.
    row_tile = 32 if a.dtype == jnp.int8 else 8
    bm, bn, bk = min(bm, ceil_to(m, row_tile)), min(bn, ceil_to(n, 128)), \
        min(bk, ceil_to(k, 128))
    ap = pad_to(a, (bm, bk))
    bp = pad_to(b, (bk, bn))
    out = gemm_pallas(ap, bp, bm=bm, bn=bn, bk=bk, interpret=interpret,
                      out_dtype=out_dtype, epilogue=epilogue,
                      bias=pad_bias(bias, n, bp.shape[1]),
                      scale=pad_bias(scale, n, bp.shape[1]),
                      out_scale=out_scale)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=(
    "spec", "dataflow", "p1", "p2", "interpret", "epilogue", "out_scale"))
def toeplitz_gemm(t: jax.Array, w2d: jax.Array, spec,
                  dataflow: Dataflow = Dataflow.NS,
                  p1: int = 128, p2: int = 128,
                  interpret: Optional[bool] = None,
                  epilogue: str = "none",
                  bias: Optional[jax.Array] = None,
                  scale: Optional[jax.Array] = None,
                  out_scale: Optional[float] = None) -> jax.Array:
    """Matched-layout conv leg: a consumer whose edge already carries its
    Toeplitz matrix (``core.layouts.LayoutSpec`` kind "toeplitz") feeds the
    dataflow-bound GEMM unit directly — Table 2's streaming Load(n, n), no
    window re-gather. ``t``: (O1·O2, K1K2·Cin) or batched (B, …);
    ``w2d``: (K1K2·Cin, Cout) → (…, O1, O2, Cout)."""
    if t.ndim == 3:
        return jax.vmap(lambda ti: toeplitz_gemm(
            ti, w2d, spec, dataflow, p1, p2, interpret=interpret,
            epilogue=epilogue, bias=bias, scale=scale,
            out_scale=out_scale))(t)
    out = gemm(t, w2d, dataflow, p1, p2, interpret=interpret,
               epilogue=epilogue, bias=bias, scale=scale,
               out_scale=out_scale)
    return out.reshape(spec.o1, spec.o2, w2d.shape[1])


@functools.partial(jax.jit, static_argnames=(
    "dataflow", "p1", "p2", "interpret", "out_dtype", "epilogue"))
def batched_gemm(a: jax.Array, b: jax.Array,
                 dataflow: Dataflow = Dataflow.NS,
                 p1: int = 128, p2: int = 128,
                 interpret: Optional[bool] = None,
                 out_dtype=None, epilogue: str = "none",
                 bias: Optional[jax.Array] = None) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    g, m, k = a.shape
    _, _, n = b.shape
    bm, bn, bk = dataflow_blocks(dataflow, p1, p2)
    bm, bn, bk = min(bm, ceil_to(m, 8)), min(bn, ceil_to(n, 128)), \
        min(bk, ceil_to(k, 128))
    ap = pad_to(a, (0, bm, bk))
    bp = pad_to(b, (0, bk, bn))
    out = batched_gemm_pallas(ap, bp, bm=bm, bn=bn, bk=bk,
                              interpret=interpret, out_dtype=out_dtype,
                              epilogue=epilogue,
                              bias=pad_bias(bias, n, bp.shape[2]))
    return out[:, :m, :n]
