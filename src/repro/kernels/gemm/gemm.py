"""Dataflow-switchable tiled GEMM — the paper's Computing Unit on the MXU.

§3.2 of the paper binds the two physical systolic-array dims (P_SA1, P_SA2)
to different GEMM dims per dataflow; the third dim streams:

    NS: (a → P_SA1, c → P_SA2), b streams   — output-stationary
    WS: (b → P_SA1, c → P_SA2), a streams   — weight block resident
    IS: (b → P_SA1, a → P_SA2), c streams   — input block resident

On TPU the virtual array is a Pallas block: the dataflow chooses which two
GEMM dims carry the (p1, p2) block shape — and therefore which dims suffer
ceil-division padding waste (Eq. 9) — while the streamed dim is tiled at the
native 128 granularity. One kernel body serves all three; the binding
happens in ops.py.

Grid is (i, j, k) with k innermost (contiguous output-block revisits, as
Pallas TPU requires); a VMEM f32 scratch accumulates partial products, which
is exactly the stall-free accumulate-in-place of the paper's PE design.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import apply_epilogue

try:  # TPU memory spaces; interpret mode works without a TPU present.
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _gemm_kernel(a_ref, b_ref, *rest, nk: int, epilogue: str,
                 has_scale: bool = False, out_scale: float = None):
    """One kernel body for the bf16 and int8 paths.

    Operand order after (a, b): [scale?][bias?] o_ref, acc_ref. The int8
    path accumulates exactly in an int32 scratch (``preferred_element_type``
    matches the scratch dtype), then the flush dequantizes with the fused
    per-channel ``scale`` row, applies bias/relu, and optionally
    requantizes at the static ``out_scale`` — one VMEM round trip total.
    """
    rest = list(rest)
    scale_ref = rest.pop(0) if has_scale else None
    o_ref, acc_ref = rest[-2], rest[-1]
    bias_ref = rest[0] if len(rest) == 3 else None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == nk - 1)
    def _flush():
        acc = apply_epilogue(
            acc_ref[...], epilogue,
            bias_ref[0] if bias_ref is not None else None,
            scale=scale_ref[0] if scale_ref is not None else None,
            out_scale=out_scale)
        o_ref[...] = acc.astype(o_ref.dtype)


def gemm_pallas(a: jax.Array, b: jax.Array, *, bm: int, bn: int, bk: int,
                interpret: bool = True, out_dtype=None,
                epilogue: str = "none",
                bias: jax.Array = None,
                scale: jax.Array = None,
                out_scale: float = None) -> jax.Array:
    """C = epilogue(A @ B [+ bias]) with explicit (bm, bn, bk) VMEM tiling.

    The epilogue is applied in-kernel at the accumulator flush — the output
    block streams through the auxiliary unit (§3) before ever leaving VMEM.
    Caller must pre-pad so M % bm == N % bn == K % bk == 0 (ops.py does);
    ``bias`` (if given) must be pre-padded to (1, N).

    Int8 path: when A/B are int8 the scratch accumulator is int32 (exact),
    ``scale`` (pre-padded (1, N), per-output-channel in_scale·w_scale)
    dequantizes at the flush, and a non-None ``out_scale`` requantizes the
    epilogue result to an int8 output.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    nk = k // bk
    quantized = a.dtype == jnp.int8
    acc_dtype = jnp.int32 if quantized else jnp.float32
    if out_dtype is None:
        out_dtype = (jnp.int8 if out_scale is not None
                     else jnp.float32 if quantized else a.dtype)

    grid = (m // bm, n // bn, nk)
    scratch = (pltpu.VMEM((bm, bn), acc_dtype) if _VMEM is not None
               else pl.ANY)  # pragma: no cover
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [a, b]
    if scale is not None:
        assert scale.shape == (1, n), (scale.shape, n)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(scale)
    if bias is not None:
        assert bias.shape == (1, n), (bias.shape, n)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, nk=nk, epilogue=epilogue,
                          has_scale=scale is not None, out_scale=out_scale),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[scratch],
        interpret=interpret,
    )(*operands)


def batched_gemm_pallas(a: jax.Array, b: jax.Array, *, bm: int, bn: int,
                        bk: int, interpret: bool = True, out_dtype=None,
                        epilogue: str = "none",
                        bias: jax.Array = None) -> jax.Array:
    """C[g] = epilogue(A[g] @ B[g] [+ bias]) — used for the (m+r-1)^2
    independent Winograd GEMMs (Eq. 6): the transform-space Hadamard products
    batched over tile position. ``bias`` (if given) is (1, N), shared across
    the batch dim."""
    g, m, k = a.shape
    g2, k2, n = b.shape
    assert g == g2 and k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    out_dtype = out_dtype or a.dtype

    def kernel(a_ref, b_ref, *rest):
        if len(rest) == 3:
            bias_ref, o_ref, acc_ref = rest
        else:
            (o_ref, acc_ref), bias_ref = rest, None
        kk = pl.program_id(3)

        @pl.when(kk == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                                preferred_element_type=jnp.float32)

        @pl.when(kk == nk - 1)
        def _flush():
            acc = apply_epilogue(acc_ref[...], epilogue,
                                 bias_ref[0] if bias_ref is not None else None)
            o_ref[0] = acc.astype(o_ref.dtype)

    scratch = (pltpu.VMEM((bm, bn), jnp.float32) if _VMEM is not None
               else pl.ANY)  # pragma: no cover
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
        pl.BlockSpec((1, bk, bn), lambda gg, i, j, kk: (gg, kk, j)),
    ]
    operands = [a, b]
    if bias is not None:
        assert bias.shape == (1, n), (bias.shape, n)
        in_specs.append(pl.BlockSpec((1, bn), lambda gg, i, j, kk: (0, j)))
        operands.append(bias)
    return pl.pallas_call(
        kernel,
        grid=(g, m // bm, n // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, m, n), out_dtype),
        scratch_shapes=[scratch],
        interpret=interpret,
    )(*operands)
