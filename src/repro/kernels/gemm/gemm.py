"""Dataflow-switchable tiled GEMM — the paper's Computing Unit on the MXU.

§3.2 of the paper binds the two physical systolic-array dims (P_SA1, P_SA2)
to different GEMM dims per dataflow; the third dim streams:

    NS: (a → P_SA1, c → P_SA2), b streams   — output-stationary
    WS: (b → P_SA1, c → P_SA2), a streams   — weight block resident
    IS: (b → P_SA1, a → P_SA2), c streams   — input block resident

On TPU the virtual array is a Pallas block: the dataflow chooses which two
GEMM dims carry the (p1, p2) block shape — and therefore which dims suffer
ceil-division padding waste (Eq. 9) — while the streamed dim is tiled at the
native 128 granularity. One kernel body serves all three; the binding
happens in ops.py.

Grid is (i, j, k) with k innermost (contiguous output-block revisits, as
Pallas TPU requires); a VMEM f32 scratch accumulates partial products, which
is exactly the stall-free accumulate-in-place of the paper's PE design.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; interpret mode works without a TPU present.
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_pallas(a: jax.Array, b: jax.Array, *, bm: int, bn: int, bk: int,
                interpret: bool = True,
                out_dtype=None) -> jax.Array:
    """C = A @ B with explicit (bm, bn, bk) VMEM tiling.

    Caller must pre-pad so M % bm == N % bn == K % bk == 0 (ops.py does).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    nk = k // bk
    out_dtype = out_dtype or a.dtype

    grid = (m // bm, n // bn, nk)
    scratch = (pltpu.VMEM((bm, bn), jnp.float32) if _VMEM is not None
               else pl.ANY)  # pragma: no cover
    return pl.pallas_call(
        functools.partial(_gemm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[scratch],
        interpret=interpret,
    )(a, b)


def batched_gemm_pallas(a: jax.Array, b: jax.Array, *, bm: int, bn: int,
                        bk: int, interpret: bool = True,
                        out_dtype=None) -> jax.Array:
    """C[g] = A[g] @ B[g] — used for the (m+r-1)^2 independent Winograd GEMMs
    (Eq. 6): the transform-space Hadamard products batched over tile position."""
    g, m, k = a.shape
    g2, k2, n = b.shape
    assert g == g2 and k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    out_dtype = out_dtype or a.dtype

    def kernel(a_ref, b_ref, o_ref, acc_ref):
        kk = pl.program_id(3)

        @pl.when(kk == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                                preferred_element_type=jnp.float32)

        @pl.when(kk == nk - 1)
        def _flush():
            o_ref[0] = acc_ref[...].astype(o_ref.dtype)

    scratch = (pltpu.VMEM((bm, bn), jnp.float32) if _VMEM is not None
               else pl.ANY)  # pragma: no cover
    return pl.pallas_call(
        kernel,
        grid=(g, m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda gg, i, j, kk: (gg, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, m, n), out_dtype),
        scratch_shapes=[scratch],
        interpret=interpret,
    )(a, b)
