"""Public Winograd conv: transforms (Pallas) + batched GEMM (Pallas),
with the multi-round decomposition for kernels larger than r×r.

The transform-space Hadamard products are (tiles, Cin) × (Cin, Cout) GEMMs
batched over the (m+r-1)² tile positions; the plan's dataflow/(p1, p2)
binding is forwarded to that batched GEMM's block dims (Eq. 9).
Accepts (H, W, Cin) or batched (B, H, W, Cin) inputs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.cost_model import Dataflow
from repro.kernels.common import (apply_epilogue, batchable, ceil_to,
                                  default_interpret)
from repro.kernels.gemm.ops import batched_gemm
from repro.kernels.layouts import materialize, restore
from repro.kernels.winograd.winograd import (input_transform,
                                             input_transform_tiles, matrices,
                                             output_transform,
                                             transform_kernel_weights)


def _conv_f_mr(x: jax.Array, w: jax.Array, m: int, o1: int, o2: int,
               pt: int, pl_: int, dataflow: Dataflow, p1: int, p2: int,
               interpret: bool, epilogue: str = "none",
               bias: Optional[jax.Array] = None) -> jax.Array:
    """Single-round F(m,r) same-stride-1 conv core; x unpadded (H, W, Cin).
    The epilogue fuses into the output transform — the last kernel of the
    Winograd pipeline."""
    r = w.shape[0]
    t = m + r - 1
    h, w_dim, c_in = x.shape
    c_out = w.shape[-1]
    ty, tx = -(-o1 // m), -(-o2 // m)
    need_r, need_c = ty * m + r - 1, tx * m + r - 1
    xp = jnp.pad(x, ((pt, max(0, need_r - h - pt)),
                     (pl_, max(0, need_c - w_dim - pl_)), (0, 0)))
    v = input_transform(xp, m=m, r=r, tiles_y=ty, tiles_x=tx,
                        interpret=interpret)          # (T², n_tiles, Cin)
    u = transform_kernel_weights(w, m, r).astype(x.dtype)  # (T², Cin, Cout)
    mm = batched_gemm(v, u, dataflow=dataflow, p1=p1, p2=p2,
                      interpret=interpret,
                      out_dtype=x.dtype)              # (T², n_tiles, Cout)
    y = output_transform(mm, m=m, r=r, tiles_y=ty, tiles_x=tx,
                         interpret=interpret, epilogue=epilogue,
                         bias=(bias.reshape(1, c_out)
                               if bias is not None else None))
    return y[:o1, :o2, :c_out]


def _conv_from_tiles(tiles: jax.Array, w: jax.Array, m: int, spec,
                     dataflow: Dataflow, p1: int, p2: int,
                     interpret: bool, epilogue: str,
                     bias: Optional[jax.Array]) -> jax.Array:
    """Matched scattered-layout consumer (§3.3): the producer stored this
    layer's (T, T) input tiles, so the spatial re-gather is skipped and the
    pipeline is tile transform → batched GEMM → output transform."""
    r = w.shape[0]
    c_out = w.shape[-1]
    ty, tx = spec.tiles_y, spec.tiles_x
    v = input_transform_tiles(tiles, m=m, r=r, tiles_y=ty, tiles_x=tx,
                              interpret=interpret)
    u = transform_kernel_weights(w, m, r).astype(tiles.dtype)
    mm = batched_gemm(v, u, dataflow=dataflow, p1=p1, p2=p2,
                      interpret=interpret, out_dtype=tiles.dtype)
    y = output_transform(mm, m=m, r=r, tiles_y=ty, tiles_x=tx,
                         interpret=interpret, epilogue=epilogue,
                         bias=(bias.reshape(1, c_out)
                               if bias is not None else None))
    return y[:spec.o1, :spec.o2, :c_out]


@batchable
@functools.partial(jax.jit, static_argnames=(
    "m", "padding", "dataflow", "p1", "p2", "interpret", "epilogue",
    "in_layout", "out_layout"))
def conv_winograd(x: jax.Array, w: jax.Array, m: int = 2,
                  padding: str = "SAME",
                  dataflow: Dataflow = Dataflow.NS,
                  p1: int = 128, p2: int = 128,
                  interpret: Optional[bool] = None,
                  epilogue: str = "none",
                  bias: Optional[jax.Array] = None,
                  in_layout=None, out_layout=None) -> jax.Array:
    """Winograd convolution, stride 1, square K×K kernels.

    K > r runs in ceil(K/r)² rounds of shifted r×r sub-kernels with output
    accumulation (§6.1.2's K1K2/r² rounds). Single-round kernels fuse the
    epilogue into the output transform; the multi-round path must apply it
    after the cross-round accumulation (ReLU does not distribute over +).

    A matching "winograd" ``in_layout`` (same m, single-round K == r) means
    ``x`` is already the scattered tile layout — the layer consumes it
    without the spatial re-gather; any other layout is restored on entry.
    A non-NHWC ``out_layout`` emits the consumer's store format.
    """
    interpret = default_interpret() if interpret is None else interpret
    r = 3
    k1, k2, c_in, c_out = w.shape
    assert k1 == k2, "winograd path requires square kernels"
    if in_layout is not None and in_layout.kind == "winograd" \
            and in_layout.m == m and k1 == in_layout.r:
        y = _conv_from_tiles(x, w, m, in_layout, dataflow, p1, p2,
                             interpret, epilogue, bias)
        return materialize(y, out_layout)
    x = restore(x, in_layout)
    h, w_dim, _ = x.shape
    if padding == "SAME":
        o1, o2 = h, w_dim
        pt_full = (k1 - 1) // 2
        pl_full = (k2 - 1) // 2
    else:
        o1, o2 = h - k1 + 1, w_dim - k2 + 1
        pt_full = pl_full = 0

    if k1 == r:
        return materialize(
            _conv_f_mr(x, w, m, o1, o2, pt_full, pl_full,
                       dataflow, p1, p2, interpret,
                       epilogue=epilogue, bias=bias), out_layout)

    # Multi-round: pad kernel to multiple of r and accumulate shifted rounds.
    rounds = -(-k1 // r)
    kp = rounds * r
    wp = jnp.pad(w, ((0, kp - k1), (0, kp - k2), (0, 0), (0, 0)))
    # out[y, x] = Σ_{ry,rx} Σ_{i,j<r} X[y+ry·r+i-pt, x+rx·r+j-pl]·W[ry·r+i, ...]
    # = Σ_rounds  F(m,r)-conv of X shifted by (ry·r, rx·r) with sub-kernel.
    xbig = jnp.pad(x, ((pt_full, kp), (pl_full, kp), (0, 0)))
    acc = jnp.zeros((o1, o2, c_out), x.dtype)
    for ry in range(rounds):
        for rx in range(rounds):
            sub = wp[ry * r:(ry + 1) * r, rx * r:(rx + 1) * r]
            xs = jax.lax.dynamic_slice(
                xbig, (ry * r, rx * r, 0),
                (o1 + r - 1, o2 + r - 1, c_in))
            # VALID conv of xs with sub gives exactly (o1, o2).
            acc = acc + _conv_f_mr(xs, sub, m, o1, o2, 0, 0,
                                   dataflow, p1, p2, interpret)
    return materialize(apply_epilogue(acc, epilogue, bias), out_layout)
