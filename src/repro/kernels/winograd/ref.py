"""Pure-jnp oracle for Winograd F(m,r) — a direct transcription of Eq. 5/6."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import batchable
from repro.kernels.winograd.winograd import matrices


def winograd_from_tiles_ref(tiles: jax.Array, w: jax.Array, m: int,
                            tiles_y: int, tiles_x: int, o1: int,
                            o2: int) -> jax.Array:
    """Eq. 5/6 on pre-gathered scattered-layout tiles (matched load, §3.3):
    tiles (tiles_y·tiles_x, T, T, Cin) spatial values, w (r, r, Cin, Cout)
    → (o1, o2, Cout). The transforms run unchanged — only the spatial
    re-gather of the tile layout is skipped."""
    r = w.shape[0]
    bt, g_mat, at = (jnp.asarray(a) for a in matrices(m, r))
    c_out = w.shape[-1]
    u = jnp.einsum("ti,ijco,uj->tuco", g_mat, w.astype(jnp.float32), g_mat)
    d = tiles.astype(jnp.float32)                     # (n, t, t, c)
    v = jnp.einsum("ti,nijc,uj->tunc", bt, d, bt)     # (t, t, n, c)
    mm = jnp.einsum("tunc,tuco->tuno", v, u)          # (t, t, n, co)
    y = jnp.einsum("at,tuno,bu->nabo", at, mm, at)    # (n, m, m, co)
    y = y.reshape(tiles_y, tiles_x, m, m, c_out).transpose(0, 2, 1, 3, 4)
    y = y.reshape(tiles_y * m, tiles_x * m, c_out)[:o1, :o2, :]
    return y.astype(tiles.dtype)


@batchable
def winograd_ref(x: jax.Array, w: jax.Array, m: int = 2,
                 padding: str = "SAME") -> jax.Array:
    """x: (H, W, Cin) or (B, H, W, Cin); w: (r, r, Cin, Cout), stride 1.

    Y = Aᵀ [ (G g Gᵀ) ⊙ (Bᵀ d B) ] A, reduced over C_in in transform space
    (the amortization noted under Eq. 5), tiles concatenated back.
    """
    r = w.shape[0]
    assert w.shape[0] == w.shape[1], "winograd oracle needs square kernels"
    bt, g_mat, at = (jnp.asarray(a) for a in matrices(m, r))
    t = m + r - 1
    h, w_dim, c_in = x.shape
    c_out = w.shape[-1]
    if padding == "SAME":
        o1, o2 = h, w_dim
        pt = (r - 1) // 2
        pl_ = (r - 1) // 2
    else:
        o1, o2 = h - r + 1, w_dim - r + 1
        pt = pl_ = 0
    ty, tx = -(-o1 // m), -(-o2 // m)
    # pad so every tile slice is in range
    need_r = ty * m + r - 1
    need_c = tx * m + r - 1
    xp = jnp.pad(x.astype(jnp.float32),
                 ((pt, max(0, need_r - h - pt)),
                  (pl_, max(0, need_c - w_dim - pl_)), (0, 0)))
    u = jnp.einsum("ti,ijco,uj->tuco", g_mat, w.astype(jnp.float32), g_mat)
    ys = []
    for iy in range(ty):
        row = []
        for ix in range(tx):
            d = xp[iy * m:iy * m + t, ix * m:ix * m + t, :]
            v = jnp.einsum("ti,ijc,uj->tuc", bt, d, bt)
            m_ = jnp.einsum("tuc,tuco->tuo", v, u)
            y = jnp.einsum("mt,tuo,nu->mno", at, m_, at)
            row.append(y)
        ys.append(jnp.concatenate(row, axis=1))
    out = jnp.concatenate(ys, axis=0)[:o1, :o2, :]
    return out.astype(x.dtype)
