"""Pure-jnp oracle for Winograd F(m,r) — a direct transcription of Eq. 5/6."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import batchable
from repro.kernels.winograd.winograd import matrices


@batchable
def winograd_ref(x: jax.Array, w: jax.Array, m: int = 2,
                 padding: str = "SAME") -> jax.Array:
    """x: (H, W, Cin) or (B, H, W, Cin); w: (r, r, Cin, Cout), stride 1.

    Y = Aᵀ [ (G g Gᵀ) ⊙ (Bᵀ d B) ] A, reduced over C_in in transform space
    (the amortization noted under Eq. 5), tiles concatenated back.
    """
    r = w.shape[0]
    assert w.shape[0] == w.shape[1], "winograd oracle needs square kernels"
    bt, g_mat, at = (jnp.asarray(a) for a in matrices(m, r))
    t = m + r - 1
    h, w_dim, c_in = x.shape
    c_out = w.shape[-1]
    if padding == "SAME":
        o1, o2 = h, w_dim
        pt = (r - 1) // 2
        pl_ = (r - 1) // 2
    else:
        o1, o2 = h - r + 1, w_dim - r + 1
        pt = pl_ = 0
    ty, tx = -(-o1 // m), -(-o2 // m)
    # pad so every tile slice is in range
    need_r = ty * m + r - 1
    need_c = tx * m + r - 1
    xp = jnp.pad(x.astype(jnp.float32),
                 ((pt, max(0, need_r - h - pt)),
                  (pl_, max(0, need_c - w_dim - pl_)), (0, 0)))
    u = jnp.einsum("ti,ijco,uj->tuco", g_mat, w.astype(jnp.float32), g_mat)
    ys = []
    for iy in range(ty):
        row = []
        for ix in range(tx):
            d = xp[iy * m:iy * m + t, ix * m:ix * m + t, :]
            v = jnp.einsum("ti,ijc,uj->tuc", bt, d, bt)
            m_ = jnp.einsum("tuc,tuco->tuo", v, u)
            y = jnp.einsum("mt,tuo,nu->mno", at, m_, at)
            row.append(y)
        ys.append(jnp.concatenate(row, axis=1))
    out = jnp.concatenate(ys, axis=0)[:o1, :o2, :]
    return out.astype(x.dtype)
