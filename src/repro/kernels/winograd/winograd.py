"""Winograd F(m,r) convolution kernels (§2.1.3, Eq. 5/6).

Pipeline (the paper's Linear Transform Modules → Pallas kernels):
  1. input transform   V[ξν, tile, c]  = (Bᵀ d B)           — Pallas kernel
  2. kernel transform  U[ξν, c, k]     = (G g Gᵀ)           — precomputed
     (amortized across inferences, exactly as the FPGA design pre-loads it)
  3. (m+r-1)² independent GEMMs M = V·U (Eq. 6)             — batched Pallas GEMM
  4. output transform  Y = Aᵀ M A, tiles scattered back      — Pallas kernel

Layouts follow §3.3: V and M live in the "scattered" Winograd layout
(T², n_tiles, C) — elements at the same intra-tile position adjacent — so
the GEMM batch dim is the intra-tile coordinate (ξ, ν).

Kernels larger than r×r run in ceil(K1/r)·ceil(K2/r) rounds of shifted
r×r sub-kernels, accumulating outputs — §6.1.2's "K1K2/3² rounds of
Winograd ... resulting in severe transformation overheads" is exactly this
path, and the cost model prices it the same way.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common import apply_epilogue

# ---------------------------------------------------------------------------
# Transform matrices (Lavin & Gray). F(2,3) uses only ±1, ±1/2 — the paper
# notes these reduce to shift-adds on FPGA; on TPU they are VPU constants.
# ---------------------------------------------------------------------------

_BT = {
    (2, 3): np.array([[1, 0, -1, 0],
                      [0, 1, 1, 0],
                      [0, -1, 1, 0],
                      [0, 1, 0, -1]], np.float32),
    (4, 3): np.array([[4, 0, -5, 0, 1, 0],
                      [0, -4, -4, 1, 1, 0],
                      [0, 4, -4, -1, 1, 0],
                      [0, -2, -1, 2, 1, 0],
                      [0, 2, -1, -2, 1, 0],
                      [0, 4, 0, -5, 0, 1]], np.float32),
}
_G = {
    (2, 3): np.array([[1, 0, 0],
                      [0.5, 0.5, 0.5],
                      [0.5, -0.5, 0.5],
                      [0, 0, 1]], np.float32),
    (4, 3): np.array([[1 / 4, 0, 0],
                      [-1 / 6, -1 / 6, -1 / 6],
                      [-1 / 6, 1 / 6, -1 / 6],
                      [1 / 24, 1 / 12, 1 / 6],
                      [1 / 24, -1 / 12, 1 / 6],
                      [0, 0, 1]], np.float32),
}
_AT = {
    (2, 3): np.array([[1, 1, 1, 0],
                      [0, 1, -1, -1]], np.float32),
    (4, 3): np.array([[1, 1, 1, 1, 1, 0],
                      [0, 1, -1, 2, -2, 0],
                      [0, 1, 1, 4, 4, 0],
                      [0, 1, -1, 8, -8, 1]], np.float32),
}


def matrices(m: int, r: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    if (m, r) not in _BT:
        raise ValueError(f"F({m},{r}) not supported; have {_BT.keys()}")
    return _BT[(m, r)], _G[(m, r)], _AT[(m, r)]


def transform_kernel_weights(w: jax.Array, m: int, r: int) -> jax.Array:
    """U[ξν, Cin, Cout] = G g Gᵀ — the offline kernel transform."""
    _, g_mat, _ = matrices(m, r)
    g_ = jnp.asarray(g_mat)
    # w: (r, r, Cin, Cout) → (T, T, Cin, Cout) → (T², Cin, Cout)
    u = jnp.einsum("ti,ijco,uj->tuco", g_, w.astype(jnp.float32), g_)
    t = m + r - 1
    return u.reshape(t * t, *w.shape[2:])


# ---------------------------------------------------------------------------
# 1. Input transform: d tiles → V (scattered layout).
# ---------------------------------------------------------------------------

def input_transform(x: jax.Array, *, m: int, r: int, tiles_y: int,
                    tiles_x: int, interpret: bool = True) -> jax.Array:
    """x: (Hp, Wp, C) padded so Hp ≥ tiles_y·m + r - 1 (same for W).
    Returns V: (T², tiles_y·tiles_x, C)."""
    t = m + r - 1
    hp, wp, c = x.shape
    bt_host = jnp.asarray(matrices(m, r)[0])

    def kernel(x_ref, bt_ref, v_ref):
        i = pl.program_id(0)          # tile row
        xx = x_ref[...]               # full map in VMEM
        bt = bt_ref[...]
        row0 = i * m
        tiles = []
        for tx in range(tiles_x):     # static unroll over tile columns
            d = jax.lax.dynamic_slice(xx, (row0, tx * m, 0), (t, t, c))
            tiles.append(d)
        d_all = jnp.stack(tiles, axis=0).astype(jnp.float32)  # (tx, t, t, c)
        v = jnp.einsum("ti,xijc,uj->tuxc", bt, d_all, bt)
        v_ref[...] = v.reshape(t * t, tiles_x, c).astype(v_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(tiles_y,),
        in_specs=[pl.BlockSpec((hp, wp, c), lambda i: (0, 0, 0)),
                  pl.BlockSpec((t, t), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((t * t, tiles_x, c), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t * t, tiles_y * tiles_x, c),
                                       x.dtype),
        interpret=interpret,
    )(x, bt_host)


def input_transform_tiles(tiles: jax.Array, *, m: int, r: int, tiles_y: int,
                          tiles_x: int, interpret: bool = True) -> jax.Array:
    """Matched-layout input transform: ``tiles`` (tiles_y·tiles_x, T, T, C)
    already sit in the scattered Winograd layout (the producer stored them
    — Table 2 row 4's streaming load), so no spatial re-gather happens
    here; each tile goes straight through Bᵀ d B.
    Returns V: (T², tiles_y·tiles_x, C)."""
    t = m + r - 1
    n, _, _, c = tiles.shape
    assert n == tiles_y * tiles_x, (n, tiles_y, tiles_x)
    bt_host = jnp.asarray(matrices(m, r)[0])

    def kernel(t_ref, bt_ref, v_ref):
        d = t_ref[...].astype(jnp.float32)        # (tiles_x, t, t, c)
        bt = bt_ref[...]
        v = jnp.einsum("ti,xijc,uj->tuxc", bt, d, bt)
        v_ref[...] = v.reshape(t * t, tiles_x, c).astype(v_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(tiles_y,),
        in_specs=[pl.BlockSpec((tiles_x, t, t, c), lambda i: (i, 0, 0, 0)),
                  pl.BlockSpec((t, t), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((t * t, tiles_x, c), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t * t, tiles_y * tiles_x, c),
                                       tiles.dtype),
        interpret=interpret,
    )(tiles, bt_host)


# ---------------------------------------------------------------------------
# 4. Output transform: M (scattered) → spatial Y.
# ---------------------------------------------------------------------------

def output_transform(m_arr: jax.Array, *, m: int, r: int, tiles_y: int,
                     tiles_x: int, interpret: bool = True,
                     epilogue: str = "none",
                     bias: jax.Array = None) -> jax.Array:
    """m_arr: (T², tiles_y·tiles_x, Cout) → (tiles_y·m, tiles_x·m, Cout).

    As the final Winograd stage it owns the fused epilogue: Y = Aᵀ M A flows
    through ReLU/bias while still VMEM-resident. ``bias`` (if given): (1, C).
    """
    t = m + r - 1
    tt, n_tiles, c = m_arr.shape
    assert tt == t * t and n_tiles == tiles_y * tiles_x
    at_host = jnp.asarray(matrices(m, r)[2])

    def kernel(m_ref, at_ref, *rest):
        if len(rest) == 2:
            bias_ref, y_ref = rest
        else:
            (y_ref,), bias_ref = rest, None
        at = at_ref[...]
        blk = m_ref[...].astype(jnp.float32)      # (T², tiles_x, C)
        mm = blk.reshape(t, t, tiles_x, c)
        y = jnp.einsum("mi,ijxc,nj->xmnc", at, mm, at)  # (tiles_x, m, m, c)
        y = apply_epilogue(y, epilogue,
                           bias_ref[0] if bias_ref is not None else None)
        y_ref[...] = y.transpose(1, 0, 2, 3).reshape(
            m, tiles_x * m, c).astype(y_ref.dtype)

    in_specs = [pl.BlockSpec((t * t, tiles_x, c), lambda i: (0, i, 0)),
                pl.BlockSpec((m, t), lambda i: (0, 0))]
    operands = [m_arr, at_host]
    if bias is not None:
        assert bias.shape == (1, c), (bias.shape, c)
        in_specs.append(pl.BlockSpec((1, c), lambda i: (0, 0)))
        operands.append(bias)
    return pl.pallas_call(
        kernel,
        grid=(tiles_y,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m, tiles_x * m, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles_y * m, tiles_x * m, c),
                                       m_arr.dtype),
        interpret=interpret,
    )(*operands)
