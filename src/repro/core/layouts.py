"""DRAM store-format specs for inter-layer layout transitions (§3.3/§5.1).

DYNAMAP's cost graph prices every edge with a Table 2 store+load matrix and
lets split vertices pick one DRAM layout per fan-out. Until now those
choices were cost-model-only; this module is the metadata half of making
them *executable*: a ``LayoutSpec`` names the concrete tensor representation
an edge carries between two layers, pinned to the consumer's conv geometry
(a Toeplitz matrix is only meaningful for a specific (K, stride, padding)).

The three kinds mirror ``core.algorithms.Layout`` (Table 1):

* ``nhwc``     — the spatial 3-D tensor (TENSOR3D); the universal
  interchange format every kernel can produce and consume.
* ``toeplitz`` — the im2col matrix ``(O1·O2, K1·K2·C)`` of the consumer's
  conv (TOEPLITZ); a matched consumer feeds it straight to the GEMM unit.
* ``winograd`` — the scattered tile layout: overlapping (m+r-1)² input
  tiles ``(tiles, T, T, C)`` of the consumer's F(m,r) conv (WINOGRAD);
  a matched consumer skips the spatial re-gather and transforms tiles
  directly.

``repro.kernels.layouts`` holds the runtime (jnp) conversions; this module
stays import-light so the mapper can build transition specs without pulling
in JAX.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.algorithms import Algorithm, AlgoFamily, Layout
from repro.core.graph import ConvMeta

LAYOUT_KINDS = ("nhwc", "toeplitz", "winograd")


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class LayoutSpec:
    """One concrete inter-layer tensor representation.

    ``(h, w, c)`` is the producer's NHWC output shape — the shape the spec
    converts from and restores to. ``k1/k2/stride/padding`` pin the
    consumer conv geometry for ``toeplitz``; ``m/r`` additionally pin the
    Winograd tile size for ``winograd`` (where ``k1 == k2 == r``: only
    single-round F(m,r) layers consume tiles directly). Frozen and hashable
    so specs ride inside ``ConvLowering`` as jit-static arguments.
    """
    kind: str = "nhwc"
    h: int = 0
    w: int = 0
    c: int = 0
    k1: int = 0
    k2: int = 0
    stride: int = 1
    padding: str = "SAME"
    m: int = 0
    r: int = 0

    def __post_init__(self) -> None:
        if self.kind not in LAYOUT_KINDS:
            raise ValueError(
                f"unknown layout kind {self.kind!r}; want one of {LAYOUT_KINDS}")
        if self.padding not in ("SAME", "VALID"):
            raise ValueError(f"bad padding {self.padding!r}; want SAME|VALID")
        if self.kind != "nhwc":
            if min(self.h, self.w, self.c, self.k1, self.k2) <= 0:
                raise ValueError(f"{self.kind} spec needs positive geometry, "
                                 f"got {self}")
            if self.stride < 1:
                raise ValueError(f"bad stride {self.stride} in {self}")
        if self.kind == "winograd":
            if self.m <= 0 or self.r <= 0:
                raise ValueError(f"winograd spec needs m, r > 0, got {self}")
            if self.k1 != self.r or self.k2 != self.r or self.stride != 1:
                raise ValueError(
                    "winograd tile layout is single-round only "
                    f"(k1 == k2 == r, stride 1), got {self}")

    # ----------------------------------------------------- derived geometry
    @property
    def o1(self) -> int:
        if self.padding == "SAME":
            return _ceil(self.h, self.stride)
        return (self.h - self.k1) // self.stride + 1

    @property
    def o2(self) -> int:
        if self.padding == "SAME":
            return _ceil(self.w, self.stride)
        return (self.w - self.k2) // self.stride + 1

    @property
    def t(self) -> int:
        return self.m + self.r - 1

    @property
    def tiles_y(self) -> int:
        return _ceil(self.o1, self.m)

    @property
    def tiles_x(self) -> int:
        return _ceil(self.o2, self.m)

    @property
    def pad_top(self) -> int:
        if self.padding == "VALID":
            return 0
        if self.kind == "winograd":
            return (self.r - 1) // 2
        ph = max((self.o1 - 1) * self.stride + self.k1 - self.h, 0)
        return ph // 2

    @property
    def pad_left(self) -> int:
        if self.padding == "VALID":
            return 0
        if self.kind == "winograd":
            return (self.r - 1) // 2
        pw = max((self.o2 - 1) * self.stride + self.k2 - self.w, 0)
        return pw // 2

    @property
    def base_rank(self) -> int:
        """Rank of one un-batched value in this layout (a leading batch dim
        adds one): nhwc (H, W, C); toeplitz (O1O2, K1K2C); winograd
        (tiles, T, T, C)."""
        return {"nhwc": 3, "toeplitz": 2, "winograd": 4}[self.kind]

    @property
    def layout(self) -> Layout:
        """The §3.3 Layout this spec realizes (cost-model pairing)."""
        return {"nhwc": Layout.TENSOR3D, "toeplitz": Layout.TOEPLITZ,
                "winograd": Layout.WINOGRAD}[self.kind]

    @property
    def key(self) -> str:
        if self.kind == "nhwc":
            return "nhwc"
        if self.kind == "toeplitz":
            return (f"toeplitz[k{self.k1}x{self.k2}s{self.stride}"
                    f"_{self.h}x{self.w}x{self.c}]")
        return f"winograd[F{self.m}x{self.r}_{self.h}x{self.w}x{self.c}]"


NHWC = LayoutSpec()


def is_nhwc(spec: Optional[LayoutSpec]) -> bool:
    return spec is None or spec.kind == "nhwc"


def invertible(spec: LayoutSpec) -> bool:
    """Can NHWC be recovered exactly from this layout?

    Needed wherever another consumer of the same stored value wants a
    different representation (the Table 2 "converting load"). Winograd
    tiles overlap, so every padded pixel survives; a Toeplitz matrix drops
    pixels when windows skip them (stride > kernel) or when VALID windows
    do not cover the input.
    """
    if spec.kind in ("nhwc", "winograd"):
        return True
    if spec.stride > min(spec.k1, spec.k2):
        return False
    if spec.padding == "VALID":
        return ((spec.o1 - 1) * spec.stride + spec.k1 >= spec.h
                and (spec.o2 - 1) * spec.stride + spec.k2 >= spec.w)
    return True


def consumer_spec(algo: Algorithm, conv: ConvMeta) -> Optional[LayoutSpec]:
    """The store format a conv layer running ``algo`` consumes directly —
    the matched-load format of Table 2 — or None when the layer cannot
    consume anything but NHWC (then the edge keeps the round trip).

    kn2row's input layout IS the 3-D tensor, so it "matches" trivially;
    im2col consumes its own Toeplitz matrix; a single-round F(m,r) layer
    (square K == r, stride 1) consumes its pre-gathered tile layout.
    Non-invertible Toeplitz geometries are rejected so a stored format can
    always serve a mismatched sibling at a split via a converting load.
    """
    pad = "SAME" if conv.pad == "same" else "VALID"
    if algo.family is AlgoFamily.KN2ROW:
        return NHWC
    if algo.family is AlgoFamily.IM2COL:
        spec = LayoutSpec("toeplitz", h=conv.h1, w=conv.h2, c=conv.c_in,
                          k1=conv.k1, k2=conv.k2, stride=conv.stride,
                          padding=pad)
        return spec if invertible(spec) else None
    # Winograd: tile layout only for the single-round fast path.
    if conv.k1 != conv.k2 or conv.k1 != algo.r or conv.stride != 1:
        return None
    return LayoutSpec("winograd", h=conv.h1, w=conv.h2, c=conv.c_in,
                      k1=conv.k1, k2=conv.k2, stride=1, padding=pad,
                      m=algo.m, r=algo.r)
