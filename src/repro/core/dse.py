"""Hardware-customization DSE — Algorithm 1 of the paper, TPU-adapted.

The paper sweeps systolic-array shapes (P_SA1, P_SA2) under the FPGA DSP
budget and, for every (layer, algorithm), picks the dataflow ψ minimizing
Eq. 9; the array shape minimizing the empirical total node cost τ_emp wins.

On TPU the array shape becomes the Pallas GEMM block shape (BM, BN): the
resource constraint is the VMEM working set (operand panels + accumulator,
double-buffered) instead of DSPs, and candidate dims are MXU-aligned
multiples of 128.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.algorithms import Algorithm, menu_for
from repro.core.cost_model import (ALL_DATAFLOWS, Dataflow, NodeCost, TPUSpec,
                                   V5E, best_dataflow, node_cost)
from repro.core.graph import ConvMeta, Graph, LayerKind


@dataclasses.dataclass(frozen=True)
class HardwareChoice:
    p1: int                       # BM — rows of the virtual systolic array
    p2: int                       # BN — cols
    k_panel: int                  # K-panel depth used for the VMEM bound
    # ψ[layer, algorithm] → best dataflow (line 8 of Algorithm 1)
    psi: Dict[Tuple[int, str], Dataflow]
    tau_emp: float


def vmem_working_set(p1: int, p2: int, k_panel: int, spec: TPUSpec) -> int:
    """Bytes of VMEM a (p1, p2, k_panel) GEMM block claims.

    Two operand panels (double-buffered) + the f32 accumulator tile. This is
    the TPU analogue of C(P_SA1, P_SA2 | r) ≤ C_FPGA in Algorithm 1 line 4.
    """
    operand = (p1 * k_panel + k_panel * p2) * spec.dtype_bytes * 2
    acc = p1 * p2 * 4
    return operand + acc


def candidate_shapes(spec: TPUSpec, k_panel: int = 512,
                     max_dim: int = 2048) -> List[Tuple[int, int]]:
    dims = [d for d in range(spec.mxu, max_dim + 1, spec.mxu)]
    out = []
    for p1, p2 in itertools.product(dims, dims):
        if vmem_working_set(p1, p2, k_panel, spec) <= spec.vmem_budget:
            out.append((p1, p2))
    return out


def identify_parameters(graph: Graph,
                        menu: Optional[Sequence[Algorithm]] = None,
                        spec: TPUSpec = V5E,
                        k_panel: int = 512,
                        max_dim: int = 2048) -> HardwareChoice:
    """Algorithm 1: sweep (P_SA1, P_SA2); per (layer, algo) keep the best
    dataflow; return the shape minimizing empirical total node cost."""
    convs = graph.conv_nodes()
    best: Optional[HardwareChoice] = None
    for (p1, p2) in candidate_shapes(spec, k_panel, max_dim):
        tau = 0.0
        psi: Dict[Tuple[int, str], Dataflow] = {}
        for node in convs:
            assert node.conv is not None
            for algo in menu_for(node.conv, list(menu) if menu else None):
                nc_best: Optional[NodeCost] = None
                for df in ALL_DATAFLOWS:
                    nc = node_cost(node.conv, algo, p1, p2, df, spec)
                    if nc_best is None or nc.total < nc_best.total:
                        nc_best = nc
                assert nc_best is not None
                psi[(node.id, algo.key)] = nc_best.dataflow
                tau += nc_best.total          # line 10: sum over all algos
        if best is None or tau < best.tau_emp:
            best = HardwareChoice(p1=p1, p2=p2, k_panel=k_panel, psi=psi,
                                  tau_emp=tau)
    assert best is not None
    return best
