"""Partitioned Boolean Quadratic Programming (PBQP) solvers.

The DYNAMAP algorithm-mapping problem (Eq. 8):

    minimize  Σ_{i<j} x_i^T T_ij x_j  +  Σ_i x_i^T c_i
    s.t.      x_i ∈ {0,1}^{|c_i|},  ||x_i||_1 == 1

PBQP is NP-complete in general (§4), but Theorems 4.1/4.2 show that on
*series-parallel* graphs the optimum is found in O(N·d²) by the two
optimality-preserving reductions of Definition 1:

  (1) degree-2 vertex elimination:  folding  min_b [ M_ub(a,b) + c_v(b)
      + M_vw(b,c) ]  into a new edge (u,w);
  (2) parallel-edge merge:          T_ij ← T_ij^1 + T_ij^2.

We additionally implement the standard PBQP R0/R1 rules (independent and
degree-1 vertices — these are the "Base step (1)" vertices of the paper's
induction), a brute-force oracle for optimality tests, the greedy baseline
the paper argues against (§6.1.2), and an RN heuristic fallback so that
non-series-parallel graphs still get a (possibly suboptimal) answer instead
of an error.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

Assignment = Dict[int, int]


@dataclasses.dataclass
class Edge:
    u: int
    v: int
    m: np.ndarray  # shape (d_u, d_v)

    def oriented(self, a: int, b: int) -> np.ndarray:
        """Matrix oriented so rows index node ``a`` and cols node ``b``."""
        if (a, b) == (self.u, self.v):
            return self.m
        if (a, b) == (self.v, self.u):
            return self.m.T
        raise KeyError((a, b))


class PBQP:
    """A PBQP instance over an undirected multigraph."""

    def __init__(self) -> None:
        self.costs: Dict[int, np.ndarray] = {}
        self.edges: List[Edge] = []

    # ---------------------------------------------------------------- build
    def add_node(self, nid: int, cost: Sequence[float]) -> None:
        c = np.asarray(cost, dtype=np.float64)
        if c.ndim != 1 or c.size == 0:
            raise ValueError(f"node {nid}: cost vector must be 1-D non-empty")
        if nid in self.costs:
            raise KeyError(f"duplicate node {nid}")
        self.costs[nid] = c

    def add_edge(self, u: int, v: int, m: np.ndarray) -> None:
        m = np.asarray(m, dtype=np.float64)
        if u == v:
            raise ValueError("self loops are not valid PBQP edges")
        if m.shape != (self.costs[u].size, self.costs[v].size):
            raise ValueError(
                f"edge ({u},{v}): matrix shape {m.shape} != "
                f"({self.costs[u].size},{self.costs[v].size})")
        self.edges.append(Edge(u, v, m))

    # ---------------------------------------------------------------- util
    def total_cost(self, assignment: Assignment) -> float:
        tot = 0.0
        for nid, c in self.costs.items():
            tot += float(c[assignment[nid]])
        for e in self.edges:
            tot += float(e.m[assignment[e.u], assignment[e.v]])
        return tot

    def copy(self) -> "PBQP":
        p = PBQP()
        p.costs = {k: v.copy() for k, v in self.costs.items()}
        p.edges = [Edge(e.u, e.v, e.m.copy()) for e in self.edges]
        return p

    def _adjacency(self) -> Dict[int, List[Edge]]:
        adj: Dict[int, List[Edge]] = {nid: [] for nid in self.costs}
        for e in self.edges:
            adj[e.u].append(e)
            adj[e.v].append(e)
        return adj


# ----------------------------------------------------------------------------
# Exact solver via series-parallel reduction (Theorems 4.1 / 4.2).
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class SolveResult:
    assignment: Assignment
    cost: float
    reductions: int          # number of reduction operations applied
    exact: bool              # False if the RN heuristic fired


def solve_series_parallel(problem: PBQP,
                          allow_heuristic: bool = True) -> SolveResult:
    """Optimal PBQP on series-parallel graphs in O(N·d²) reductions.

    Reduction loop:
      * parallel-edge merge (operation 2) whenever two edges share endpoints;
      * R0: isolated vertex  → pick argmin of its cost vector;
      * R1: degree-1 vertex  → fold into its neighbor's cost vector;
      * R2: degree-2 vertex  → fold into a new edge between its neighbors
        (operation 1 / base case (1) in the proof of Theorem 4.1);
      * if none applies and nodes remain: the graph is not series-parallel.
        With ``allow_heuristic`` we apply the classic PBQP RN rule (locally
        minimal choice at a max-degree node); otherwise raise.

    Decisions eliminated early are reconstructed by back-substitution, in
    reverse order, exactly as in the constructive proof.
    """
    p = problem.copy()
    # Each entry: (node, reconstruct_fn) where reconstruct_fn(assignment)->choice
    trail: List[Tuple[int, Callable[[Assignment], int]]] = []
    reductions = 0
    exact = True

    def merge_parallel() -> bool:
        nonlocal reductions
        by_pair: Dict[frozenset, List[int]] = {}
        for idx, e in enumerate(p.edges):
            by_pair.setdefault(frozenset((e.u, e.v)), []).append(idx)
        for pair, idxs in by_pair.items():
            if len(idxs) > 1:
                base = p.edges[idxs[0]]
                for other_idx in idxs[1:]:
                    other = p.edges[other_idx]
                    base.m = base.m + other.oriented(base.u, base.v)
                p.edges = [e for i, e in enumerate(p.edges) if i not in set(idxs[1:])]
                reductions += 1
                return True
        return False

    while True:
        if merge_parallel():
            continue

        adj = p._adjacency()
        if not p.costs:
            break

        # R0 — isolated vertex.
        r0 = next((n for n, es in adj.items() if len(es) == 0), None)
        if r0 is not None:
            choice = int(np.argmin(p.costs[r0]))
            trail.append((r0, lambda a, _c=choice: _c))
            del p.costs[r0]
            reductions += 1
            continue

        # R1 — degree-1 vertex v with neighbor u.
        r1 = next((n for n, es in adj.items() if len(es) == 1), None)
        if r1 is not None:
            e = adj[r1][0]
            u = e.v if e.u == r1 else e.u
            m_uv = e.oriented(u, r1)                       # (d_u, d_v)
            folded = m_uv + p.costs[r1][None, :]           # (d_u, d_v)
            best_v = np.argmin(folded, axis=1)             # per u-choice
            p.costs[u] = p.costs[u] + np.min(folded, axis=1)
            p.edges.remove(e)
            del p.costs[r1]
            trail.append((r1, lambda a, _u=u, _bv=best_v: int(_bv[a[_u]])))
            reductions += 1
            continue

        # R2 — degree-2 vertex v with neighbors u, w (operation 1).
        r2 = next((n for n, es in adj.items() if len(es) == 2), None)
        if r2 is not None:
            e1, e2 = adj[r2]
            u = e1.v if e1.u == r2 else e1.u
            w = e2.v if e2.u == r2 else e2.u
            m_uv = e1.oriented(u, r2)                      # (d_u, d_v)
            m_vw = e2.oriented(r2, w)                      # (d_v, d_w)
            # delta[a, b, c] = m_uv[a,b] + c_v[b] + m_vw[b,c]
            delta = (m_uv[:, :, None] + p.costs[r2][None, :, None]
                     + m_vw[None, :, :])                   # (d_u, d_v, d_w)
            best_v = np.argmin(delta, axis=1)              # (d_u, d_w)
            new_m = np.min(delta, axis=1)                  # (d_u, d_w)
            p.edges.remove(e1)
            p.edges.remove(e2)
            del p.costs[r2]
            p.add_edge(u, w, new_m)
            trail.append((r2, lambda a, _u=u, _w=w, _bv=best_v:
                          int(_bv[a[_u], a[_w]])))
            reductions += 1
            continue

        # Two nodes + one edge left → solve exactly and stop.
        if len(p.costs) == 2 and len(p.edges) == 1:
            e = p.edges[0]
            total = (p.costs[e.u][:, None] + p.costs[e.v][None, :] + e.m)
            iu, iv = np.unravel_index(np.argmin(total), total.shape)
            trail.append((e.u, lambda a, _c=int(iu): _c))
            trail.append((e.v, lambda a, _c=int(iv): _c))
            p.edges.clear()
            p.costs.clear()
            break

        if len(p.costs) == 1 and not p.edges:
            nid = next(iter(p.costs))
            choice = int(np.argmin(p.costs[nid]))
            trail.append((nid, lambda a, _c=choice: _c))
            p.costs.clear()
            break

        # Stuck: not series-parallel.
        if not allow_heuristic:
            raise ValueError("graph is not series-parallel; reduction stalled")
        exact = False
        # RN heuristic: pick the max-degree node; choose its locally best
        # option (node cost + best-case contribution of each incident edge),
        # then fold that choice into the neighbors' cost vectors.
        n = max(adj, key=lambda k: len(adj[k]))
        local = p.costs[n].copy()
        for e in adj[n]:
            local += np.min(e.oriented(n, e.v if e.u == n else e.u), axis=1)
        choice = int(np.argmin(local))
        for e in list(adj[n]):
            other = e.v if e.u == n else e.u
            p.costs[other] = p.costs[other] + e.oriented(other, n)[:, choice]
            p.edges.remove(e)
        del p.costs[n]
        trail.append((n, lambda a, _c=choice: _c))
        reductions += 1

    # Back-substitute in reverse elimination order.
    assignment: Assignment = {}
    for nid, fn in reversed(trail):
        assignment[nid] = fn(assignment)

    return SolveResult(assignment=assignment,
                       cost=problem.total_cost(assignment),
                       reductions=reductions,
                       exact=exact)


# ----------------------------------------------------------------------------
# Oracles / baselines.
# ----------------------------------------------------------------------------

def solve_brute_force(problem: PBQP, max_states: int = 5_000_000) -> SolveResult:
    """Exhaustive enumeration — the optimality oracle for tests."""
    nids = sorted(problem.costs)
    dims = [problem.costs[n].size for n in nids]
    n_states = 1
    for d in dims:
        n_states *= d
    if n_states > max_states:
        raise ValueError(f"state space {n_states} exceeds cap {max_states}")
    best: Optional[Assignment] = None
    best_cost = float("inf")
    for combo in itertools.product(*[range(d) for d in dims]):
        a = dict(zip(nids, combo))
        c = problem.total_cost(a)
        if c < best_cost:
            best_cost = c
            best = a
    assert best is not None
    return SolveResult(assignment=best, cost=best_cost, reductions=0, exact=True)


def solve_greedy_node(problem: PBQP) -> SolveResult:
    """The paper's strawman (§6.1.2): per-node argmin of the node cost only,
    ignoring transition costs entirely."""
    a = {nid: int(np.argmin(c)) for nid, c in problem.costs.items()}
    return SolveResult(assignment=a, cost=problem.total_cost(a),
                       reductions=0, exact=False)


def solve_greedy_incremental(problem: PBQP, order: Sequence[int]) -> SolveResult:
    """Greedy in a given (topological) order: each node picks the choice that
    minimizes node cost + transitions to already-assigned neighbors."""
    adj = problem._adjacency()
    a: Assignment = {}
    for nid in order:
        local = problem.costs[nid].copy()
        for e in adj[nid]:
            other = e.v if e.u == nid else e.u
            if other in a:
                local += e.oriented(nid, other)[:, a[other]]
        a[nid] = int(np.argmin(local))
    return SolveResult(assignment=a, cost=problem.total_cost(a),
                       reductions=0, exact=False)
