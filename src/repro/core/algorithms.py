"""Convolution algorithm space (§2.1): im2col, kn2row, Winograd F(m,r).

Each algorithm turns a CONV layer into one or more GEMMs; this module captures
(a) which algorithms are applicable to a given layer, (b) the GEMM dimensions
each induces (Eq. 10-12), and (c) the tensor layouts they consume/produce
(§3.3 — needed for the transition matrices).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import List, Optional, Tuple

from repro.core.graph import ConvMeta


class AlgoFamily(enum.Enum):
    IM2COL = "im2col"
    KN2ROW = "kn2row"
    WINOGRAD = "winograd"


class Layout(enum.Enum):
    """Tensor layouts of §3.3 (Table 1)."""
    TOEPLITZ = "toeplitz"       # im2col input
    TENSOR3D = "tensor3d"       # im2col/kn2row output, kn2row input
    WINOGRAD = "winograd"       # scattered (m+r-1)^2 tile layout


@dataclasses.dataclass(frozen=True)
class Algorithm:
    family: AlgoFamily
    m: int = 0   # Winograd output tile
    r: int = 0   # Winograd kernel tile

    def __str__(self) -> str:
        if self.family is AlgoFamily.WINOGRAD:
            return f"winograd(F{self.m}x{self.r})"
        return self.family.value

    @property
    def key(self) -> str:
        return str(self)

    # ------------------------------------------------------------- layouts
    @property
    def input_layout(self) -> Layout:
        return {
            AlgoFamily.IM2COL: Layout.TOEPLITZ,
            AlgoFamily.KN2ROW: Layout.TENSOR3D,
            AlgoFamily.WINOGRAD: Layout.WINOGRAD,
        }[self.family]

    @property
    def output_layout(self) -> Layout:
        # im2col and kn2row both emit the spatial 3D-tensor layout (§3.3);
        # Winograd emits the scattered tile layout.
        if self.family is AlgoFamily.WINOGRAD:
            return Layout.WINOGRAD
        return Layout.TENSOR3D

    # ------------------------------------------------------- applicability
    def applicable(self, conv: ConvMeta) -> bool:
        if self.family is AlgoFamily.WINOGRAD:
            # Paper §6.1.2: Winograd applied on layers with square-shaped
            # kernels; F(m,r) needs stride 1. Kernels wider than r run in
            # ceil(K1K2/r^2) rounds of r×r sub-kernels; kernels *smaller*
            # than r would be zero-padded up to r, wasting multiplies with
            # no accuracy in the cost model — so the menu requires K ≥ r.
            return (conv.k1 == conv.k2 and conv.k1 >= self.r
                    and conv.stride == 1)
        if self.family is AlgoFamily.KN2ROW:
            # kn2row decomposes into K1K2 unit convs; stride>1 handled by
            # strided sampling of the accumulate phase — supported.
            return True
        return True   # im2col is universal

    # --------------------------------------------------------- GEMM shapes
    def gemm_calls(self, conv: ConvMeta) -> List[Tuple[int, int, int]]:
        """The (a, b, c) = (rows(X), depth, cols(W)) GEMM dims induced.

        im2col   (Eq. 2/10):  one GEMM   (O1O2, K1K2*Cin, Cout)
        kn2row   (Eq. 3/11):  K1K2 GEMMs (O1O2, Cin, Cout)
        winograd (Eq. 6/12):  rounds*(m+r-1)^2 GEMMs (H1H2/m^2, Cin, Cout)
        """
        if self.family is AlgoFamily.IM2COL:
            return [(conv.o1 * conv.o2, conv.k1 * conv.k2 * conv.c_in, conv.c_out)]
        if self.family is AlgoFamily.KN2ROW:
            return [(conv.o1 * conv.o2, conv.c_in, conv.c_out)] * (conv.k1 * conv.k2)
        # Winograd: tiles over the *input* map (paper Eq. 12 uses H1H2/m^2).
        tiles = math.ceil(conv.h1 / self.m) * math.ceil(conv.h2 / self.m)
        rounds = math.ceil((conv.k1 * conv.k2) / (self.r * self.r))
        n_gemms = rounds * (self.m + self.r - 1) ** 2
        return [(tiles, conv.c_in, conv.c_out)] * n_gemms

    def multiplies(self, conv: ConvMeta) -> int:
        """Total MXU multiplies under this algorithm (complexity trade-off
        of §2.1: Winograd reduces multiplies, im2col/kn2row match spatial)."""
        return sum(a * b * c for (a, b, c) in self.gemm_calls(conv))


# Default algorithm menu — the paper's three families with the Winograd
# hyper-parameters it evaluates (m=2, r=3) plus the F(4,3) variant discussed
# in §2.1 ("F(4x4, 3x3) ... reduction of multiplications is 4 times").
IM2COL = Algorithm(AlgoFamily.IM2COL)
KN2ROW = Algorithm(AlgoFamily.KN2ROW)
WINO_2_3 = Algorithm(AlgoFamily.WINOGRAD, m=2, r=3)
WINO_4_3 = Algorithm(AlgoFamily.WINOGRAD, m=4, r=3)

DEFAULT_MENU: List[Algorithm] = [IM2COL, KN2ROW, WINO_2_3, WINO_4_3]
PAPER_MENU: List[Algorithm] = [IM2COL, KN2ROW, WINO_2_3]


def menu_for(conv: ConvMeta,
             menu: Optional[List[Algorithm]] = None) -> List[Algorithm]:
    menu = DEFAULT_MENU if menu is None else menu
    out = [a for a in menu if a.applicable(conv)]
    if not out:
        raise ValueError(f"no applicable algorithm for conv {conv}")
    return out
