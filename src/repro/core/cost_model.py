"""TPU-adapted DYNAMAP cost model (paper Eq. 9-13, Table 2).

The paper models per-layer execution cycles on a P_SA1×P_SA2 systolic array
(Eq. 9) plus DRAM layout-transition latencies (Table 2, burst-wastage f of
Eq. 13). The TPU adaptation keeps every functional form and re-grounds the
constants:

* The "systolic array" is the virtual array realized by one Pallas GEMM
  block (BM×BN); a step of that array retires BM·BN MACs. Converting steps
  to seconds uses the chip's peak MAC rate, so perfect tiling ⇒ roofline
  compute time, and ceil-division padding reproduces the paper's
  effective-PE-utilization losses (Eq. 14) exactly.
* DDR bandwidth → HBM bandwidth (819 GB/s); the burst-length wastage f()
  becomes the lane-alignment penalty: arrays whose minor dim < 128 lanes
  waste the padded fraction of each VREG-granular transfer.
* The Winograd linear-transform overhead LT runs on the VPU, not the MXU.
* Collective terms (for sharded execution) use the ICI link bandwidth; the
  CNN-side model is single-chip (latency-oriented, batch=1, like the paper).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.algorithms import (Algorithm, AlgoFamily, Layout)
from repro.core.graph import ConvMeta


# ---------------------------------------------------------------------------
# Hardware description (FPGA device meta data → TPU chip meta data).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s
    ici_bw: float = 50e9                # bytes/s per link (~3 links/axis usable)
    vmem_bytes: int = 64 * 2 ** 20      # usable VMEM working set per core
    vmem_budget: int = 48 * 2 ** 20     # budget the DSE may claim for GEMM blocks
    mxu: int = 128                      # MXU systolic dimension / lane count
    sublane: int = 8
    vpu_flops: float = 3.9e12           # vector unit, for Winograd transforms
    dtype_bytes: int = 2                # bf16 default; 1 for the paper's int8

    @property
    def peak_macs(self) -> float:
        return self.peak_flops / 2.0


V5E = TPUSpec()
V5E_INT8 = dataclasses.replace(V5E, dtype_bytes=1, peak_flops=394e12,
                               name="tpu-v5e-int8")

# An Alveo-U200-like device (the paper's board) expressed in the same spec:
# 6084 DSPs × 286 MHz × 2 ops ≈ 3.48 TOP/s int8; DDR4 ≈ 19.2 GB/s effective;
# ~4 MB usable on-chip buffering; 64-wide bursts. Used by the benchmarks to
# validate the paper's *own* trade-offs (Table 4 direction) — on this spec
# the FPGA-regime algorithm mixes re-appear.
FPGA_LIKE = TPUSpec(name="alveo-u200-like", peak_flops=3.48e12,
                    hbm_bw=19.2e9, ici_bw=0.0, vmem_bytes=6 * 2 ** 20,
                    vmem_budget=4 * 2 ** 20, mxu=64, sublane=8,
                    vpu_flops=0.2e12, dtype_bytes=1)


class Dataflow(enum.Enum):
    """§3.2: Non-Stationary / Weight-Stationary / Input-Stationary."""
    NS = "NS"
    WS = "WS"
    IS = "IS"


ALL_DATAFLOWS = (Dataflow.NS, Dataflow.WS, Dataflow.IS)


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Eq. 9 — GEMM steps on the (virtual) systolic array.
# ---------------------------------------------------------------------------

def gemm_steps(a: int, b: int, c: int, p1: int, p2: int,
               dataflow: Dataflow, i_sa: Optional[int] = None) -> int:
    """Cycle count of a (a,b)x(b,c) GEMM on a p1×p2 array under ``dataflow``.

    Verbatim Eq. 9; I_SA is the one-time initialization overhead which the
    stall-free PE optimizations (§3.2) reduce to a single occurrence.
    """
    if i_sa is None:
        i_sa = max(p1, p2)
    if dataflow is Dataflow.NS:
        return _ceil(a, p1) * _ceil(c, p2) * b + i_sa
    if dataflow is Dataflow.WS:
        return _ceil(b, p1) * _ceil(c, p2) * a + i_sa
    return _ceil(b, p1) * _ceil(a, p2) * c + i_sa


def best_dataflow(a: int, b: int, c: int, p1: int, p2: int) -> Tuple[Dataflow, int]:
    """argmin over Eq. 9 — line 7-8 of Algorithm 1."""
    best = None
    for df in ALL_DATAFLOWS:
        s = gemm_steps(a, b, c, p1, p2, df)
        if best is None or s < best[1]:
            best = (df, s)
    return best


def gemm_utilization(a: int, b: int, c: int, p1: int, p2: int,
                     dataflow: Dataflow) -> float:
    """Effective PE utilization μ of Eq. 14 for one GEMM."""
    steps = gemm_steps(a, b, c, p1, p2, dataflow, i_sa=0)
    return (a * b * c) / (steps * p1 * p2)


# ---------------------------------------------------------------------------
# Per-layer node costs (Eq. 10-12) in seconds.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NodeCost:
    """Decomposed per-layer cost; ``total`` is what enters the PBQP node
    cost vector."""
    compute_s: float          # MXU time (Eq. 9 steps → seconds)
    transform_s: float        # Winograd LT / kn2row pad-accumulate (VPU)
    memory_s: float           # HBM traffic incl. operand re-fetch
    dataflow: Dataflow
    steps: int
    utilization: float

    @property
    def total(self) -> float:
        # HBM streaming overlaps MXU compute on TPU (double-buffered DMA),
        # so the layer is bound by the slower of the two; the VPU transform
        # stage is pipelined with GEMM but its residual exposed cost is
        # modeled additively (paper adds LT inside Eq. 12 the same way).
        return max(self.compute_s, self.memory_s) + self.transform_s


# Per-tile instruction counts of the Winograd data / inverse transforms
# (Lavin & Gray §5; the paper exploits the same ±1, ±1/2 structure as
# shift-adds). Fallback: dense 2×(t×t) constant matmuls.
_WINO_XFORM_OPS = {
    (2, 3): (32, 24),       # F(2x2, 3x3): data 32 ops, inverse 24 ops
    (4, 3): (156, 90),      # F(4x4, 3x3)
}


def _winograd_transform_flops(conv: ConvMeta, m: int, r: int) -> float:
    """Add/shift ops of the B^T d B, A^T M A transforms (G g G^T is
    precomputed once per model and amortized, as in the paper §3.1)."""
    t = m + r - 1
    tiles = math.ceil(conv.h1 / m) * math.ceil(conv.h2 / m)
    rounds = math.ceil((conv.k1 * conv.k2) / (r * r))
    if (m, r) in _WINO_XFORM_OPS:
        per_tile_in, per_tile_out = _WINO_XFORM_OPS[(m, r)]
    else:
        per_tile_in = 2 * (2 * t ** 3)
        per_tile_out = 2 * (2 * t * t * m + 2 * t * m * m)
    return float(rounds) * (tiles * conv.c_in * per_tile_in
                            + tiles * conv.c_out * per_tile_out)


def gemm_hbm_bytes(a: int, b: int, c: int, p1: int, p2: int,
                   dataflow: Dataflow, spec: TPUSpec) -> float:
    """HBM traffic of a tiled GEMM, including operand re-fetch.

    This is the TPU-side counterpart of Eq. 9: block shape determines how
    often each operand panel streams from HBM. Operands that fit whole in
    the VMEM budget are counted once (they stay resident — the FPGA design's
    on-chip Input/Kernel buffers).
    """
    dt = spec.dtype_bytes
    a_bytes, b_bytes, c_bytes = a * b * dt, b * c * dt, a * c * dt
    budget = spec.vmem_budget

    if dataflow is Dataflow.NS:
        # Output-stationary: A row-panels refetched per N-tile, B column-
        # panels refetched per M-tile, C written once.
        ra, rb, rc = _ceil(c, p2), _ceil(a, p1), 1
    elif dataflow is Dataflow.WS:
        # Weight block (K×N tile) resident; A streamed per (K,N) block-row;
        # partial C revisited once per K-tile.
        ra, rb = _ceil(c, p2), 1
        rc = 2 * _ceil(b, p1) - 1
    else:  # IS
        ra, rb = 1, _ceil(a, p2)
        rc = 2 * _ceil(b, p1) - 1

    total = 0.0
    total += a_bytes if a_bytes <= budget else ra * a_bytes
    total += b_bytes if b_bytes <= budget else rb * b_bytes
    total += c_bytes if c_bytes <= budget else rc * c_bytes
    return total


def node_cost(conv: ConvMeta, algo: Algorithm, p1: int, p2: int,
              dataflow: Optional[Dataflow] = None,
              spec: TPUSpec = V5E) -> NodeCost:
    """Latency of executing one CONV layer under (algorithm, dataflow).

    Eq. 10 (im2col), Eq. 11 (kn2row ×K1K2), Eq. 12 (winograd ×(m+r-1)^2
    with LT overhead); cycles/FREQ → steps·(p1·p2)/peak_macs.
    """
    calls = algo.gemm_calls(conv)
    # All calls in one layer share dims, so pick the dataflow once (§5.2).
    a, b, c = calls[0]
    n_calls = len(calls)
    if dataflow is None:
        dataflow, _ = best_dataflow(a, b, c, p1, p2)
    # I_SA is paid once per *layer* thanks to the stall-free PE design; the
    # per-pass overheads are overlapped (§3.2).
    steps = n_calls * gemm_steps(a, b, c, p1, p2, dataflow, i_sa=0)
    steps += max(p1, p2)
    compute_s = steps * (p1 * p2) / spec.peak_macs

    transform_s = 0.0
    if algo.family is AlgoFamily.WINOGRAD:
        transform_s = _winograd_transform_flops(conv, algo.m, algo.r) / spec.vpu_flops
    elif algo.family is AlgoFamily.KN2ROW:
        # Pad-and-Accumulate: K1K2·O1O2·Cout adds, pipelined with GEMM
        # (§3.1) — residual exposed cost modeled on the VPU.
        transform_s = (conv.k1 * conv.k2 * conv.o1 * conv.o2 * conv.c_out
                       ) / spec.vpu_flops

    # HBM traffic: every GEMM call streams its operands (with re-fetch per
    # the block shape); kn2row re-reads the input map per unit conv only if
    # it cannot stay VMEM-resident (the kernel keeps it resident — mirrored
    # here), and Winograd streams the transform-space tiles.
    if algo.family is AlgoFamily.KN2ROW:
        in_bytes = a * b * spec.dtype_bytes
        if in_bytes > spec.vmem_budget:
            mem_bytes = n_calls * gemm_hbm_bytes(a, b, c, p1, p2, dataflow,
                                                 spec)
        else:
            mem_bytes = (gemm_hbm_bytes(a, b, c, p1, p2, dataflow, spec)
                         + (n_calls - 1) * (b * c + a * c) * spec.dtype_bytes)
    else:
        mem_bytes = n_calls * gemm_hbm_bytes(a, b, c, p1, p2, dataflow, spec)
    memory_s = mem_bytes / spec.hbm_bw

    total_macs = n_calls * a * b * c
    util = total_macs / (steps * p1 * p2) if steps else 0.0
    return NodeCost(compute_s=compute_s, transform_s=transform_s,
                    memory_s=memory_s, dataflow=dataflow,
                    steps=steps, utilization=util)


# ---------------------------------------------------------------------------
# Eq. 13 — bandwidth wastage. DDR burst-length → TPU lane alignment.
# ---------------------------------------------------------------------------

def eff_bandwidth(spec: TPUSpec, minor_dim: int) -> float:
    """f(BW, C): transfers whose minor dimension underfills the 128-lane
    VREG granularity waste the padded fraction (Eq. 13's shape, re-grounded)."""
    if minor_dim >= spec.mxu:
        return spec.hbm_bw
    padded = spec.mxu
    return spec.hbm_bw * (minor_dim / padded)


# ---------------------------------------------------------------------------
# Table 2 — layout-transition (store + load) latencies between layers.
# ---------------------------------------------------------------------------

def _store_bytes(src: Algorithm, dst: Algorithm, nxt: ConvMeta,
                 c_out_prev: int, spec: TPUSpec,
                 implicit_im2col: bool = False) -> Tuple[float, float]:
    """Bytes written for the AF_i → AF_{i+1} store and the effective BW.

    Dim convention follows Table 2: H/K/O are the *next* layer's meta data,
    C_out(i) is the producing layer's channel count.
    """
    dt = spec.dtype_bytes
    sf, df_ = src.output_layout, dst.input_layout

    if df_ is Layout.TOEPLITZ:
        if implicit_im2col:
            # Beyond-paper mode: implicit-GEMM conv gathers windows on-chip,
            # so only the 3-D tensor ever hits HBM.
            bytes_ = nxt.h1 * nxt.h2 * c_out_prev * dt
            bw = spec.hbm_bw
        else:
            bytes_ = nxt.o1 * nxt.o2 * nxt.k1 * nxt.k2 * c_out_prev * dt
            bw = spec.hbm_bw
        if sf is Layout.WINOGRAD:
            # Row 5: two-step (Winograd→3D→Toeplitz) with pipelined LTUs;
            # ovhd = pipeline fill of the second LTU.
            return bytes_, bw * 0.9
        return bytes_, bw

    if df_ is Layout.TENSOR3D:
        # Rows 2: one-to-one (or reorder-only) stores of H1H2·C elements.
        return nxt.h1 * nxt.h2 * c_out_prev * dt, spec.hbm_bw

    # df_ is WINOGRAD input layout.
    m = dst.m
    t = dst.m + dst.r - 1
    blow = (t * t) / (m * m)
    bytes_ = nxt.h1 * nxt.h2 * blow * c_out_prev * dt
    if sf is Layout.WINOGRAD:
        # Row 4: scattered→scattered is streaming.
        return bytes_, spec.hbm_bw
    # Row 3: scattered writes, addresses H1H2/m^2 apart → lane wastage f().
    return bytes_, eff_bandwidth(spec, c_out_prev)


@dataclasses.dataclass
class TransitionCalibration:
    """Measured-vs-predicted scale factors for Table 2 transitions.

    The analytical model prices a transition from layout bytes and
    bandwidth; on the machine actually executing the program the realized
    cost can differ (XLA fuses the conversion gather, caches absorb the
    round trip). Benchmarks that measure elided-vs-round-trip wall clock
    (``benchmarks/bench_layout_elision.py``) distill the ratio into scale
    factors keyed by (source layout, destination layout) — ``scale`` > 1
    means transitions cost more than modeled, < 1 less — and pass the
    calibration back into ``transition_cost`` so predicted savings can be
    reported in realized terms.
    """
    scales: Dict[Tuple[Layout, Layout], float] = \
        dataclasses.field(default_factory=dict)
    default: float = 1.0

    def scale(self, src: Layout, dst: Layout) -> float:
        return self.scales.get((src, dst), self.default)


def transition_cost(src: Algorithm, dst: Algorithm, nxt: ConvMeta,
                    c_out_prev: int, spec: TPUSpec = V5E,
                    implicit_im2col: bool = False,
                    extra_s: float = 0.0,
                    on_chip: bool = False,
                    calibration: Optional[TransitionCalibration] = None
                    ) -> float:
    """Table 2 store + load legs in seconds (+ pooling etc. via extra_s).

    ``on_chip=True`` models flow step ⑤: consecutive layers whose combined
    footprint fits in VMEM skip the HBM round trip entirely.
    ``calibration`` rescales the modeled cost by the measured factor for
    this (source layout, destination layout) pair.
    """
    if on_chip:
        return extra_s
    store_bytes, store_bw = _store_bytes(src, dst, nxt, c_out_prev, spec,
                                         implicit_im2col)
    # Load leg is symmetric (§3.3: "the DLT at data-load side performs
    # symmetric operations"): same byte count back in at full/effective BW.
    load_bytes, load_bw = store_bytes, store_bw
    cost = store_bytes / store_bw + load_bytes / load_bw
    if calibration is not None:
        cost *= calibration.scale(src.output_layout, dst.input_layout)
    return cost + extra_s


def fits_on_chip(prev_out_elems: int, next_in_elems: int,
                 spec: TPUSpec = V5E) -> bool:
    """Flow step ⑤: can the producer's output stay resident for the consumer?"""
    return (prev_out_elems + next_in_elems) * spec.dtype_bytes \
        <= spec.vmem_budget


# ---------------------------------------------------------------------------
# Roofline helpers shared with benchmarks / EXPERIMENTS.md.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(flops: float, bytes_hbm: float, bytes_collective: float,
             chips: int = 1, spec: TPUSpec = V5E,
             links_per_chip: float = 1.0) -> Roofline:
    return Roofline(
        compute_s=flops / (chips * spec.peak_flops),
        memory_s=bytes_hbm / (chips * spec.hbm_bw),
        collective_s=(bytes_collective / (chips * links_per_chip * spec.ici_bw)
                      if bytes_collective else 0.0),
    )
