"""Measured per-layer autotuning of overlay bindings (block autotuning
beyond the DSE's (p1, p2) sweep).

DYNAMAP's DSE picks each layer's algorithm, dataflow and (p1, p2) block
binding from the *analytical* cost model (Eq. 9/13). That model ranks
bindings for the paper's target hardware; on the machine actually serving
traffic the ranking can differ (interpreter overheads, cache behavior, XLA
fusion). This module closes the loop: for every conv layer it benchmarks
candidate ``(algorithm, dataflow, p1, p2, backend)`` bindings **on the
device**, caches the winners in a JSON tuning record keyed by the layer's
conv signature, and ``core.mapper.lower_plan`` consumes that record to
override the cost-model binding per layer — including mixing jnp-reference
and Pallas backends inside one compiled plan.

Typical use::

    plan = map_network(graph)                     # model-predicted plan
    record = autotune_graph(graph, plan)          # measure on this device
    record.save("tuning.json")
    run = compile_plan(graph, plan, tuning=record)  # measured bindings

Records are keyed by ``(conv signature, batch bucket)``: bindings do not
rank identically at batch 1 and batch 8, so the serving tier tunes once per
batch bucket (``autotune_buckets``) and each bucket's compiled executable
consumes the winner measured *at that batch size*
(``lower_plan(..., tuning=record, batch=bucket)``). Signature keys still
transfer between graphs that share conv shapes, and re-tuning is
incremental (``skip_known``).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.algorithms import Algorithm, AlgoFamily, menu_for
from repro.core.cost_model import ALL_DATAFLOWS, Dataflow
from repro.core.graph import ConvMeta, Graph
from repro.core.mapper import ConvLowering, ExecutionPlan

# "lax" = XLA's native spatial conv — algorithm-independent, so it
# contributes one candidate per layer; it is the strongest conv the host
# XLA can emit and routinely wins on CPU (on TPU the Pallas sweeps fight
# back — that's the point of measuring).
BACKENDS = ("lax", "reference", "pallas")

# Version 2: entries are keyed by (conv signature, batch bucket) —
# "sig@bN" — instead of the bare signature; version-1 blobs are migrated
# on load (their entries become bucket-1 entries, or bucket meta["batch"]
# when the record was measured at a batch size).
RECORD_VERSION = 2


def conv_key(conv: ConvMeta) -> str:
    """Shape signature identifying a conv layer for tuning purposes: two
    layers with the same signature induce identical GEMMs, so they share a
    measured winner."""
    return (f"c{conv.c_in}x{conv.c_out}_h{conv.h1}x{conv.h2}"
            f"_k{conv.k1}x{conv.k2}_s{conv.stride}_{conv.pad}")


def record_key(conv: ConvMeta, batch: Optional[int] = None,
               precision: str = "bf16") -> str:
    """Full tuning-record key: conv signature plus the batch bucket the
    binding was measured at. ``batch=None`` (the single-image setting)
    records as bucket 1 — a batch-1 tick and a single image induce the
    same per-image GEMMs. Non-bf16 measurements append a ``#<precision>``
    suffix ("sig@bN#int8"): bindings do not rank identically across
    precisions (int8 moves half the bytes), so int8 layers only ever
    adopt bindings measured at int8 — bf16 keys are unchanged, keeping
    old records valid."""
    key = f"{conv_key(conv)}@b{int(batch or 1)}"
    return key if precision == "bf16" else f"{key}#{precision}"


def parse_record_key(key: str) -> Tuple[str, int, str]:
    """Inverse of ``record_key``: "sig@bN[#prec]" → (sig, N, prec)."""
    base, _, prec = key.partition("#")
    sig, _, bucket = base.rpartition("@b")
    if not sig or not bucket.isdigit():
        raise ValueError(f"unparseable record key {key!r}")
    return sig, int(bucket), prec or "bf16"


def algo_from_key(key: str) -> Algorithm:
    """Inverse of ``Algorithm.key`` ("im2col", "winograd(F2x3)", ...)."""
    for fam in AlgoFamily:
        if key == fam.value:
            return Algorithm(fam)
    if key.startswith("winograd(F"):
        m, r = key[len("winograd(F"):-1].split("x")
        return Algorithm(AlgoFamily.WINOGRAD, m=int(m), r=int(r))
    raise ValueError(f"unparseable algorithm key {key!r}")


@dataclasses.dataclass(frozen=True)
class Binding:
    """One candidate configuration of the overlay for a layer."""
    algo_key: str
    dataflow: str                  # Dataflow name: NS | WS | IS
    p1: int
    p2: int
    backend: str                   # reference | pallas

    @property
    def algo(self) -> Algorithm:
        return algo_from_key(self.algo_key)

    def label(self) -> str:
        return (f"{self.algo_key}|{self.dataflow}|{self.p1}x{self.p2}"
                f"|{self.backend}")


@dataclasses.dataclass
class LayerTuning:
    """Measured winner for one (conv signature, batch bucket)."""
    binding: Binding
    measured_s: float
    # (label, seconds) for every candidate tried — kept for analysis.
    candidates: List[Tuple[str, float]]
    # Batch bucket the measurement ran at (1 = single image).
    batch: int = 1
    # Precision the candidates were measured at ("bf16" | "int8").
    precision: str = "bf16"


class TuningRecord:
    """(conv signature, batch bucket) → measured best binding; JSON
    round-trippable. Entry keys are ``record_key`` strings ("sig@bN")."""

    def __init__(self, entries: Optional[Dict[str, LayerTuning]] = None,
                 meta: Optional[Dict[str, object]] = None) -> None:
        self.entries: Dict[str, LayerTuning] = dict(entries or {})
        self.meta: Dict[str, object] = dict(meta or {})

    # ------------------------------------------------------------ lookup
    def buckets_for(self, conv: ConvMeta,
                    precision: str = "bf16") -> List[int]:
        """Batch buckets this record has measured for ``conv`` at the
        given precision, ascending."""
        sig = conv_key(conv)
        out = []
        for key in self.entries:
            k_sig, bucket, prec = parse_record_key(key)
            if k_sig == sig and prec == precision:
                out.append(bucket)
        return sorted(out)

    def lookup(self, conv: ConvMeta, batch: Optional[int] = None,
               precision: str = "bf16") -> Optional[LayerTuning]:
        """The entry measured at ``batch`` (bucket-matched). Without an
        exact bucket match, fall back to the largest tuned bucket below the
        requested one (closest smaller workload), else the smallest above —
        so a batch-1-only record still serves every bucket, just without
        per-bucket specialization. Entries never cross precisions: an int8
        layer with no int8 measurement runs its model-predicted binding."""
        want = int(batch or 1)
        hit = self.entries.get(record_key(conv, want, precision))
        if hit is not None:
            return hit
        buckets = self.buckets_for(conv, precision)
        if not buckets:
            return None
        below = [b for b in buckets if b < want]
        pick = below[-1] if below else buckets[0]
        return self.entries[record_key(conv, pick, precision)]

    def lowering_for(self, conv: ConvMeta, batch: Optional[int] = None,
                     precision: str = "bf16") -> Optional[ConvLowering]:
        """The measured binding as a ConvLowering fragment (epilogue and
        precision/scales are the caller's concern — tuning only overrides
        the execution binding)."""
        hit = self.lookup(conv, batch, precision)
        if hit is None:
            return None
        b = hit.binding
        return ConvLowering(b.algo, Dataflow[b.dataflow], b.p1, b.p2,
                            backend=b.backend)

    # ------------------------------------------------------------ persist
    def to_json(self) -> Dict[str, object]:
        return {
            "version": RECORD_VERSION,
            "meta": self.meta,
            "entries": {
                key: {
                    "binding": dataclasses.asdict(t.binding),
                    "measured_s": t.measured_s,
                    "candidates": [[lbl, s] for lbl, s in t.candidates],
                    "batch": t.batch,
                    "precision": t.precision,
                }
                for key, t in self.entries.items()
            },
        }

    @classmethod
    def from_json(cls, blob: Dict[str, object]) -> "TuningRecord":
        version = blob.get("version")
        if version not in (1, RECORD_VERSION):
            raise ValueError(f"tuning record version {version} "
                             f"!= {RECORD_VERSION}")
        meta = dict(blob.get("meta", {}))                  # type: ignore
        # v1 records were keyed by bare signature; the whole record was
        # measured at one batch size (meta["batch"], None = single image).
        v1_bucket = int(meta.get("batch") or 1) if version == 1 else None
        entries = {}
        for key, ent in blob.get("entries", {}).items():   # type: ignore
            if version == 1:
                key = f"{key}@b{v1_bucket}"
                bucket = v1_bucket
                precision = "bf16"
            else:
                bucket = int(ent.get("batch", parse_record_key(key)[1]))
                precision = str(ent.get("precision",
                                        parse_record_key(key)[2]))
            entries[key] = LayerTuning(
                binding=Binding(**ent["binding"]),
                measured_s=float(ent["measured_s"]),
                candidates=[(lbl, float(s)) for lbl, s in ent["candidates"]],
                batch=bucket,
                precision=precision,
            )
        return cls(entries, meta)

    def save(self, path) -> None:
        Path(path).write_text(json.dumps(self.to_json(), indent=2))

    @classmethod
    def load(cls, path) -> "TuningRecord":
        return cls.from_json(json.loads(Path(path).read_text()))

    # -------------------------------------------------------------- merge
    def merge(self, other: "TuningRecord") -> int:
        """Fold ``other``'s entries into this record, keeping existing
        entries on key conflicts (this record's measurements are the
        incumbents — remeasure and overwrite explicitly if you want the
        challenger). Because keys are (conv signature, bucket) — never
        graph identity — this is how tuning transfers across models: a
        fleet can pool the records of every tenant and each engine sees
        the union of all measured winners. Returns the number of entries
        adopted. ``meta`` keys absent here are copied over too."""
        adopted = 0
        for key, tuned in other.entries.items():
            if key not in self.entries:
                self.entries[key] = tuned
                adopted += 1
        for k, v in other.meta.items():
            if k == "buckets":
                mine = set(self.meta.get("buckets", []))
                self.meta["buckets"] = sorted(mine | set(v))
            else:
                self.meta.setdefault(k, v)
        return adopted


def refresh_from_service(record: "TuningRecord", graph: Graph,
                         service_emas: Dict[int, float], *,
                         precisions: Optional[Dict[int, str]] = None,
                         min_improvement: float = 0.05
                         ) -> Dict[int, float]:
    """Live-refresh a record's measured costs from serving-tier EMAs.

    The serving engine keeps one service-time EMA per batch bucket (the
    measured wall time of a tick); the record predicts the same tick as
    the sum of its per-layer measured winners. When the live EMA diverges
    from that prediction by more than ``min_improvement`` (the autotuner's
    5% hysteresis — sub-hysteresis noise never churns the record), every
    ``(signature, bucket)`` entry measured at that exact bucket is
    rescaled by the live/recorded ratio — ``measured_s`` and the stored
    candidate times alike — so consumers of recorded costs (re-tune
    baselines, operator dashboards, the hot-swap supervisor's decision
    inputs) see them in live terms. Bindings are untouched: a uniform
    per-bucket scale cannot re-rank candidates measured together; flipping
    a winner requires a real re-measurement (``tune_layer``).

    ``precisions`` (conv node id → "bf16"|"int8") mirrors the deployed
    plan so the prediction sums the entries the engine actually lowers
    with. Returns the applied scale per bucket (empty = nothing diverged
    or nothing measured); applied scales accumulate in
    ``record.meta["live_refresh"]`` with the tick counts they came from.
    """
    precisions = precisions or {}
    applied: Dict[int, float] = {}
    for bucket, ema in sorted(service_emas.items()):
        if ema is None or ema <= 0.0:
            continue
        expected = 0.0
        exact_keys = []
        for node in graph.conv_nodes():
            prec = precisions.get(node.id, "bf16")
            hit = record.lookup(node.conv, batch=bucket, precision=prec)
            if hit is None:
                continue
            expected += hit.measured_s
            key = record_key(node.conv, bucket, prec)
            if key in record.entries:
                exact_keys.append(key)
        if expected <= 0.0 or not exact_keys:
            continue
        ratio = float(ema) / expected
        if abs(ratio - 1.0) <= min_improvement:
            continue                      # within hysteresis: hold steady
        for key in set(exact_keys):
            ent = record.entries[key]
            ent.measured_s *= ratio
            ent.candidates = [(lbl, s * ratio) for lbl, s in ent.candidates]
        applied[bucket] = ratio
    if applied:
        log = dict(record.meta.get("live_refresh", {}))
        for bucket, ratio in applied.items():
            log[str(bucket)] = round(
                float(log.get(str(bucket), 1.0)) * ratio, 6)
        record.meta["live_refresh"] = log
    return applied


# ---------------------------------------------------------------------------
# Candidate generation.
# ---------------------------------------------------------------------------

def candidate_bindings(conv: ConvMeta,
                       p1p2: Sequence[Tuple[int, int]] = ((128, 128),),
                       dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
                       backends: Sequence[str] = BACKENDS,
                       menu: Optional[Sequence[Algorithm]] = None
                       ) -> List[Binding]:
    """The search space for one layer.

    The reference backend ignores dataflow/(p1, p2) — the binding only
    shapes the Pallas schedule — so it contributes one candidate per
    applicable algorithm; the Pallas backend sweeps the full cross product;
    the lax backend ignores the algorithm too (XLA picks its own conv
    strategy) and contributes exactly one candidate.
    """
    algos = menu_for(conv, list(menu) if menu is not None else None)
    out: List[Binding] = []
    if "lax" in backends:
        out.append(Binding(algos[0].key, Dataflow.NS.name, 128, 128, "lax"))
    for algo in algos:
        if "reference" in backends:
            out.append(Binding(algo.key, Dataflow.NS.name, 128, 128,
                               "reference"))
        if "pallas" in backends:
            for df in dataflows:
                for (p1, p2) in p1p2:
                    out.append(Binding(algo.key, df.name, p1, p2, "pallas"))
    return out


# ---------------------------------------------------------------------------
# Measurement.
# ---------------------------------------------------------------------------

def benchmark_binding(conv: ConvMeta, binding: Binding, *,
                      reps: int = 3, warmup: int = 1,
                      interpret: Optional[bool] = None,
                      batch: Optional[int] = None,
                      precision: str = "bf16",
                      seed: int = 0) -> float:
    """Wall-clock one overlay call for ``conv`` under ``binding`` on the
    actual device; returns the best (min) of ``reps`` timed runs — min is
    the standard noise-robust estimator for microbenchmarks.

    The call is jitted whole, exactly as it appears inside a compiled plan,
    so reference and Pallas backends are timed on equal footing. ``batch``
    measures the batched overlay path (B, H, W, C) — bindings do not rank
    identically at batch 1 and batch 8, so tune at the batch you serve.
    ``precision="int8"`` measures the quantized overlay path (a synthetic
    unit activation scale — timing is scale-independent).
    """
    from repro.cnn import overlay       # deferred: overlay imports kernels

    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    shape = (conv.h1, conv.h2, conv.c_in)
    if batch is not None:
        shape = (batch,) + shape
    x = jax.random.normal(kx, shape, jnp.float32)
    w = jax.random.normal(kw, (conv.k1, conv.k2, conv.c_in, conv.c_out),
                          jnp.float32) / (conv.k1 * conv.k2 * conv.c_in) ** .5
    pad = "SAME" if conv.pad == "same" else "VALID"
    quant_kw = {} if precision == "bf16" else dict(
        precision=precision, in_scale=3.0 / 127.0)

    @jax.jit
    def run(x, w):
        return overlay.apply_conv(
            x, w, binding.algo, Dataflow[binding.dataflow],
            binding.p1, binding.p2, stride=conv.stride, padding=pad,
            backend=binding.backend, interpret=interpret,
            epilogue="relu", **quant_kw)

    for _ in range(max(1, warmup)):
        jax.block_until_ready(run(x, w))    # compile + warm caches
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(run(x, w))
        best = min(best, time.perf_counter() - t0)
    return best


def tune_layer(conv: ConvMeta, *,
               p1p2: Sequence[Tuple[int, int]] = ((128, 128),),
               dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
               backends: Sequence[str] = BACKENDS,
               menu: Optional[Sequence[Algorithm]] = None,
               reps: int = 3, interpret: Optional[bool] = None,
               batch: Optional[int] = None,
               precision: str = "bf16",
               baseline: Optional[Binding] = None,
               min_improvement: float = 0.05) -> LayerTuning:
    """Benchmark every candidate binding for one conv; return the winner.

    With a ``baseline`` (the plan's own binding), a challenger must beat it
    by more than ``min_improvement`` (fractional) or the baseline is kept:
    at μs layer scales dispatch jitter can crown a spurious winner, and the
    hysteresis guarantees a tuned plan never regresses below the
    model-predicted binding by chasing noise. ``precision="int8"`` measures
    the quantized path; Winograd candidates are dropped (the overlay
    rejects int8 Winograd).
    """
    results: List[Tuple[str, float]] = []
    base_s: Optional[float] = None
    if baseline is not None:
        base_s = benchmark_binding(conv, baseline, reps=reps,
                                   interpret=interpret, batch=batch,
                                   precision=precision)
        results.append((baseline.label(), base_s))
    best: Optional[Tuple[Binding, float]] = None
    for cand in candidate_bindings(conv, p1p2, dataflows, backends, menu):
        if baseline is not None and cand == baseline:
            continue
        if precision == "int8" \
                and cand.algo.family is AlgoFamily.WINOGRAD:
            continue
        s = benchmark_binding(conv, cand, reps=reps, interpret=interpret,
                              batch=batch, precision=precision)
        results.append((cand.label(), s))
        if best is None or s < best[1]:
            best = (cand, s)
    if best is None or (base_s is not None
                        and best[1] >= base_s * (1 - min_improvement)):
        assert baseline is not None and base_s is not None
        best = (baseline, base_s)
    return LayerTuning(binding=best[0], measured_s=best[1],
                       candidates=results, batch=int(batch or 1),
                       precision=precision)


def signature_coverage(graph: Graph, record: TuningRecord,
                       buckets: Sequence[int] = (1,)
                       ) -> Dict[str, List[str]]:
    """How well ``record`` covers ``graph``'s unique conv signatures at
    the given batch ``buckets`` — the cross-model reuse report: before
    registering a new tenant, this says which of its layers ride existing
    measured winners and which would fall back or run untuned.

    Returns record keys ("sig@bN") partitioned into ``exact`` (entry
    measured at that bucket), ``fallback`` (served by a neighboring
    bucket's entry via ``lookup``'s bucket fallback) and ``missing`` (no
    entry for the signature at all — the model's untuned layers)."""
    out: Dict[str, List[str]] = {"exact": [], "fallback": [], "missing": []}
    seen = set()
    for node in graph.conv_nodes():
        for bucket in buckets:
            key = record_key(node.conv, bucket)
            if key in seen:
                continue
            seen.add(key)
            if key in record.entries:
                out["exact"].append(key)
            elif record.lookup(node.conv, bucket) is not None:
                out["fallback"].append(key)
            else:
                out["missing"].append(key)
    for keys in out.values():
        keys.sort()
    return out


def autotune_graph(graph: Graph, plan: Optional[ExecutionPlan] = None, *,
                   p1p2: Optional[Sequence[Tuple[int, int]]] = None,
                   dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
                   backends: Sequence[str] = BACKENDS,
                   menu: Optional[Sequence[Algorithm]] = None,
                   reps: int = 3, interpret: Optional[bool] = None,
                   batch: Optional[int] = None,
                   precision: str = "bf16",
                   record: Optional[TuningRecord] = None,
                   skip_known: bool = True,
                   baseline_backend: str = "reference",
                   min_improvement: float = 0.05,
                   verbose: bool = False) -> TuningRecord:
    """Measure every *unique* conv signature in ``graph`` and record the
    fastest binding for each.

    ``plan`` (if given) plays two roles: it seeds the (p1, p2) candidate
    list with the DSE's Eq. 9 choice, and its per-layer binding (under
    ``baseline_backend``) becomes the hysteresis baseline a challenger must
    beat by ``min_improvement`` — so a tuned plan can only diverge from the
    model's prediction where the device measurably disagrees. Passing an
    existing ``record`` makes tuning incremental: (signature, bucket) pairs
    already recorded are skipped (``skip_known=True``). Entries land under
    batch bucket ``batch`` (None → bucket 1, measured on a single image).
    """
    if p1p2 is None:
        p1p2 = [(128, 128)]
        if plan is not None and (plan.p1, plan.p2) not in p1p2:
            p1p2.append((plan.p1, plan.p2))
    record = record if record is not None else TuningRecord()
    record.meta.setdefault("backend", jax.default_backend())
    record.meta.setdefault("reps", reps)
    record.meta.setdefault("min_improvement", min_improvement)
    bucket = int(batch or 1)
    buckets = set(record.meta.get("buckets", []))
    buckets.add(bucket)
    record.meta["buckets"] = sorted(buckets)

    seen: Dict[str, Tuple[ConvMeta, Optional[Binding]]] = {}
    for node in graph.conv_nodes():
        key = record_key(node.conv, bucket, precision)
        if key in seen:
            continue
        baseline = None
        if plan is not None and node.id in plan.assignment:
            algo = plan.assignment[node.id]
            if not (precision == "int8"
                    and algo.family is AlgoFamily.WINOGRAD):
                baseline = Binding(algo.key, plan.dataflows[node.id].name,
                                   plan.p1, plan.p2, baseline_backend)
        seen[key] = (node.conv, baseline)

    for key, (conv, baseline) in seen.items():
        if skip_known and key in record.entries:
            continue
        t0 = time.perf_counter()
        tuned = tune_layer(conv, p1p2=p1p2, dataflows=dataflows,
                           backends=backends, menu=menu, reps=reps,
                           interpret=interpret, batch=batch,
                           precision=precision, baseline=baseline,
                           min_improvement=min_improvement)
        record.entries[key] = tuned
        if verbose:
            print(f"autotune {key}: {tuned.binding.label()} "
                  f"{tuned.measured_s * 1e6:.0f}us "
                  f"({len(tuned.candidates)} candidates, "
                  f"{time.perf_counter() - t0:.1f}s)")
    return record


def tune_elision(graph: Graph, plan: Optional[ExecutionPlan] = None, *,
                 params=None, batch: Optional[int] = None,
                 default_algo: Optional[Algorithm] = None,
                 epilogue: str = "relu",
                 tuning: Optional[TuningRecord] = None,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 reps: int = 3, min_improvement: float = 0.05,
                 record: Optional[TuningRecord] = None,
                 verbose: bool = False
                 ) -> Dict[Tuple[int, int], bool]:
    """Measure per-edge layout-transition elision on this device.

    The lowering elides every transition the plan's store formats allow;
    this closes the measurement loop the same way ``tune_layer`` does for
    bindings: starting from the all-elided compiled plan, each elided edge
    is re-compiled with its transition forced back to the NHWC round trip,
    and the override is kept only when it beats the all-elided baseline by
    ``min_improvement`` (hysteresis — elision toggles are never flipped on
    noise). Returns the ``elide_overrides`` dict for
    ``lower_plan``/``compile_plan``; with a ``record``, the overrides are
    also stored under ``record.meta["elision_overrides"]`` (JSON-safe
    ``[[src, dst, flag], ...]``).
    """
    from repro.cnn.executor import compile_plan, init_params  # deferred
    from repro.core.algorithms import IM2COL
    from repro.core.mapper import lower_plan

    default_algo = IM2COL if default_algo is None else default_algo
    if params is None:
        params = init_params(graph, jax.random.PRNGKey(0))
    shape = tuple(graph.nodes[graph.source()].attrs["out_shape"])
    if batch is not None:
        shape = (batch,) + shape
    x = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)

    def measure(overrides: Optional[Dict[Tuple[int, int], bool]]) -> float:
        run = compile_plan(graph, plan, default_algo=default_algo,
                           use_pallas=use_pallas, interpret=interpret,
                           epilogue=epilogue, tuning=tuning,
                           tuning_batch=batch, elide_overrides=overrides)
        jax.block_until_ready(run(params, x))       # compile + warm
        best = float("inf")
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            jax.block_until_ready(run(params, x))
            best = min(best, time.perf_counter() - t0)
        return best

    lowered = lower_plan(graph, plan, default_algo, epilogue=epilogue,
                         tuning=tuning, batch=batch)
    base_s = measure(None)
    overrides: Dict[Tuple[int, int], bool] = {}
    for edge in lowered.elided_edges:
        s = measure({edge: False})
        if s < base_s * (1 - min_improvement):
            overrides[edge] = False
        if verbose:
            kept = "round-trip" if overrides.get(edge) is False else "elided"
            print(f"tune_elision {edge}: {s * 1e6:.0f}us vs "
                  f"{base_s * 1e6:.0f}us elided → {kept}")
    if record is not None:
        record.meta["elision_overrides"] = \
            [[src, dst, flag] for (src, dst), flag in sorted(overrides.items())]
    return overrides


def elision_overrides_from_meta(record: TuningRecord
                                ) -> Dict[Tuple[int, int], bool]:
    """Inverse of the ``tune_elision(record=...)`` meta stash."""
    raw = record.meta.get("elision_overrides", [])
    return {(int(src), int(dst)): bool(flag) for src, dst, flag in raw}


def autotune_buckets(graph: Graph, plan: Optional[ExecutionPlan] = None, *,
                     buckets: Sequence[int] = (1, 2, 4, 8),
                     record: Optional[TuningRecord] = None,
                     verbose: bool = False,
                     **kwargs) -> TuningRecord:
    """Tune every unique conv signature at every serving batch bucket.

    One record holds all buckets; ``lower_plan(..., tuning=record,
    batch=bucket)`` then binds each bucket's executable to the winner
    measured at that batch size (the serving engine compiles one program
    per bucket — see ``serving.cnn_engine``). Bucket 1 is measured on a
    single image, matching the paper's no-batch low-latency setting;
    larger buckets measure the batched (B, H, W, C) overlay path.

    ``kwargs`` forward to ``autotune_graph`` (backends, reps, dataflows,
    interpret, ...); tuning stays incremental across calls via ``record``.
    """
    record = record if record is not None else TuningRecord()
    for bucket in sorted(set(int(b) for b in buckets)):
        record = autotune_graph(graph, plan,
                                batch=None if bucket == 1 else bucket,
                                record=record, verbose=verbose, **kwargs)
    return record
