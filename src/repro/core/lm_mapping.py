"""DYNAMAP generalized to transformer stacks (DESIGN.md §3).

The paper's machinery — per-node implementation choice + pairwise
transition costs on a series-parallel graph, solved optimally by PBQP — is
architecture-agnostic. Here the "algorithms" are per-layer execution
strategies (attention sharding mode × MoE dispatch algorithm), node costs
are the measured/probed per-layer roofline terms, and transition costs are
the resharding collectives incurred when adjacent layers disagree on the
activation layout (a layout flip between sequence-sharded and head-sharded
activations costs one all-to-all of the residual stream).

This is what drives strategy selection in §Perf: e.g. the measured
command-r-35b numbers (seq: coll 18.0 s / mem 17.0 s; heads: coll 14.1 s /
mem 36.3 s per step) let the PBQP decide per layer — and, because the
transition cost punishes mixing, it correctly returns a homogeneous 'seq'
assignment rather than a greedy per-term mix.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import TPUSpec, V5E
from repro.core.pbqp import PBQP, SolveResult, solve_series_parallel


@dataclasses.dataclass(frozen=True)
class LayerStrategy:
    """One executable strategy for a transformer layer."""
    name: str                      # e.g. "seq", "heads", "seq+sorted_moe"
    compute_s: float               # per-layer roofline terms (seconds)
    memory_s: float
    collective_s: float
    layout: str                    # activation layout it leaves behind

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def transition_cost_s(src_layout: str, dst_layout: str,
                      resid_bytes_per_chip: float,
                      spec: TPUSpec = V5E) -> float:
    """Resharding the (B, S, d) residual stream between layouts = one
    all-to-all of the per-chip shard over the ICI."""
    if src_layout == dst_layout:
        return 0.0
    return resid_bytes_per_chip / spec.ici_bw


def map_layer_strategies(n_layers: int,
                         strategies: Sequence[LayerStrategy],
                         resid_bytes_per_chip: float,
                         spec: TPUSpec = V5E) -> Tuple[Dict[int, str],
                                                       SolveResult]:
    """Optimal per-layer strategy assignment for a chain-of-layers model.

    A transformer stack is the simplest series-parallel graph (a chain), so
    Theorem 4.1 applies directly and the solve is exact in O(L·d²).
    """
    p = PBQP()
    costs = [s.total_s for s in strategies]
    for i in range(n_layers):
        p.add_node(i, costs)
    d = len(strategies)
    t = np.zeros((d, d))
    for a in range(d):
        for b in range(d):
            t[a, b] = transition_cost_s(strategies[a].layout,
                                        strategies[b].layout,
                                        resid_bytes_per_chip, spec)
    for i in range(n_layers - 1):
        p.add_edge(i, i + 1, t)
    res = solve_series_parallel(p)
    assignment = {i: strategies[res.assignment[i]].name
                  for i in range(n_layers)}
    return assignment, res


def strategies_from_probes(probes: Dict[str, Dict[str, float]],
                           n_layers: int,
                           layouts: Optional[Dict[str, str]] = None
                           ) -> List[LayerStrategy]:
    """Build per-layer strategies from whole-model probe terms (seconds per
    step, as produced by launch.roofline) by dividing through the layer
    count."""
    layouts = layouts or {}
    out = []
    for name, terms in probes.items():
        out.append(LayerStrategy(
            name=name,
            compute_s=terms["compute_s"] / n_layers,
            memory_s=terms["memory_s"] / n_layers,
            collective_s=terms["collective_s"] / n_layers,
            layout=layouts.get(name, name)))
    return out
