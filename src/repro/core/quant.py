"""Calibration and the accuracy gate for the int8 overlay path.

The mapper prices int8 algorithm replicas purely by throughput
(``V5E_INT8``: 2x the MACs, half the bytes); whether a layer can *afford*
int8 numerically is a property of its weights and activations, not its
cost. This module closes that loop before a plan is finalized:

* ``calibrate_act_scales`` — one eager f32 walk over sample inputs,
  recording each conv layer's input abs-max through the executor's
  ``conv_tap`` hook; the per-tensor activation scale is ``amax / 127``
  (symmetric, zero-point 0 — matching ``kernels.common.quantize``).
* ``layer_errors`` — per-layer quantization error measured in isolation:
  each candidate layer runs once at f32 and once through the int8 path on
  its OWN f32 reference input (errors never compound across layers), and
  the relative max error ``max|int8 - f32| / max|f32|`` is reported.
* ``plan_mixed_precision`` — the gate: solve the precision-aware PBQP,
  demote every int8 layer whose isolated error exceeds ``tol`` via
  ``map_network(force_bf16=...)``, and re-solve to a fixpoint (a demotion
  changes boundary costs, which can flip a neighbor's precision). Demoted
  layers' choice vectors are identical to the unquantized build, so they
  lower bitwise-identically to the all-bf16 plan.

Error isolation is what makes the gate cheap and monotone: a layer's
error is independent of every other layer's precision, so it is measured
once and the demotion loop converges without re-measuring.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.cost_model import TPUSpec, V5E, V5E_INT8
from repro.core.graph import Graph
from repro.core.mapper import ExecutionPlan, HardwareChoice, map_network
from repro.kernels.common import INT8_MAX, _SCALE_EPS

Params = Dict[int, Dict[str, jax.Array]]


def _capture_conv_inputs(graph: Graph, params: Params, x: jax.Array
                         ) -> Dict[int, jax.Array]:
    """One eager f32 reference walk; returns each conv node's NHWC input
    exactly as the executor would feed it (post-pool, post-concat)."""
    from repro.cnn.executor import forward  # deferred: executor imports core

    captured: Dict[int, jax.Array] = {}

    def tap(nid: int, xin: jax.Array) -> None:
        captured[nid] = xin

    forward(graph, params, x, plan=None, conv_tap=tap)
    return captured


def calibrate_act_scales(graph: Graph, params: Params,
                         samples: jax.Array) -> Dict[int, float]:
    """Per-tensor activation scales from sample batches.

    ``samples``: one image (H, W, C) or a calibration batch (N, H, W, C).
    Runs the plain f32 reference walk (the scale of a layer's input does
    not depend on the plan — every plan computes the same function) and
    records each conv input's abs-max; the returned ``{nid: amax / 127}``
    map feeds ``lower_plan(act_scales=...)`` / ``compile_plan`` and is a
    static Python-float per layer, so it enters the executable cache key
    rather than the traced program's inputs."""
    captured = _capture_conv_inputs(graph, params, jnp.asarray(samples))
    return {
        nid: max(float(jnp.max(jnp.abs(xin))), _SCALE_EPS) / INT8_MAX
        for nid, xin in captured.items()
    }


def layer_errors(graph: Graph, params: Params, x: jax.Array,
                 act_scales: Dict[int, float],
                 nodes: Optional[Sequence[int]] = None) -> Dict[int, float]:
    """Isolated per-layer int8 output error vs the f32 reference.

    For each conv in ``nodes`` (default: every conv with a calibrated
    scale), the layer runs on its f32 reference input twice — plain f32
    and through the overlay's int8 path (fake-quant emulation on the lax
    backend: bit-identical quantization error to the Pallas kernels,
    without interpret-mode cost) — and reports
    ``mean|int8 - f32| / median|f32|``: mean error against the *typical*
    (median) output magnitude. The robust denominator is deliberate — an
    activation outlier blows up a max- or mean-based denominator exactly
    as much as the error it causes, hiding the layer the gate most needs
    to demote (per-tensor scaling sacrifices every ordinary activation to
    represent the outlier). Epilogue-free on purpose: bias adds a
    quantization-independent offset and ReLU only clips, so the raw conv
    output is the conservative (largest-error) measurement point."""
    from repro.cnn import overlay               # deferred
    from repro.core.algorithms import IM2COL

    captured = _capture_conv_inputs(graph, params, jnp.asarray(x))
    want = list(nodes) if nodes is not None else sorted(
        nid for nid in captured if nid in act_scales)
    errors: Dict[int, float] = {}
    for nid in want:
        node = graph.nodes[nid]
        m = node.conv
        pad = "SAME" if m.pad == "same" else "VALID"
        xin, w = captured[nid], params[nid]["w"]
        ref = overlay.apply_conv(xin, w, IM2COL, stride=m.stride,
                                 padding=pad, backend="lax")
        got = overlay.apply_conv(xin, w, IM2COL, stride=m.stride,
                                 padding=pad, backend="lax",
                                 precision="int8",
                                 in_scale=act_scales[nid])
        errors[nid] = float(jnp.mean(jnp.abs(got - ref))
                            / (jnp.median(jnp.abs(ref)) + _SCALE_EPS))
    return errors


@dataclasses.dataclass
class QuantReport:
    """Outcome of the mixed-precision gate: the finalized plan plus
    everything needed to compile and audit it."""
    plan: ExecutionPlan
    act_scales: Dict[int, float]       # conv node -> per-tensor input scale
    errors: Dict[int, float]           # isolated error of every measured node
    demoted: List[int]                 # nodes the gate forced back to bf16
    tol: float
    rounds: int                        # PBQP solves until fixpoint

    @property
    def precision_mix(self) -> Dict[str, int]:
        """{"int8": n, "bf16": m} over the plan's conv layers."""
        mix = {"int8": 0, "bf16": 0}
        for prec in self.plan.precisions.values():
            mix[prec] = mix.get(prec, 0) + 1
        return mix


def plan_mixed_precision(graph: Graph, params: Params, samples: jax.Array,
                         *, tol: float = 0.05,
                         spec: TPUSpec = V5E,
                         int8_spec: TPUSpec = V5E_INT8,
                         hw: Optional[HardwareChoice] = None,
                         menu=None, solver: str = "sp",
                         implicit_im2col: bool = False,
                         use_on_chip: bool = True,
                         max_rounds: int = 8,
                         verbose: bool = False) -> QuantReport:
    """Solve a precision-aware plan and demote inaccurate layers to bf16.

    Calibrates activation scales on ``samples``, measures every conv's
    isolated int8 error once, then iterates: solve the joint PBQP
    (``map_network(quantize=True, force_bf16=demoted)``), demote any int8
    layer whose error exceeds ``tol``, re-solve. Converges in at most
    ``max_rounds`` (each round strictly grows the demoted set, which is
    bounded by the conv count). Returns the final plan + audit trail; feed
    ``report.plan`` and ``report.act_scales`` to ``compile_plan``."""
    samples = jnp.asarray(samples)
    act_scales = calibrate_act_scales(graph, params, samples)
    errors = layer_errors(graph, params, samples, act_scales)
    demoted: set = set()
    rounds = 0
    while True:
        rounds += 1
        plan = map_network(graph, menu=menu, spec=spec, hw=hw,
                           solver=solver, quantize=True,
                           int8_spec=int8_spec,
                           implicit_im2col=implicit_im2col,
                           use_on_chip=use_on_chip,
                           force_bf16=sorted(demoted))
        offenders = sorted(
            nid for nid, prec in plan.precisions.items()
            if prec == "int8" and errors.get(nid, 0.0) > tol)
        if verbose and offenders:
            print(f"quant gate round {rounds}: demoting {offenders} "
                  f"(err > {tol})")
        if not offenders or rounds >= max_rounds:
            break
        demoted.update(offenders)
    return QuantReport(plan=plan, act_scales=act_scales, errors=errors,
                       demoted=sorted(demoted), tol=tol, rounds=rounds)
