"""DYNAMAP core: graph IR, cost model, PBQP mapping, DSE (paper §3-§5)."""
from repro.core.algorithms import (Algorithm, AlgoFamily, DEFAULT_MENU,
                                   IM2COL, KN2ROW, Layout, PAPER_MENU,
                                   WINO_2_3, WINO_4_3, menu_for)
from repro.core.cost_model import (ALL_DATAFLOWS, Dataflow, NodeCost,
                                   Roofline, TPUSpec, TransitionCalibration,
                                   V5E, V5E_INT8, best_dataflow,
                                   eff_bandwidth, fits_on_chip, gemm_steps,
                                   gemm_utilization, node_cost, roofline,
                                   transition_cost)
from repro.core.dse import (HardwareChoice, candidate_shapes,
                            identify_parameters, vmem_working_set)
from repro.core.graph import (ConvMeta, Graph, LayerKind, LayerNode,
                              is_series_parallel)
from repro.core.autotune import (Binding, LayerTuning, TuningRecord,
                                 autotune_graph, benchmark_binding,
                                 candidate_bindings, conv_key,
                                 elision_overrides_from_meta, tune_elision,
                                 tune_layer)
from repro.core.layouts import LayoutSpec, consumer_spec, invertible
from repro.core.mapper import (ConvLowering, CostGraphBuilder,
                               ExecutionPlan, LayoutTransition,
                               LoweredProgram, evaluate_fixed_mapping,
                               lower_plan, map_network, transition_report)
from repro.core.pbqp import (PBQP, SolveResult, solve_brute_force,
                             solve_greedy_incremental, solve_greedy_node,
                             solve_series_parallel)
