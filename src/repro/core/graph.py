"""CNN/DAG graph IR for DYNAMAP.

The paper (§4) models a CNN as G = (V, E, C_v, T_e): vertices are layers,
edges are producer→consumer orderings, C_v are per-vertex cost vectors (one
entry per algorithm-dataflow pair) and T_e are transition-cost matrices.

This module provides the *structural* IR: typed layer nodes, edges, and the
series-parallel machinery (Definition 1, operations (1) and (2)) used both by
the PBQP solver and by the model builders in ``repro.cnn.models``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


class LayerKind(enum.Enum):
    INPUT = "input"
    CONV = "conv"
    POOL_MAX = "pool_max"
    POOL_AVG = "pool_avg"
    CONCAT = "concat"
    FC = "fc"
    ADD = "add"          # residual add (ResNet)
    GLOBAL_POOL = "global_pool"
    SOFTMAX = "softmax"
    OUTPUT = "output"


@dataclasses.dataclass(frozen=True)
class ConvMeta:
    """CONV layer meta data exactly as §2.1 defines it.

    Each CONV layer has C_in (C_out) input (output) channels, each channel a
    H1×H2 (O1×O2) feature map; weights are C_in×C_out kernels of size K1×K2.
    """
    c_in: int
    c_out: int
    h1: int
    h2: int
    k1: int
    k2: int
    stride: int = 1
    pad: str = "same"  # "same" | "valid"

    @property
    def o1(self) -> int:
        if self.pad == "same":
            return -(-self.h1 // self.stride)
        return (self.h1 - self.k1) // self.stride + 1

    @property
    def o2(self) -> int:
        if self.pad == "same":
            return -(-self.h2 // self.stride)
        return (self.h2 - self.k2) // self.stride + 1

    @property
    def macs(self) -> int:
        """Multiply-accumulates of spatial convolution (Y_CONV in Eq. 14)."""
        return self.o1 * self.o2 * self.c_in * self.c_out * self.k1 * self.k2

    @property
    def out_elems(self) -> int:
        return self.o1 * self.o2 * self.c_out

    @property
    def in_elems(self) -> int:
        return self.h1 * self.h2 * self.c_in

    @property
    def weight_elems(self) -> int:
        return self.k1 * self.k2 * self.c_in * self.c_out


@dataclasses.dataclass
class LayerNode:
    """One vertex of the CNN graph."""
    id: int
    kind: LayerKind
    name: str = ""
    conv: Optional[ConvMeta] = None
    # Non-conv meta (pooling window / stride, concat arity ...) kept loose:
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.kind is LayerKind.CONV and self.conv is None:
            raise ValueError(f"CONV node {self.id} requires ConvMeta")
        if not self.name:
            self.name = f"{self.kind.value}_{self.id}"


class Graph:
    """A DAG of LayerNodes.

    Edges are directed (producer → consumer) for execution; the series-parallel
    reduction of §4 operates on the *undirected* skeleton, which we expose via
    ``undirected_adjacency``.
    """

    def __init__(self) -> None:
        self.nodes: Dict[int, LayerNode] = {}
        self.edges: List[Tuple[int, int]] = []
        self._next_id = 0

    # ---------------------------------------------------------------- build
    def add_node(self, kind: LayerKind, name: str = "", conv: Optional[ConvMeta] = None,
                 **attrs: object) -> int:
        nid = self._next_id
        self._next_id += 1
        self.nodes[nid] = LayerNode(id=nid, kind=kind, name=name, conv=conv, attrs=dict(attrs))
        return nid

    def add_edge(self, src: int, dst: int) -> None:
        if src not in self.nodes or dst not in self.nodes:
            raise KeyError(f"edge ({src},{dst}) references unknown node")
        self.edges.append((src, dst))

    def chain(self, node_ids: Sequence[int]) -> None:
        for a, b in zip(node_ids, node_ids[1:]):
            self.add_edge(a, b)

    # ---------------------------------------------------------------- query
    def successors(self, nid: int) -> List[int]:
        return [d for (s, d) in self.edges if s == nid]

    def predecessors(self, nid: int) -> List[int]:
        return [s for (s, d) in self.edges if d == nid]

    def out_degree(self, nid: int) -> int:
        return len(self.successors(nid))

    def in_degree(self, nid: int) -> int:
        return len(self.predecessors(nid))

    def conv_nodes(self) -> List[LayerNode]:
        return [n for n in self.nodes.values() if n.kind is LayerKind.CONV]

    def source(self) -> int:
        srcs = [nid for nid in self.nodes if self.in_degree(nid) == 0]
        if len(srcs) != 1:
            raise ValueError(f"graph must have exactly one source, got {srcs}")
        return srcs[0]

    def sink(self) -> int:
        snks = [nid for nid in self.nodes if self.out_degree(nid) == 0]
        if len(snks) != 1:
            raise ValueError(f"graph must have exactly one sink, got {snks}")
        return snks[0]

    def topo_order(self) -> List[int]:
        indeg = {nid: self.in_degree(nid) for nid in self.nodes}
        ready = sorted([nid for nid, d in indeg.items() if d == 0])
        order: List[int] = []
        while ready:
            nid = ready.pop(0)
            order.append(nid)
            for succ in sorted(self.successors(nid)):
                indeg[succ] -= 1
                if indeg[succ] == 0:
                    ready.append(succ)
        if len(order) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return order

    def undirected_adjacency(self) -> Dict[int, List[Tuple[int, int]]]:
        """node → list of (neighbor, edge_index); parallel edges kept distinct."""
        adj: Dict[int, List[Tuple[int, int]]] = {nid: [] for nid in self.nodes}
        for ei, (s, d) in enumerate(self.edges):
            adj[s].append((d, ei))
            adj[d].append((s, ei))
        return adj


# --------------------------------------------------------------------------
# Series-parallel recognition (Definition 1 of the paper).
# --------------------------------------------------------------------------

def is_series_parallel(graph: Graph, source: Optional[int] = None,
                       sink: Optional[int] = None) -> bool:
    """Check Definition 1 by running the reduction to exhaustion.

    Operations:
      (1) remove a degree-2 vertex (≠ s, t); connect its two neighbors.
      (2) replace a pair of parallel edges with a single edge.

    The graph is series-parallel iff the fixpoint is a single edge (K2).
    """
    s = graph.source() if source is None else source
    t = graph.sink() if sink is None else sink

    # Work on an undirected multigraph: list of frozenset pairs.
    edges: List[Tuple[int, int]] = [(a, b) for (a, b) in graph.edges]
    alive = set(graph.nodes)

    changed = True
    while changed:
        changed = False
        # (2) merge parallel edges first (cheap).
        seen: Dict[frozenset, int] = {}
        merged: List[Tuple[int, int]] = []
        for (a, b) in edges:
            key = frozenset((a, b))
            if key in seen:
                changed = True  # drop duplicate
            else:
                seen[key] = 1
                merged.append((a, b))
        edges = merged

        # (1) eliminate one degree-2 vertex.
        deg: Dict[int, List[Tuple[int, int]]] = {n: [] for n in alive}
        for e in edges:
            deg[e[0]].append(e)
            deg[e[1]].append(e)
        for v in list(alive):
            if v in (s, t):
                continue
            if len(deg[v]) == 2:
                (e1, e2) = deg[v]
                n1 = e1[0] if e1[1] == v else e1[1]
                n2 = e2[0] if e2[1] == v else e2[1]
                if n1 == v or n2 == v:   # self loop — not SP
                    return False
                edges = [e for e in edges if e is not e1 and e is not e2]
                edges.append((n1, n2))
                alive.discard(v)
                changed = True
                break

    return alive == {s, t} and len(edges) == 1


def assert_single_source_sink(graph: Graph) -> Tuple[int, int]:
    return graph.source(), graph.sink()
