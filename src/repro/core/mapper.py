"""DYNAMAP end-to-end mapping flow (§5): cost-graph construction + PBQP.

Steps (Figure 7):
  ① Algorithm 1 identifies (P_SA1, P_SA2) and per-(layer, algorithm) dataflow ψ;
  ② the CNN cost graph is constructed (§5.1): conv vertices carry cost vectors
     over algorithm choices; out-degree>1 vertices get a *store-format* split
     vertex v_s; edges carry layout-transition matrices (Table 2);
  ③ the PBQP solver performs the series-parallel node reductions (§4);
  ④-⑥ the result is an ExecutionPlan the executor / codegen consumes.

Construction note: the paper gives v_s a choice vector of size Σ_b'|A_b'|
(one entry per downstream-layer algorithm). We use the equivalent compact
form — v_s chooses among the *distinct input layouts* of downstream
algorithms; store edges pay the layout-conversion write, load edges pay a
matched (streaming) read when layouts agree and a converting read otherwise.
Both formulations price exactly the same store/load legs of Table 2.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:  # pragma: no cover — autotune imports mapper at runtime
    from repro.core.autotune import TuningRecord

from repro.core.algorithms import (Algorithm, AlgoFamily, DEFAULT_MENU,
                                   IM2COL, KN2ROW, Layout, menu_for)
from repro.core.cost_model import (Dataflow, TPUSpec, TransitionCalibration,
                                   V5E, V5E_INT8, best_dataflow,
                                   eff_bandwidth, fits_on_chip, gemm_steps,
                                   node_cost, transition_cost)
from repro.core.dse import HardwareChoice, identify_parameters
from repro.core.graph import ConvMeta, Graph, LayerKind, LayerNode
from repro.core.layouts import LayoutSpec, NHWC, consumer_spec
from repro.core.pbqp import (PBQP, SolveResult, solve_brute_force,
                             solve_greedy_incremental, solve_greedy_node,
                             solve_series_parallel)


PASSTHROUGH = "passthrough"

# Lowering-time validation sets: fail loudly in ``lower_plan`` instead of
# obscurely at trace time inside a kernel.
EPILOGUES = ("none", "relu", "bias", "bias_relu")
BACKENDS = ("auto", "pallas", "reference", "lax")
PRECISIONS = ("bf16", "int8")


@dataclasses.dataclass
class NodeChoices:
    """The per-vertex choice set entering the PBQP. With quantization on,
    conv vertices carry an (algorithm × precision) cross product: the int8
    replicas of each non-Winograd algorithm appear as extra entries
    (labels ``"<algo>@int8"``) priced under the int8 hardware spec, and
    ``precisions[i]`` names entry i's precision (None ⇒ all bf16)."""
    node_id: int
    kind: LayerKind
    algos: List[Algorithm]          # empty for passthrough nodes
    labels: List[str]
    costs: np.ndarray               # (d,)
    dataflows: List[Optional[Dataflow]]
    precisions: Optional[List[str]] = None


@dataclasses.dataclass
class ExecutionPlan:
    p1: int
    p2: int
    assignment: Dict[int, Algorithm]          # conv node → algorithm
    dataflows: Dict[int, Dataflow]            # conv node → dataflow
    store_formats: Dict[int, Layout]          # split producer → DRAM layout
    total_cost_s: float
    solver: SolveResult
    choices: Dict[int, NodeChoices]
    # conv node → "int8"|"bf16"; empty ⇒ all bf16 (pre-quantization plans).
    precisions: Dict[int, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class ConvLowering:
    """Static per-conv-layer binding the compiled overlay closes over:
    everything the Computing Unit needs to execute one layer — algorithm
    wrapper, the Eq. 9 dataflow/(p1, p2) GEMM block binding, the fused
    post-GEMM ``epilogue`` ("none"|"relu"|"bias"|"bias_relu") and the
    ``backend`` the layer runs on ("auto" follows the executor-wide
    use_pallas flag; "pallas"/"reference"/"lax" pin it, letting one
    compiled plan mix tiny-conv jnp/lax layers with big Pallas GEMMs).
    ``in_layout``/``out_layout`` (None = NHWC) realize the plan's DRAM
    store formats: the layer consumes its predecessor's stored format
    directly / emits its consumer's store format (§3.3, Table 2).
    Hashable, so a (graph, lowering) pair keys one jit-compiled program.

    Precision binding: ``precision`` "int8" runs the quantized overlay
    path with the calibrated static per-tensor ``in_scale``; ``out_scale``
    (set only on a fused int8→int8 chain edge) makes the layer requantize
    its fused epilogue output to the consumer's scale and emit int8;
    ``in_quantized`` marks the consumer side of that same edge."""
    algo: Algorithm
    dataflow: Dataflow
    p1: int
    p2: int
    epilogue: str = "relu"
    backend: str = "auto"
    in_layout: Optional[LayoutSpec] = None
    out_layout: Optional[LayoutSpec] = None
    precision: str = "bf16"
    in_scale: Optional[float] = None
    out_scale: Optional[float] = None
    in_quantized: bool = False


@dataclasses.dataclass(frozen=True)
class LayoutTransition:
    """The realized store format of one graph edge.

    ``layout`` is the DRAM representation the producer stores (NHWC unless
    a non-trivial format was chosen); ``elide=True`` means the consumer
    reads that format *directly* (the matched streaming load of Table 2 —
    no NHWC round trip); ``elide=False`` with a non-NHWC layout is the
    converting load (a mismatched sibling at a split); ``reason`` records
    why an edge kept the round trip. ``precision`` is the dtype crossing
    the edge: "int8" only on a fused chain edge whose producer requantizes
    into the consumer's activation scale (both endpoints int8, NHWC)."""
    src: int
    dst: int
    layout: LayoutSpec
    elide: bool
    reason: str = ""
    precision: str = "bf16"


@dataclasses.dataclass
class LoweredProgram:
    """What ``lower_plan`` hands the executor: per-conv bindings plus the
    per-edge layout transitions derived from ``plan.store_formats``.

    ``convs`` maps conv node → ConvLowering; ``transitions`` maps every
    graph edge → LayoutTransition; ``store_specs`` maps producer node →
    the non-NHWC format it stages (split vertices materialize it ONCE and
    fan it out; the executor materializes it for non-conv producers, conv
    producers fuse it via ``ConvLowering.out_layout``). Behaves as a
    mapping over ``convs`` so pre-layout call sites (``lowering[nid]``,
    ``.values()``) keep working.

    ``calibration`` is the transition-cost calibration the program was
    lowered under (None = the uncalibrated analytical model); consumers
    that re-price the program's transitions (``transition_report``) read
    it from here instead of taking a duplicate side-channel argument.
    """
    convs: Dict[int, ConvLowering]
    transitions: Dict[Tuple[int, int], LayoutTransition] = \
        dataclasses.field(default_factory=dict)
    store_specs: Dict[int, LayoutSpec] = dataclasses.field(default_factory=dict)
    calibration: Optional[TransitionCalibration] = None

    # -------------------------------------------------- mapping protocol
    def __getitem__(self, nid: int) -> ConvLowering:
        return self.convs[nid]

    def __contains__(self, nid: int) -> bool:
        return nid in self.convs

    def __iter__(self):
        return iter(self.convs)

    def __len__(self) -> int:
        return len(self.convs)

    def get(self, nid: int, default=None):
        return self.convs.get(nid, default)

    def keys(self):
        return self.convs.keys()

    def values(self):
        return self.convs.values()

    def items(self):
        return self.convs.items()

    # ------------------------------------------------------ observability
    @property
    def elided_edges(self) -> List[Tuple[int, int]]:
        """Edges whose consumer reads a non-NHWC store format directly —
        the transitions the compiled program skips."""
        return sorted((t.src, t.dst) for t in self.transitions.values()
                      if t.elide and t.layout.kind != "nhwc")

    @property
    def quantized_edges(self) -> List[Tuple[int, int]]:
        """Fused precision edges: the producer requantizes into the
        consumer's activation scale and the edge carries int8 bytes."""
        return sorted((t.src, t.dst) for t in self.transitions.values()
                      if t.precision == "int8")


def _validate_lowering(graph: Graph, epilogue: str, backend: str,
                       elide_overrides) -> None:
    if epilogue not in EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}; want one of "
                         f"{EPILOGUES}")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of "
                         f"{BACKENDS}")
    if elide_overrides is None:
        return
    edges = set(graph.edges)
    for edge, flag in elide_overrides.items():
        if not (isinstance(edge, tuple) and len(edge) == 2
                and edge in edges):
            raise ValueError(f"elide_overrides key {edge!r} is not an edge "
                             "of the graph")
        if not isinstance(flag, bool):
            raise ValueError(f"elide_overrides[{edge}] must be bool, "
                             f"got {flag!r}")


def _most_common_spec(specs: List[LayoutSpec]) -> Optional[LayoutSpec]:
    """Majority vote with first-seen tie-breaking (deterministic)."""
    counts: Dict[LayoutSpec, int] = {}
    for s in specs:
        counts[s] = counts.get(s, 0) + 1
    best = None
    for s in specs:                      # first-seen order
        if best is None or counts[s] > counts[best]:
            best = s
    return best


def _consumer_want(graph: Graph, base: Dict[int, ConvLowering],
                   v: int) -> Tuple[Optional[LayoutSpec], str]:
    """The store format consumer ``v`` reads directly, or (None, why)."""
    node = graph.nodes[v]
    if node.kind is not LayerKind.CONV:
        return NHWC, ""
    low = base[v]
    if low.backend == "lax":
        return None, "lax backend consumes NHWC"
    spec = consumer_spec(low.algo, node.conv)
    if spec is None:
        return None, f"{low.algo.key} has no directly-consumable format here"
    return spec, ""


def _thread_layouts(graph: Graph, plan: Optional[ExecutionPlan],
                    base: Dict[int, ConvLowering], elide: bool,
                    overrides: Dict[Tuple[int, int], bool]
                    ) -> LoweredProgram:
    """Derive per-edge LayoutTransitions and attach in/out layouts.

    Chain edges store the consumer's own input layout (the Table 2 edge
    cost already prices exactly that store); split producers store ONE
    format — the PBQP's ``plan.store_formats`` pick when available,
    restricted to the fan-out's matching consumers — and siblings that
    want something else pay a converting load (``kernels.layouts.restore``).
    """
    transitions: Dict[Tuple[int, int], LayoutTransition] = {}
    store_specs: Dict[int, LayoutSpec] = {}
    in_layouts: Dict[int, LayoutSpec] = {}
    for u in graph.topo_order():
        succs = sorted(graph.successors(u))
        if not succs:
            continue
        # What each consumer *could* read directly — overrides do not
        # enter this vote, so disabling one edge never reshuffles its
        # siblings' transitions (a per-edge toggle measures that edge and
        # only that edge).
        wants = {v: _consumer_want(graph, base, v) for v in succs}
        if graph.nodes[u].kind is LayerKind.INPUT:
            # The network input arrives in NHWC from outside (the serving
            # engine's staging buffer, the client): there is no producer
            # layer to store a format, and the cost graph prices the input
            # vertex as a 3-D-tensor producer — the first layer always
            # pays its own load-side conversion. (NHWC-consuming layers
            # still match trivially.)
            wants = {v: ((s, why) if s is not None and s.kind == "nhwc"
                         else (None, "network input arrives in NHWC"))
                     for v, (s, why) in wants.items()}
        candidates = [] if not elide else \
            [s for (s, _) in wants.values()
             if s is not None and s.kind != "nhwc"]
        if plan is not None and len(succs) > 1 and u in plan.store_formats:
            # Honor the PBQP's store-format split vertex: only formats of
            # the chosen DRAM layout may be materialized on this fan-out.
            chosen = plan.store_formats[u]
            candidates = ([] if chosen is Layout.TENSOR3D else
                          [s for s in candidates if s.layout is chosen])
        store = _most_common_spec(candidates)
        if (store is not None and len(succs) == 1
                and overrides.get((u, succs[0])) is False):
            # A chain edge's store exists only for its one consumer: the
            # override restores the true NHWC baseline (no materialization
            # at all), not a round trip through the format.
            store = None
        for v in succs:
            want, why = wants[v]
            if not elide:
                want, why = None, "elision disabled"
            elif overrides.get((u, v)) is False:
                want, why = None, "disabled by per-edge override"
            if want is not None and store is not None and want == store:
                transitions[(u, v)] = LayoutTransition(u, v, store, True)
                in_layouts[v] = store
            elif want is not None and want.kind == "nhwc" and store is None:
                # kn2row / non-conv consumers: the 3-D tensor IS their
                # input layout — matched without any conversion.
                transitions[(u, v)] = LayoutTransition(u, v, NHWC, True)
            else:
                if not why:
                    why = ("converting load (store format mismatch)"
                           if store is not None
                           else "store format stays NHWC")
                transitions[(u, v)] = LayoutTransition(
                    u, v, store if store is not None else NHWC, False, why)
        if store is not None:
            store_specs[u] = store
    convs = {
        nid: dataclasses.replace(low, in_layout=in_layouts.get(nid),
                                 out_layout=store_specs.get(nid))
        for nid, low in base.items()
    }
    return LoweredProgram(convs, transitions, store_specs)


def _fuse_precision_edges(graph: Graph, prog: LoweredProgram
                          ) -> LoweredProgram:
    """Skip the f32 round trip on int8→int8 chain edges.

    A single-consumer NHWC edge between two int8 layers carries int8: the
    producer requantizes its fused epilogue output into the consumer's
    activation scale (``out_scale``) and the consumer skips its own input
    quantization (``in_quantized``) — the precision counterpart of layout
    elision, reusing the same LayoutTransition bookkeeping. Fan-outs and
    non-NHWC edges stay f32 (consumers quantize on load).
    """
    convs = dict(prog.convs)
    transitions = dict(prog.transitions)
    for (u, v), tr in prog.transitions.items():
        lu, lv = convs.get(u), convs.get(v)
        if (lu is None or lv is None
                or lu.precision != "int8" or lv.precision != "int8"
                or len(graph.successors(u)) != 1
                or tr.layout.kind != "nhwc"
                or lu.out_layout is not None or lv.in_layout is not None):
            continue
        convs[u] = dataclasses.replace(convs[u], out_scale=lv.in_scale)
        convs[v] = dataclasses.replace(convs[v], in_quantized=True)
        transitions[(u, v)] = dataclasses.replace(tr, precision="int8")
    return LoweredProgram(convs, transitions, prog.store_specs)


def lower_plan(graph: Graph, plan: Optional[ExecutionPlan],
               default_algo: Algorithm = IM2COL, *,
               epilogue: str = "relu",
               backend: str = "auto",
               tuning: Optional["TuningRecord"] = None,
               batch: Optional[int] = None,
               elide: bool = True,
               elide_overrides: Optional[Dict[Tuple[int, int], bool]] = None,
               act_scales: Optional[Dict[int, float]] = None,
               calibration: Optional[TransitionCalibration] = None
               ) -> LoweredProgram:
    """Lower an ExecutionPlan to the static spec consumed at trace time.

    With ``plan=None`` every conv gets ``default_algo`` under the NS
    dataflow on a 128×128 virtual array (the paper's unconfigured overlay).

    ``epilogue``/``backend`` seed every layer's lowering; a ``tuning``
    record (``core.autotune``) overrides the cost-model binding — algorithm,
    dataflow, (p1, p2) blocks and backend — per layer with the *measured*
    winner, keyed by (conv signature, batch bucket). ``batch`` selects the
    bucket the lowered program will serve (None → bucket 1): bindings do
    not rank identically across batch sizes, so a bucketed serving engine
    lowers one spec per bucket. Layers without a record entry keep the
    model-predicted binding.

    The returned ``LoweredProgram`` also carries the realized store format
    of every edge: with ``elide=True`` (default) consumers read matching
    store formats directly and the NHWC round trip survives only where
    producer/consumer layouts disagree; ``elide=False`` lowers the
    layout-agnostic always-round-trip program (the pre-layout baseline,
    kept for benchmarking); ``elide_overrides`` flips individual edges
    (``{(src, dst): False}``), letting the autotuner measure elision
    per edge. Unknown epilogue/backend strings and malformed overrides are
    rejected here, not at trace time.

    Precision: a plan whose ``precisions`` marks a layer "int8" lowers it
    to the quantized overlay path; ``act_scales`` (conv node → calibrated
    per-tensor activation scale, ``core.quant.calibrate_act_scales``) is
    then required for every int8 layer. Int8→int8 single-consumer NHWC
    edges fuse (the producer requantizes straight into the consumer's
    scale and the edge carries int8); every other precision boundary is a
    plain quantize/dequantize at the consumer/producer.

    ``calibration`` rides along on the returned program (it does not
    change the lowering itself): downstream re-pricing —
    ``transition_report`` — reads it from ``LoweredProgram.calibration``,
    the single calibration channel shared with ``map_network``.
    """
    _validate_lowering(graph, epilogue, backend, elide_overrides)
    precisions = (getattr(plan, "precisions", None) or {}) \
        if plan is not None else {}
    base: Dict[int, ConvLowering] = {}
    for node in graph.conv_nodes():
        nid = node.id
        if plan is None:
            low = ConvLowering(default_algo, Dataflow.NS, 128, 128,
                               epilogue, backend)
        else:
            low = ConvLowering(
                plan.assignment.get(nid, default_algo),
                plan.dataflows.get(nid, Dataflow.NS),
                plan.p1, plan.p2, epilogue, backend)
        prec = precisions.get(nid, "bf16")
        if prec not in PRECISIONS:
            raise ValueError(f"conv {nid}: unknown precision {prec!r}; "
                             f"want one of {PRECISIONS}")
        if tuning is not None:
            tuned = tuning.lowering_for(node.conv, batch=batch,
                                        precision=prec)
            if tuned is not None:
                if tuned.backend not in BACKENDS:
                    raise ValueError(
                        f"tuning record binds conv {nid} to unknown "
                        f"backend {tuned.backend!r}; want one of {BACKENDS}")
                low = dataclasses.replace(
                    low, algo=tuned.algo, dataflow=tuned.dataflow,
                    p1=tuned.p1, p2=tuned.p2, backend=tuned.backend)
        if prec == "int8":
            if low.algo.family is AlgoFamily.WINOGRAD:
                raise ValueError(f"conv {nid}: Winograd is bf16-only; an "
                                 "int8 plan entry cannot lower to it")
            if act_scales is None or nid not in act_scales:
                raise ValueError(
                    f"conv {nid} is planned int8 but has no calibrated "
                    "activation scale; pass act_scales from "
                    "core.quant.calibrate_act_scales")
            low = dataclasses.replace(low, precision="int8",
                                      in_scale=float(act_scales[nid]))
        base[nid] = low
    prog = _thread_layouts(graph, plan, base, elide, elide_overrides or {})
    if any(l.precision == "int8" for l in prog.convs.values()):
        prog = _fuse_precision_edges(graph, prog)
    prog.calibration = calibration
    return prog


def _layer_out(node: LayerNode) -> Tuple[int, int, int]:
    """(H, W, C) of a node's output; builders annotate non-conv nodes."""
    if node.conv is not None:
        return (node.conv.o1, node.conv.o2, node.conv.c_out)
    shape = node.attrs.get("out_shape")
    if shape is None:
        raise ValueError(f"node {node.name} missing out_shape annotation")
    h, w, c = shape  # type: ignore[misc]
    return int(h), int(w), int(c)


def _passthrough_cost(node: LayerNode, spec: TPUSpec) -> float:
    """Node cost of non-conv layers (§3.4 pooling module, adds, softmax)."""
    h, w, c = _layer_out(node)
    elems = h * w * c
    if node.kind in (LayerKind.POOL_MAX, LayerKind.POOL_AVG):
        k = int(node.attrs.get("k", 3))
        ops = elems * k * k
        return ops / spec.vpu_flops + elems * spec.dtype_bytes / spec.hbm_bw
    if node.kind in (LayerKind.ADD, LayerKind.SOFTMAX, LayerKind.GLOBAL_POOL):
        return elems / spec.vpu_flops + elems * spec.dtype_bytes / spec.hbm_bw
    if node.kind is LayerKind.FC:
        a = 1
        b = int(node.attrs["in_features"])
        c_ = int(node.attrs["out_features"])
        # FC = single GEMM; dataflow freedom still applies.
        _, steps = best_dataflow(a, b, c_, 128, 128)
        return steps * (128 * 128) / spec.peak_macs \
            + b * c_ * spec.dtype_bytes / spec.hbm_bw
    return 0.0


class CostGraphBuilder:
    """§5.1 — builds the PBQP instance from a CNN graph."""

    def __init__(self, graph: Graph, hw: HardwareChoice,
                 menu: Optional[Sequence[Algorithm]] = None,
                 spec: TPUSpec = V5E,
                 implicit_im2col: bool = False,
                 use_on_chip: bool = True,
                 quantize: bool = False,
                 int8_spec: TPUSpec = V5E_INT8,
                 force_bf16: Sequence[int] = (),
                 calibration: Optional[TransitionCalibration] = None) -> None:
        self.graph = graph
        self.hw = hw
        self.menu = list(menu) if menu is not None else list(DEFAULT_MENU)
        self.spec = spec
        self.implicit_im2col = implicit_im2col
        self.use_on_chip = use_on_chip
        # Measured-vs-predicted transition scales: every edge matrix the
        # builder prices goes through ``transition_cost(calibration=...)``,
        # so a re-solve sees the machine's realized transition costs (the
        # closed-loop re-pricing path — see ``map_network``/``replan``).
        self.calibration = calibration
        # Precision dimension: with ``quantize`` on, every non-Winograd
        # algorithm entry gets an int8 replica priced under ``int8_spec``
        # (the accuracy gate re-solves with demoted layers in
        # ``force_bf16``, which suppresses their int8 entries entirely —
        # so a demoted layer's choice vector is identical to the
        # unquantized build and its assignment is bitwise-stable).
        self.quantize = quantize
        self.int8_spec = int8_spec
        self.force_bf16 = frozenset(force_bf16)
        self.choices: Dict[int, NodeChoices] = {}
        self.split_formats: Dict[int, List[Algorithm]] = {}
        # Virtual store-format vertex id → the producer it splits, so the
        # solved plan can key store_formats by *producer* (what the
        # lowering pipeline needs to materialize the format).
        self.split_producer: Dict[int, int] = {}
        self._next_virtual_id = max(graph.nodes) + 1 if graph.nodes else 0

    # ------------------------------------------------------------- choices
    def _conv_choices(self, node: LayerNode) -> NodeChoices:
        assert node.conv is not None
        menu = menu_for(node.conv, self.menu)
        algos, costs, dfs, labels, precs = [], [], [], [], []
        for algo in menu:
            df = self.hw.psi.get((node.id, algo.key))
            nc = node_cost(node.conv, algo, self.hw.p1, self.hw.p2, df,
                           self.spec)
            algos.append(algo)
            costs.append(nc.total)
            dfs.append(nc.dataflow)
            labels.append(algo.key)
            precs.append("bf16")
        if self.quantize and node.id not in self.force_bf16:
            for algo in menu:
                if algo.family is AlgoFamily.WINOGRAD:
                    continue  # transforms amplify quantization error
                df = self.hw.psi.get((node.id, algo.key))
                nc = node_cost(node.conv, algo, self.hw.p1, self.hw.p2, df,
                               self.int8_spec)
                algos.append(algo)
                costs.append(nc.total)
                dfs.append(nc.dataflow)
                labels.append(f"{algo.key}@int8")
                precs.append("int8")
        return NodeChoices(node.id, node.kind, algos, labels,
                           np.asarray(costs), dfs,
                           precs if self.quantize else None)

    def _pass_choices(self, node: LayerNode) -> NodeChoices:
        return NodeChoices(node.id, node.kind, [], [PASSTHROUGH],
                           np.asarray([_passthrough_cost(node, self.spec)]),
                           [None])

    # ---------------------------------------------------------- transitions
    def _quant_pass_s(self, elems: int) -> float:
        """One elementwise quantize pass on an edge tensor: read the bf16
        activations, write int8 (the dequantize direction is free — the
        int8 producer's accumulator flush emits f32 anyway)."""
        return elems * (self.spec.dtype_bytes
                        + self.int8_spec.dtype_bytes) / self.spec.hbm_bw

    def _edge_matrix(self, src: LayerNode, dst: LayerNode,
                     src_ch: NodeChoices, dst_ch: NodeChoices) -> np.ndarray:
        """Table 2 store+load matrix between two executable vertices.

        Precision boundaries price here: an int8→int8 chain edge moves
        int8 bytes (the fused requantized transfer, ``int8_spec``); a
        bf16→int8 boundary adds the consumer's quantize pass; int8→bf16
        costs nothing extra (the flush emits f32)."""
        sh, sw, sc = _layer_out(src)
        m = np.zeros((len(src_ch.labels), len(dst_ch.labels)))
        elems = sh * sw * sc
        on_chip = False
        if self.use_on_chip and dst.conv is not None:
            on_chip = fits_on_chip(elems, dst.conv.in_elems, self.spec)
        elif self.use_on_chip and dst.conv is None:
            dh, dw, dc = _layer_out(dst)
            on_chip = fits_on_chip(elems, dh * dw * dc, self.spec)

        sp = _precisions_or_default(src_ch)
        dp = _precisions_or_default(dst_ch)
        for i, s_algo in enumerate(_algos_or_default(src_ch)):
            for j, d_algo in enumerate(_algos_or_default(dst_ch)):
                if dst.conv is not None:
                    both_int8 = sp[i] == "int8" and dp[j] == "int8"
                    m[i, j] = transition_cost(
                        s_algo, d_algo, dst.conv, sc,
                        self.int8_spec if both_int8 else self.spec,
                        implicit_im2col=self.implicit_im2col,
                        on_chip=on_chip,
                        calibration=self.calibration)
                    if dp[j] == "int8" and sp[i] != "int8":
                        m[i, j] += self._quant_pass_s(elems)
                else:
                    # Non-conv consumer: 3-D tensor round trip (an int8
                    # producer emits f32 at the boundary — same bytes).
                    bytes_ = elems * self.spec.dtype_bytes
                    m[i, j] = 0.0 if on_chip else 2 * bytes_ / self.spec.hbm_bw
                    if not on_chip and self.calibration is not None:
                        m[i, j] *= self.calibration.scale(
                            s_algo.output_layout, Layout.TENSOR3D)
        return m

    def _split_store_matrix(self, src: LayerNode, src_ch: NodeChoices,
                            formats: List[Algorithm],
                            rep_consumer: Optional[ConvMeta]) -> np.ndarray:
        sh, sw, sc = _layer_out(src)
        m = np.zeros((len(src_ch.labels), len(formats)))
        for i, s_algo in enumerate(_algos_or_default(src_ch)):
            for j, fmt in enumerate(formats):
                if rep_consumer is not None:
                    m[i, j] = 0.5 * transition_cost(
                        s_algo, fmt, rep_consumer, sc, self.spec,
                        implicit_im2col=self.implicit_im2col,
                        calibration=self.calibration)
                else:
                    m[i, j] = sh * sw * sc * self.spec.dtype_bytes \
                        / self.spec.hbm_bw
        return m

    def _split_load_matrix(self, formats: List[Algorithm],
                           src: LayerNode,
                           dst: LayerNode, dst_ch: NodeChoices) -> np.ndarray:
        sh, sw, sc = _layer_out(src)
        m = np.zeros((len(formats), len(dst_ch.labels)))
        dp = _precisions_or_default(dst_ch)
        for i, fmt in enumerate(formats):
            for j, d_algo in enumerate(_algos_or_default(dst_ch)):
                if dst.conv is None:
                    m[i, j] = sh * sw * sc * self.spec.dtype_bytes \
                        / self.spec.hbm_bw
                    continue
                if fmt.input_layout is d_algo.input_layout and \
                        (fmt.family is not AlgoFamily.WINOGRAD or
                         fmt.m == d_algo.m):
                    # Matched format → streaming load (paper's Load(n, n)).
                    m[i, j] = 0.5 * transition_cost(
                        fmt, d_algo, dst.conv, sc, self.spec,
                        implicit_im2col=self.implicit_im2col,
                        calibration=self.calibration)
                else:
                    # Converting load: pay the dst-layout bytes at the
                    # (possibly lane-penalized) effective bandwidth.
                    m[i, j] = transition_cost(
                        fmt, d_algo, dst.conv, sc, self.spec,
                        implicit_im2col=self.implicit_im2col,
                        calibration=self.calibration)
                if dp[j] == "int8":
                    # Fan-out stores stay f32; an int8 consumer pays its
                    # own quantize pass on load.
                    m[i, j] += self._quant_pass_s(sh * sw * sc)
        return m

    # ---------------------------------------------------------------- build
    def build(self) -> Tuple[PBQP, Dict[int, NodeChoices]]:
        g = self.graph
        pbqp = PBQP()
        for nid in g.topo_order():
            node = g.nodes[nid]
            ch = (self._conv_choices(node) if node.kind is LayerKind.CONV
                  else self._pass_choices(node))
            self.choices[nid] = ch
            pbqp.add_node(nid, ch.costs)

        for nid in g.topo_order():
            node = g.nodes[nid]
            succs = g.successors(nid)
            if len(succs) <= 1:
                for s in succs:
                    pbqp.add_edge(nid, s, self._edge_matrix(
                        node, g.nodes[s], self.choices[nid], self.choices[s]))
                continue
            # out-degree > 1 → insert the store-format vertex v_s (§5.1).
            formats: List[Algorithm] = []
            seen = set()
            for s in succs:
                for algo in _algos_or_default(self.choices[s]):
                    key = (algo.input_layout, algo.m)
                    if key not in seen:
                        seen.add(key)
                        formats.append(algo)
            rep = next((g.nodes[s].conv for s in succs
                        if g.nodes[s].conv is not None), None)
            vs = self._next_virtual_id
            self._next_virtual_id += 1
            self.split_producer[vs] = nid
            vs_ch = NodeChoices(vs, LayerKind.CONCAT, formats,
                                [f"store:{a.input_layout.value}" for a in formats],
                                np.zeros(len(formats)),
                                [None] * len(formats))
            self.choices[vs] = vs_ch
            self.split_formats[nid] = formats
            pbqp.add_node(vs, vs_ch.costs)
            pbqp.add_edge(nid, vs, self._split_store_matrix(
                node, self.choices[nid], formats, rep))
            for s in succs:
                pbqp.add_edge(vs, s, self._split_load_matrix(
                    formats, node, g.nodes[s], self.choices[s]))
        return pbqp, self.choices


def _algos_or_default(ch: NodeChoices) -> List[Algorithm]:
    """Passthrough vertices behave as 3-D-tensor producers/consumers, which
    is exactly kn2row's layout (§3.3)."""
    return ch.algos if ch.algos else [KN2ROW]


def _precisions_or_default(ch: NodeChoices) -> List[str]:
    """Entry-wise precisions; vertices without the dimension are bf16."""
    if ch.precisions:
        return ch.precisions
    return ["bf16"] * max(len(ch.labels), 1)


_CAL_UNSET = object()   # sentinel: distinguishes "not passed" from None


def transition_report(graph: Graph, lowered: LoweredProgram,
                      spec: TPUSpec = V5E,
                      calibration=_CAL_UNSET) -> Dict[str, object]:
    """Predicted Table 2 cost of the lowered program's elided transitions
    vs the always-NHWC-round-trip baseline — what the layout bench compares
    against realized wall clock.

    Pricing mirrors the cost graph exactly: an elided edge pays the
    direct store into the consumer's format (½·T) plus the matched
    streaming load (½·T(dst, dst)); the round-trip baseline pays the 3-D
    tensor store (½·T(src, 3D)) plus the converting load into the
    consumer's layout (full T, the ``_split_load_matrix`` convention).

    Calibration comes from ``lowered.calibration`` (set by
    ``lower_plan(calibration=...)``) — the single channel shared with
    ``map_network``. Passing ``calibration=`` here directly is deprecated;
    it still wins over the program's own calibration so existing callers
    price identically, but new code should thread it through
    ``lower_plan``.
    """
    if calibration is _CAL_UNSET:
        calibration = lowered.calibration
    elif calibration is not None:
        warnings.warn(
            "transition_report(calibration=...) is deprecated; pass "
            "calibration to lower_plan(...) and let the LoweredProgram "
            "carry it", DeprecationWarning, stacklevel=2)
    edges = []
    roundtrip_total = elided_total = 0.0
    for (u, v), tr in sorted(lowered.transitions.items()):
        node_v = graph.nodes[v]
        if (not tr.elide or tr.layout.kind == "nhwc"
                or node_v.kind is not LayerKind.CONV):
            continue
        conv = node_v.conv
        dst = lowered[v].algo
        src = lowered.convs[u].algo if u in lowered.convs else KN2ROW
        c_prev = tr.layout.c
        roundtrip = (0.5 * transition_cost(src, KN2ROW, conv, c_prev, spec,
                                           calibration=calibration)
                     + transition_cost(KN2ROW, dst, conv, c_prev, spec,
                                       calibration=calibration))
        elided = (0.5 * transition_cost(src, dst, conv, c_prev, spec,
                                        calibration=calibration)
                  + 0.5 * transition_cost(dst, dst, conv, c_prev, spec,
                                          calibration=calibration))
        roundtrip_total += roundtrip
        elided_total += elided
        edges.append({"src": u, "dst": v, "layout": tr.layout.key,
                      "roundtrip_s": roundtrip, "elided_s": elided,
                      "saving_s": roundtrip - elided})
    return {"edges": edges, "n_elided": len(edges),
            "predicted_roundtrip_s": roundtrip_total,
            "predicted_elided_s": elided_total,
            "predicted_saving_s": roundtrip_total - elided_total}


# ---------------------------------------------------------------------------
# The public flow.
# ---------------------------------------------------------------------------

def map_network(graph: Graph,
                menu: Optional[Sequence[Algorithm]] = None,
                spec: TPUSpec = V5E,
                hw: Optional[HardwareChoice] = None,
                implicit_im2col: bool = False,
                use_on_chip: bool = True,
                solver: str = "sp",
                quantize: bool = False,
                int8_spec: TPUSpec = V5E_INT8,
                force_bf16: Sequence[int] = (),
                calibration: Optional[TransitionCalibration] = None
                ) -> ExecutionPlan:
    """Run the full DYNAMAP flow on a CNN graph. ``solver`` ∈ {sp, brute,
    greedy_node, greedy_incremental} — non-sp solvers exist for the paper's
    baseline comparisons and for optimality tests.

    ``quantize=True`` adds per-layer precision as a joint PBQP dimension:
    each non-Winograd algorithm entry gets an int8 replica priced under
    ``int8_spec`` (2× peak MACs, half the bytes on V5E) with precision-
    boundary conversion costs on the edges, and the solved plan carries a
    ``precisions`` map. ``force_bf16`` pins the listed conv nodes to bf16
    (the accuracy gate's demotion mechanism): a pinned node's choice
    vector is identical to the unquantized build, so demoted layers lower
    bitwise-identically to the all-bf16 plan.

    ``calibration`` (``cost_model.TransitionCalibration``) re-prices every
    edge matrix by the measured/predicted scale of its (source layout,
    destination layout) pair, so a re-solve optimizes against the machine's
    realized transition costs instead of the analytical model — the
    closed-loop half of the DSE (see ``replan`` and
    ``serving.supervisor.PlanSupervisor``). Mapping is deterministic: the
    same graph + spec + calibration always yields the identical plan."""
    if hw is None:
        hw = identify_parameters(graph, menu=menu, spec=spec)
    builder = CostGraphBuilder(graph, hw, menu=menu, spec=spec,
                               implicit_im2col=implicit_im2col,
                               use_on_chip=use_on_chip,
                               quantize=quantize, int8_spec=int8_spec,
                               force_bf16=force_bf16,
                               calibration=calibration)
    pbqp, choices = builder.build()

    if solver == "sp":
        res = solve_series_parallel(pbqp)
    elif solver == "brute":
        res = solve_brute_force(pbqp)
    elif solver == "greedy_node":
        res = solve_greedy_node(pbqp)
    elif solver == "greedy_incremental":
        order = [n for n in sorted(pbqp.costs)]
        res = solve_greedy_incremental(pbqp, order)
    else:
        raise ValueError(f"unknown solver {solver}")

    assignment: Dict[int, Algorithm] = {}
    dataflows: Dict[int, Dataflow] = {}
    store_formats: Dict[int, Layout] = {}
    precisions: Dict[int, str] = {}
    for nid, ch in choices.items():
        pick = res.assignment[nid]
        if ch.kind is LayerKind.CONV and ch.algos:
            assignment[nid] = ch.algos[pick]
            df = ch.dataflows[pick]
            dataflows[nid] = df if df is not None else Dataflow.NS
            if quantize:
                precisions[nid] = _precisions_or_default(ch)[pick]
        elif ch.labels and ch.labels[pick].startswith("store:"):
            # Keyed by the split *producer* (the graph node that stores),
            # not the virtual v_s id — this is what lower_plan consumes.
            store_formats[builder.split_producer[nid]] = \
                ch.algos[pick].input_layout
    return ExecutionPlan(p1=hw.p1, p2=hw.p2, assignment=assignment,
                         dataflows=dataflows, store_formats=store_formats,
                         total_cost_s=res.cost, solver=res, choices=choices,
                         precisions=precisions)


def plan_fingerprint(plan: Optional[ExecutionPlan]):
    """Content fingerprint of the parts of a plan a compiled program closes
    over (bindings + store formats + precisions — solver diagnostics
    excluded). Two plans with equal fingerprints lower and compile
    identically; the executable cache and the hot-swap supervisor both key
    off this."""
    if plan is None:
        return None
    precisions = getattr(plan, "precisions", None) or {}
    return (plan.p1, plan.p2,
            tuple(sorted((n, a.key) for n, a in plan.assignment.items())),
            tuple(sorted((n, d.name) for n, d in plan.dataflows.items())),
            tuple(sorted((n, f.value) for n, f in plan.store_formats.items())),
            tuple(sorted(precisions.items())))


@dataclasses.dataclass(frozen=True)
class ReplanResult:
    """Outcome of one calibrated re-solve against a deployed plan.

    ``plan`` is what should be serving after this decision: the candidate
    when adopted, the deployed plan otherwise. ``changed`` records whether
    the candidate's fingerprint differs at all; ``adopted`` additionally
    requires the candidate to beat the deployed plan's *re-priced* cost by
    more than the hysteresis margin — re-priced meaning the deployed
    assignment evaluated under the SAME calibrated cost graph the
    candidate was solved on, so the comparison is apples to apples."""
    plan: ExecutionPlan
    candidate: ExecutionPlan
    adopted: bool
    changed: bool
    deployed_cost_s: float
    candidate_cost_s: float


def replan(graph: Graph, deployed: ExecutionPlan, *,
           calibration: Optional[TransitionCalibration] = None,
           hysteresis: float = 0.05,
           **map_kwargs) -> ReplanResult:
    """Calibrated PBQP re-solve with a hysteresis adoption gate.

    Re-solves the mapping under ``calibration`` and prices the *deployed*
    assignment on the same calibrated cost graph; the candidate is adopted
    only when it differs AND its solved cost undercuts the deployed plan's
    re-priced cost by more than ``hysteresis`` (fraction, default the
    autotuner's 5%). Perturbing every calibration scale by a factor within
    ``1 ± hysteresis/2`` can shift the deployed/candidate cost ratio by at
    most ~2×(hysteresis/2), so sub-hysteresis measurement noise can never
    flip the deployed plan — the stability property
    ``tests/test_property.py`` checks.

    ``map_kwargs`` must repeat the kwargs the deployed plan was mapped
    with (menu/spec/solver/...): the deployed assignment's choice indices
    are only meaningful on an identically-shaped cost graph."""
    candidate = map_network(graph, calibration=calibration, **map_kwargs)
    builder_kw = {k: v for k, v in map_kwargs.items() if k != "solver"}
    hw = builder_kw.pop("hw", None)
    menu = builder_kw.pop("menu", None)
    spec = builder_kw.pop("spec", V5E)
    if hw is None:
        hw = identify_parameters(graph, menu=menu, spec=spec)
    builder = CostGraphBuilder(graph, hw, menu=menu, spec=spec,
                               calibration=calibration, **builder_kw)
    pbqp, _ = builder.build()
    deployed_cost = pbqp.total_cost(deployed.solver.assignment)
    changed = plan_fingerprint(candidate) != plan_fingerprint(deployed)
    adopted = changed and \
        candidate.total_cost_s < deployed_cost * (1.0 - hysteresis)
    return ReplanResult(plan=candidate if adopted else deployed,
                        candidate=candidate, adopted=adopted,
                        changed=changed,
                        deployed_cost_s=deployed_cost,
                        candidate_cost_s=candidate.total_cost_s)


def evaluate_fixed_mapping(graph: Graph, policy: str,
                           menu: Optional[Sequence[Algorithm]] = None,
                           spec: TPUSpec = V5E,
                           hw: Optional[HardwareChoice] = None,
                           implicit_im2col: bool = False,
                           use_on_chip: bool = True) -> float:
    """Cost of the paper's single-algorithm baselines on the same cost graph:
    bl3 = 'im2col', bl4 = 'kn2row' (where possible, else im2col),
    bl5 = 'winograd' (where applicable, else im2col)."""
    if hw is None:
        hw = identify_parameters(graph, menu=menu, spec=spec)
    builder = CostGraphBuilder(graph, hw, menu=menu, spec=spec,
                               implicit_im2col=implicit_im2col,
                               use_on_chip=use_on_chip)
    pbqp, choices = builder.build()

    assignment: Dict[int, int] = {}
    for nid, ch in choices.items():
        if ch.kind is LayerKind.CONV and ch.algos:
            idx = _pick_for_policy(ch.algos, policy)
        else:
            # Split vertices: choose the best format greedily given the
            # forced conv assignment is uniform — pick matched layout.
            idx = _split_pick(ch, policy)
        assignment[nid] = idx
    return pbqp.total_cost(assignment)


def _pick_for_policy(algos: List[Algorithm], policy: str) -> int:
    fams = [a.family for a in algos]
    if policy == "im2col":
        return fams.index(AlgoFamily.IM2COL)
    if policy == "kn2row":
        if AlgoFamily.KN2ROW in fams:
            return fams.index(AlgoFamily.KN2ROW)
        return fams.index(AlgoFamily.IM2COL)
    if policy == "winograd":
        if AlgoFamily.WINOGRAD in fams:
            return fams.index(AlgoFamily.WINOGRAD)
        return fams.index(AlgoFamily.IM2COL)
    raise ValueError(policy)


def _split_pick(ch: NodeChoices, policy: str) -> int:
    if not ch.labels or not ch.labels[0].startswith("store:"):
        return 0
    want = {"im2col": Layout.TOEPLITZ, "kn2row": Layout.TENSOR3D,
            "winograd": Layout.WINOGRAD}.get(policy, Layout.TENSOR3D)
    for i, a in enumerate(ch.algos):
        if a.input_layout is want:
            return i
    return 0
