"""DYNAMAP end-to-end mapping flow (§5): cost-graph construction + PBQP.

Steps (Figure 7):
  ① Algorithm 1 identifies (P_SA1, P_SA2) and per-(layer, algorithm) dataflow ψ;
  ② the CNN cost graph is constructed (§5.1): conv vertices carry cost vectors
     over algorithm choices; out-degree>1 vertices get a *store-format* split
     vertex v_s; edges carry layout-transition matrices (Table 2);
  ③ the PBQP solver performs the series-parallel node reductions (§4);
  ④-⑥ the result is an ExecutionPlan the executor / codegen consumes.

Construction note: the paper gives v_s a choice vector of size Σ_b'|A_b'|
(one entry per downstream-layer algorithm). We use the equivalent compact
form — v_s chooses among the *distinct input layouts* of downstream
algorithms; store edges pay the layout-conversion write, load edges pay a
matched (streaming) read when layouts agree and a converting read otherwise.
Both formulations price exactly the same store/load legs of Table 2.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:  # pragma: no cover — autotune imports mapper at runtime
    from repro.core.autotune import TuningRecord

from repro.core.algorithms import (Algorithm, AlgoFamily, DEFAULT_MENU,
                                   IM2COL, KN2ROW, Layout, menu_for)
from repro.core.cost_model import (Dataflow, TPUSpec, V5E, best_dataflow,
                                   eff_bandwidth, fits_on_chip, gemm_steps,
                                   node_cost, transition_cost)
from repro.core.dse import HardwareChoice, identify_parameters
from repro.core.graph import ConvMeta, Graph, LayerKind, LayerNode
from repro.core.pbqp import (PBQP, SolveResult, solve_brute_force,
                             solve_greedy_incremental, solve_greedy_node,
                             solve_series_parallel)


PASSTHROUGH = "passthrough"


@dataclasses.dataclass
class NodeChoices:
    """The per-vertex choice set entering the PBQP."""
    node_id: int
    kind: LayerKind
    algos: List[Algorithm]          # empty for passthrough nodes
    labels: List[str]
    costs: np.ndarray               # (d,)
    dataflows: List[Optional[Dataflow]]


@dataclasses.dataclass
class ExecutionPlan:
    p1: int
    p2: int
    assignment: Dict[int, Algorithm]          # conv node → algorithm
    dataflows: Dict[int, Dataflow]            # conv node → dataflow
    store_formats: Dict[int, Layout]          # split producer → DRAM layout
    total_cost_s: float
    solver: SolveResult
    choices: Dict[int, NodeChoices]


@dataclasses.dataclass(frozen=True)
class ConvLowering:
    """Static per-conv-layer binding the compiled overlay closes over:
    everything the Computing Unit needs to execute one layer — algorithm
    wrapper, the Eq. 9 dataflow/(p1, p2) GEMM block binding, the fused
    post-GEMM ``epilogue`` ("none"|"relu"|"bias"|"bias_relu") and the
    ``backend`` the layer runs on ("auto" follows the executor-wide
    use_pallas flag; "pallas"/"reference"/"lax" pin it, letting one
    compiled plan mix tiny-conv jnp/lax layers with big Pallas GEMMs).
    Hashable, so a (graph, lowering) pair keys one jit-compiled program."""
    algo: Algorithm
    dataflow: Dataflow
    p1: int
    p2: int
    epilogue: str = "relu"
    backend: str = "auto"


def lower_plan(graph: Graph, plan: Optional[ExecutionPlan],
               default_algo: Algorithm = IM2COL, *,
               epilogue: str = "relu",
               backend: str = "auto",
               tuning: Optional["TuningRecord"] = None,
               batch: Optional[int] = None
               ) -> Dict[int, ConvLowering]:
    """Lower an ExecutionPlan to the static spec consumed at trace time.

    With ``plan=None`` every conv gets ``default_algo`` under the NS
    dataflow on a 128×128 virtual array (the paper's unconfigured overlay).

    ``epilogue``/``backend`` seed every layer's lowering; a ``tuning``
    record (``core.autotune``) overrides the cost-model binding — algorithm,
    dataflow, (p1, p2) blocks and backend — per layer with the *measured*
    winner, keyed by (conv signature, batch bucket). ``batch`` selects the
    bucket the lowered program will serve (None → bucket 1): bindings do
    not rank identically across batch sizes, so a bucketed serving engine
    lowers one spec per bucket. Layers without a record entry keep the
    model-predicted binding.
    """
    out: Dict[int, ConvLowering] = {}
    for node in graph.conv_nodes():
        nid = node.id
        if plan is None:
            low = ConvLowering(default_algo, Dataflow.NS, 128, 128,
                               epilogue, backend)
        else:
            low = ConvLowering(
                plan.assignment.get(nid, default_algo),
                plan.dataflows.get(nid, Dataflow.NS),
                plan.p1, plan.p2, epilogue, backend)
        if tuning is not None:
            tuned = tuning.lowering_for(node.conv, batch=batch)
            if tuned is not None:
                low = dataclasses.replace(
                    low, algo=tuned.algo, dataflow=tuned.dataflow,
                    p1=tuned.p1, p2=tuned.p2, backend=tuned.backend)
        out[nid] = low
    return out


def _layer_out(node: LayerNode) -> Tuple[int, int, int]:
    """(H, W, C) of a node's output; builders annotate non-conv nodes."""
    if node.conv is not None:
        return (node.conv.o1, node.conv.o2, node.conv.c_out)
    shape = node.attrs.get("out_shape")
    if shape is None:
        raise ValueError(f"node {node.name} missing out_shape annotation")
    h, w, c = shape  # type: ignore[misc]
    return int(h), int(w), int(c)


def _passthrough_cost(node: LayerNode, spec: TPUSpec) -> float:
    """Node cost of non-conv layers (§3.4 pooling module, adds, softmax)."""
    h, w, c = _layer_out(node)
    elems = h * w * c
    if node.kind in (LayerKind.POOL_MAX, LayerKind.POOL_AVG):
        k = int(node.attrs.get("k", 3))
        ops = elems * k * k
        return ops / spec.vpu_flops + elems * spec.dtype_bytes / spec.hbm_bw
    if node.kind in (LayerKind.ADD, LayerKind.SOFTMAX, LayerKind.GLOBAL_POOL):
        return elems / spec.vpu_flops + elems * spec.dtype_bytes / spec.hbm_bw
    if node.kind is LayerKind.FC:
        a = 1
        b = int(node.attrs["in_features"])
        c_ = int(node.attrs["out_features"])
        # FC = single GEMM; dataflow freedom still applies.
        _, steps = best_dataflow(a, b, c_, 128, 128)
        return steps * (128 * 128) / spec.peak_macs \
            + b * c_ * spec.dtype_bytes / spec.hbm_bw
    return 0.0


class CostGraphBuilder:
    """§5.1 — builds the PBQP instance from a CNN graph."""

    def __init__(self, graph: Graph, hw: HardwareChoice,
                 menu: Optional[Sequence[Algorithm]] = None,
                 spec: TPUSpec = V5E,
                 implicit_im2col: bool = False,
                 use_on_chip: bool = True) -> None:
        self.graph = graph
        self.hw = hw
        self.menu = list(menu) if menu is not None else list(DEFAULT_MENU)
        self.spec = spec
        self.implicit_im2col = implicit_im2col
        self.use_on_chip = use_on_chip
        self.choices: Dict[int, NodeChoices] = {}
        self.split_formats: Dict[int, List[Algorithm]] = {}
        self._next_virtual_id = max(graph.nodes) + 1 if graph.nodes else 0

    # ------------------------------------------------------------- choices
    def _conv_choices(self, node: LayerNode) -> NodeChoices:
        assert node.conv is not None
        algos = menu_for(node.conv, self.menu)
        costs, dfs, labels = [], [], []
        for algo in algos:
            df = self.hw.psi.get((node.id, algo.key))
            nc = node_cost(node.conv, algo, self.hw.p1, self.hw.p2, df,
                           self.spec)
            costs.append(nc.total)
            dfs.append(nc.dataflow)
            labels.append(algo.key)
        return NodeChoices(node.id, node.kind, algos, labels,
                           np.asarray(costs), dfs)

    def _pass_choices(self, node: LayerNode) -> NodeChoices:
        return NodeChoices(node.id, node.kind, [], [PASSTHROUGH],
                           np.asarray([_passthrough_cost(node, self.spec)]),
                           [None])

    # ---------------------------------------------------------- transitions
    def _edge_matrix(self, src: LayerNode, dst: LayerNode,
                     src_ch: NodeChoices, dst_ch: NodeChoices) -> np.ndarray:
        """Table 2 store+load matrix between two executable vertices."""
        sh, sw, sc = _layer_out(src)
        m = np.zeros((len(src_ch.labels), len(dst_ch.labels)))
        on_chip = False
        if self.use_on_chip and dst.conv is not None:
            on_chip = fits_on_chip(sh * sw * sc, dst.conv.in_elems, self.spec)
        elif self.use_on_chip and dst.conv is None:
            dh, dw, dc = _layer_out(dst)
            on_chip = fits_on_chip(sh * sw * sc, dh * dw * dc, self.spec)

        for i, s_algo in enumerate(_algos_or_default(src_ch)):
            for j, d_algo in enumerate(_algos_or_default(dst_ch)):
                if dst.conv is not None:
                    m[i, j] = transition_cost(
                        s_algo, d_algo, dst.conv, sc, self.spec,
                        implicit_im2col=self.implicit_im2col,
                        on_chip=on_chip)
                else:
                    # Non-conv consumer: 3-D tensor round trip.
                    bytes_ = sh * sw * sc * self.spec.dtype_bytes
                    m[i, j] = 0.0 if on_chip else 2 * bytes_ / self.spec.hbm_bw
        return m

    def _split_store_matrix(self, src: LayerNode, src_ch: NodeChoices,
                            formats: List[Algorithm],
                            rep_consumer: Optional[ConvMeta]) -> np.ndarray:
        sh, sw, sc = _layer_out(src)
        m = np.zeros((len(src_ch.labels), len(formats)))
        for i, s_algo in enumerate(_algos_or_default(src_ch)):
            for j, fmt in enumerate(formats):
                if rep_consumer is not None:
                    m[i, j] = 0.5 * transition_cost(
                        s_algo, fmt, rep_consumer, sc, self.spec,
                        implicit_im2col=self.implicit_im2col)
                else:
                    m[i, j] = sh * sw * sc * self.spec.dtype_bytes \
                        / self.spec.hbm_bw
        return m

    def _split_load_matrix(self, formats: List[Algorithm],
                           src: LayerNode,
                           dst: LayerNode, dst_ch: NodeChoices) -> np.ndarray:
        sh, sw, sc = _layer_out(src)
        m = np.zeros((len(formats), len(dst_ch.labels)))
        for i, fmt in enumerate(formats):
            for j, d_algo in enumerate(_algos_or_default(dst_ch)):
                if dst.conv is None:
                    m[i, j] = sh * sw * sc * self.spec.dtype_bytes \
                        / self.spec.hbm_bw
                    continue
                if fmt.input_layout is d_algo.input_layout and \
                        (fmt.family is not AlgoFamily.WINOGRAD or
                         fmt.m == d_algo.m):
                    # Matched format → streaming load (paper's Load(n, n)).
                    m[i, j] = 0.5 * transition_cost(
                        fmt, d_algo, dst.conv, sc, self.spec,
                        implicit_im2col=self.implicit_im2col)
                else:
                    # Converting load: pay the dst-layout bytes at the
                    # (possibly lane-penalized) effective bandwidth.
                    m[i, j] = transition_cost(
                        fmt, d_algo, dst.conv, sc, self.spec,
                        implicit_im2col=self.implicit_im2col)
        return m

    # ---------------------------------------------------------------- build
    def build(self) -> Tuple[PBQP, Dict[int, NodeChoices]]:
        g = self.graph
        pbqp = PBQP()
        for nid in g.topo_order():
            node = g.nodes[nid]
            ch = (self._conv_choices(node) if node.kind is LayerKind.CONV
                  else self._pass_choices(node))
            self.choices[nid] = ch
            pbqp.add_node(nid, ch.costs)

        for nid in g.topo_order():
            node = g.nodes[nid]
            succs = g.successors(nid)
            if len(succs) <= 1:
                for s in succs:
                    pbqp.add_edge(nid, s, self._edge_matrix(
                        node, g.nodes[s], self.choices[nid], self.choices[s]))
                continue
            # out-degree > 1 → insert the store-format vertex v_s (§5.1).
            formats: List[Algorithm] = []
            seen = set()
            for s in succs:
                for algo in _algos_or_default(self.choices[s]):
                    key = (algo.input_layout, algo.m)
                    if key not in seen:
                        seen.add(key)
                        formats.append(algo)
            rep = next((g.nodes[s].conv for s in succs
                        if g.nodes[s].conv is not None), None)
            vs = self._next_virtual_id
            self._next_virtual_id += 1
            vs_ch = NodeChoices(vs, LayerKind.CONCAT, formats,
                                [f"store:{a.input_layout.value}" for a in formats],
                                np.zeros(len(formats)),
                                [None] * len(formats))
            self.choices[vs] = vs_ch
            self.split_formats[nid] = formats
            pbqp.add_node(vs, vs_ch.costs)
            pbqp.add_edge(nid, vs, self._split_store_matrix(
                node, self.choices[nid], formats, rep))
            for s in succs:
                pbqp.add_edge(vs, s, self._split_load_matrix(
                    formats, node, g.nodes[s], self.choices[s]))
        return pbqp, self.choices


def _algos_or_default(ch: NodeChoices) -> List[Algorithm]:
    """Passthrough vertices behave as 3-D-tensor producers/consumers, which
    is exactly kn2row's layout (§3.3)."""
    return ch.algos if ch.algos else [KN2ROW]


# ---------------------------------------------------------------------------
# The public flow.
# ---------------------------------------------------------------------------

def map_network(graph: Graph,
                menu: Optional[Sequence[Algorithm]] = None,
                spec: TPUSpec = V5E,
                hw: Optional[HardwareChoice] = None,
                implicit_im2col: bool = False,
                use_on_chip: bool = True,
                solver: str = "sp") -> ExecutionPlan:
    """Run the full DYNAMAP flow on a CNN graph. ``solver`` ∈ {sp, brute,
    greedy_node, greedy_incremental} — non-sp solvers exist for the paper's
    baseline comparisons and for optimality tests."""
    if hw is None:
        hw = identify_parameters(graph, menu=menu, spec=spec)
    builder = CostGraphBuilder(graph, hw, menu=menu, spec=spec,
                               implicit_im2col=implicit_im2col,
                               use_on_chip=use_on_chip)
    pbqp, choices = builder.build()

    if solver == "sp":
        res = solve_series_parallel(pbqp)
    elif solver == "brute":
        res = solve_brute_force(pbqp)
    elif solver == "greedy_node":
        res = solve_greedy_node(pbqp)
    elif solver == "greedy_incremental":
        order = [n for n in sorted(pbqp.costs)]
        res = solve_greedy_incremental(pbqp, order)
    else:
        raise ValueError(f"unknown solver {solver}")

    assignment: Dict[int, Algorithm] = {}
    dataflows: Dict[int, Dataflow] = {}
    store_formats: Dict[int, Layout] = {}
    for nid, ch in choices.items():
        pick = res.assignment[nid]
        if ch.kind is LayerKind.CONV and ch.algos:
            assignment[nid] = ch.algos[pick]
            df = ch.dataflows[pick]
            dataflows[nid] = df if df is not None else Dataflow.NS
        elif ch.labels and ch.labels[pick].startswith("store:"):
            store_formats[nid] = ch.algos[pick].input_layout
    return ExecutionPlan(p1=hw.p1, p2=hw.p2, assignment=assignment,
                         dataflows=dataflows, store_formats=store_formats,
                         total_cost_s=res.cost, solver=res, choices=choices)


def evaluate_fixed_mapping(graph: Graph, policy: str,
                           menu: Optional[Sequence[Algorithm]] = None,
                           spec: TPUSpec = V5E,
                           hw: Optional[HardwareChoice] = None,
                           implicit_im2col: bool = False,
                           use_on_chip: bool = True) -> float:
    """Cost of the paper's single-algorithm baselines on the same cost graph:
    bl3 = 'im2col', bl4 = 'kn2row' (where possible, else im2col),
    bl5 = 'winograd' (where applicable, else im2col)."""
    if hw is None:
        hw = identify_parameters(graph, menu=menu, spec=spec)
    builder = CostGraphBuilder(graph, hw, menu=menu, spec=spec,
                               implicit_im2col=implicit_im2col,
                               use_on_chip=use_on_chip)
    pbqp, choices = builder.build()

    assignment: Dict[int, int] = {}
    for nid, ch in choices.items():
        if ch.kind is LayerKind.CONV and ch.algos:
            idx = _pick_for_policy(ch.algos, policy)
        else:
            # Split vertices: choose the best format greedily given the
            # forced conv assignment is uniform — pick matched layout.
            idx = _split_pick(ch, policy)
        assignment[nid] = idx
    return pbqp.total_cost(assignment)


def _pick_for_policy(algos: List[Algorithm], policy: str) -> int:
    fams = [a.family for a in algos]
    if policy == "im2col":
        return fams.index(AlgoFamily.IM2COL)
    if policy == "kn2row":
        if AlgoFamily.KN2ROW in fams:
            return fams.index(AlgoFamily.KN2ROW)
        return fams.index(AlgoFamily.IM2COL)
    if policy == "winograd":
        if AlgoFamily.WINOGRAD in fams:
            return fams.index(AlgoFamily.WINOGRAD)
        return fams.index(AlgoFamily.IM2COL)
    raise ValueError(policy)


def _split_pick(ch: NodeChoices, policy: str) -> int:
    if not ch.labels or not ch.labels[0].startswith("store:"):
        return 0
    want = {"im2col": Layout.TOEPLITZ, "kn2row": Layout.TENSOR3D,
            "winograd": Layout.WINOGRAD}.get(policy, Layout.TENSOR3D)
    for i, a in enumerate(ch.algos):
        if a.input_layout is want:
            return i
    return 0
