"""Graph executor: runs a CNN under a DYNAMAP ExecutionPlan.

The central Computing Unit analogy holds here too: every conv dispatches to
the same overlay (``overlay.apply_conv``), only the per-layer binding —
algorithm wrapper plus dataflow/(p1, p2) GEMM blocks — differs (algorithm
and dataflow switching, §3). Because all three algorithms compute the same
convolution, executing under *any* plan must produce identical outputs —
that invariant is what the integration tests assert.

Two execution modes:

* ``forward`` — eager: Python walks the graph per call, dispatching each
  layer. Convenient for experiments; slow under traffic.
* ``compile_plan`` — the plan-compilation pipeline: graph topology and the
  plan's per-layer algorithm/dataflow choices are lowered to a static
  spec (``core.mapper.lower_plan``) and closed over at trace time, yielding
  ONE ``jax.jit``-compiled program per (graph, plan) with no Python dispatch
  on the hot path. The compiled program is batched: it accepts ``(H, W, C)``
  or ``(B, H, W, C)`` inputs, so it can serve batched traffic directly
  (see ``serving.cnn_engine.CNNServingEngine``). With ``mesh=`` the batch
  dimension additionally shards across a device mesh's data axes
  (params replicated) — same lowered program, multi-chip placement.

Compiled programs never close over params (weights are call arguments), so
they are shareable across models: ``ExecutableCache`` +
``compile_plan(..., cache=)`` key each executable by ``(graph hash, plan,
bucket, mesh, options)`` and hand multi-tenant engines the same compiled
body for every tenant that shares an architecture (see
``serving.multi_engine``).
"""
from __future__ import annotations

import hashlib
import json
import threading
import warnings
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.cnn import layers as L
from repro.cnn import overlay
from repro.core.algorithms import Algorithm, IM2COL
from repro.core.graph import Graph, LayerKind
from repro.core.layouts import LayoutSpec, is_nhwc
from repro.core.mapper import (ConvLowering, ExecutionPlan, LoweredProgram,
                               lower_plan, plan_fingerprint)
from repro.kernels.layouts import materialize, restore

Params = Dict[int, Dict[str, jax.Array]]
Lowering = Union[LoweredProgram, Dict[int, ConvLowering]]


# ---------------------------------------------------------------------------
# Shared executable cache (multi-tenant serving).
#
# Compiled programs close over (graph structure, plan, tuning winners,
# compile options) — params stay call arguments — so two *models* that share
# an architecture (same graph hash) can share every bucket executable even
# though their weights differ. ``ExecutableCache`` is that sharing, keyed by
# ``executable_cache_key``: (graph hash, plan fingerprint, bucket, mesh,
# remaining compile options). ``MultiModelEngine`` passes one cache to every
# tenant engine; the second tenant of an architecture compiles nothing.
# ---------------------------------------------------------------------------

def graph_hash(graph: Graph) -> str:
    """Stable structural hash of a CNN graph: layer kinds, conv signatures,
    non-conv attrs and edges — node *names* are display-only and excluded.
    Two independently built graphs with identical structure hash equal (the
    multi-tenant case: one architecture, many weight sets), and any
    structural difference — a channel count, a stride, an edge — changes
    the hash, so distinct models can never collide on a cache key."""
    h = hashlib.sha256()
    for nid in sorted(graph.nodes):
        node = graph.nodes[nid]
        c = node.conv
        conv_sig = ("-" if c is None else
                    f"{c.c_in}x{c.c_out}_{c.h1}x{c.h2}_{c.k1}x{c.k2}"
                    f"_s{c.stride}_{c.pad}")
        attrs = ";".join(f"{k}={node.attrs[k]!r}" for k in sorted(node.attrs))
        h.update(f"n{nid}|{node.kind.value}|{conv_sig}|{attrs}\n".encode())
    for src, dst in sorted(graph.edges):
        h.update(f"e{src}>{dst}\n".encode())
    return h.hexdigest()[:16]


# The plan's content fingerprint moved next to ExecutionPlan itself
# (core.mapper.plan_fingerprint) so the hot-swap supervisor can compare
# plans without importing the executor; the private alias survives for
# existing call sites.
_plan_fingerprint = plan_fingerprint


def _tuning_fingerprint(tuning) -> Optional[str]:
    """Content hash of a ``TuningRecord`` — records are keyed by conv
    signature, not by graph, so the same record object (or an equal reload
    of it) fingerprints equal and lets tenants share tuned executables."""
    if tuning is None:
        return None
    blob = json.dumps(tuning.to_json(), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _mesh_fingerprint(mesh):
    if mesh is None:
        return None
    return (tuple(str(a) for a in mesh.axis_names),
            tuple(int(s) for s in mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def executable_cache_key(graph: Graph, plan: Optional[ExecutionPlan] = None,
                         *, default_algo: Algorithm = IM2COL,
                         use_pallas: bool = False,
                         interpret: Optional[bool] = None,
                         epilogue: str = "relu",
                         tuning=None,
                         tuning_batch: Optional[int] = None,
                         avg_pool_via: str = "jnp",
                         elide: bool = True,
                         elide_overrides: Optional[Dict[Tuple[int, int],
                                                        bool]] = None,
                         mesh=None,
                         donate: bool = False,
                         act_scales: Optional[Dict[int, float]] = None
                         ) -> tuple:
    """The ``(graph hash, plan, bucket, mesh, options)`` identity of one
    compiled executable: everything ``compile_plan`` closes over EXCEPT
    params (call arguments — weights never key the cache) and
    ``fault_hook`` (a host-side wrapper applied outside the cache, so a
    fault-armed engine and a clean one still share the compiled body).
    The plan fingerprint carries per-layer precisions and ``act_scales``
    the calibrated activation scales, so an int8 plan and the bf16 plan of
    the same architecture can never collide on a key."""
    return (graph_hash(graph), _plan_fingerprint(plan), default_algo.key,
            bool(use_pallas), interpret, epilogue,
            _tuning_fingerprint(tuning), int(tuning_batch or 1),
            avg_pool_via, bool(elide),
            (None if elide_overrides is None
             else tuple(sorted(elide_overrides.items()))),
            _mesh_fingerprint(mesh), bool(donate),
            (None if act_scales is None
             else tuple(sorted((int(n), float(s))
                               for n, s in act_scales.items()))))


class ExecutableCache:
    """Process-wide cache of compiled overlay programs, shared across
    serving engines (the multi-tenant executable cache — ROADMAP's f-CNNx
    direction). ``get_or_compile`` returns the cached callable for a key or
    builds-and-stores it; hit/miss counters feed ``stats()`` and the
    ``bench_multi_model`` cross-model-reuse gate. Entries are never evicted
    — one entry per (architecture, plan, bucket, mesh, options) is exactly
    the working set a serving process needs resident.

    Thread-safe: the hot-swap supervisor compiles replacement bucket
    ladders on a background thread against the same cache the serving
    thread reads, so lookup-and-store runs under a lock (held across the
    build too — two threads racing on one key must not compile twice and
    publish different callables for it)."""

    def __init__(self) -> None:
        self._store: Dict[tuple, Callable] = {}
        self.hits = 0
        self.misses = 0
        self._lock = threading.RLock()

    def get_or_compile(self, key: tuple,
                       builder: Callable[[], Callable]) -> Callable:
        with self._lock:
            run = self._store.get(key)
            if run is not None:
                self.hits += 1
                return run
            self.misses += 1
            run = builder()
            self._store[key] = run
            return run

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: tuple) -> bool:
        return key in self._store

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._store), "hits": self.hits,
                "misses": self.misses}


class _Staged:
    """One node's output as staged for its consumers: the value in the
    edge's store format plus a lazily-restored NHWC view (computed at most
    once per producer, shared by every mismatched consumer — the split
    vertex materializes ONE format and fans it out)."""

    __slots__ = ("value", "spec", "_nhwc")

    def __init__(self, value: jax.Array,
                 spec: Optional[LayoutSpec] = None) -> None:
        self.value = value
        self.spec = None if is_nhwc(spec) else spec
        self._nhwc = value if self.spec is None else None

    def nhwc(self) -> jax.Array:
        if self._nhwc is None:
            self._nhwc = restore(self.value, self.spec)   # converting load
        return self._nhwc

    def in_layout(self, spec: Optional[LayoutSpec]) -> jax.Array:
        """The value as a consumer's ``in_layout`` expects it."""
        if is_nhwc(spec):
            return self.nhwc()
        if self.spec == spec:
            return self.value                             # matched load
        return materialize(self.nhwc(), spec)


def init_params(graph: Graph, key: jax.Array,
                dtype=jnp.float32, conv_bias: bool = True) -> Params:
    """Per-layer parameter pytree. Convs get a zero-initialized per-channel
    bias (``conv_bias=False`` reproduces the bias-free PR-2 layout) which
    the ``bias``/``bias_relu`` fused epilogues consume — so GoogleNet /
    Inception lower CONV+bias+ReLU to ONE overlay call per layer."""
    params: Params = {}
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        if node.kind is LayerKind.CONV:
            m = node.conv
            key, sub = jax.random.split(key)
            fan_in = m.k1 * m.k2 * m.c_in
            w = jax.random.normal(sub, (m.k1, m.k2, m.c_in, m.c_out),
                                  dtype) / jnp.sqrt(fan_in)
            params[nid] = {"w": w}
            if conv_bias:
                params[nid]["b"] = jnp.zeros((m.c_out,), dtype)
        elif node.kind is LayerKind.FC:
            key, sub = jax.random.split(key)
            fin = int(node.attrs["in_features"])
            fout = int(node.attrs["out_features"])
            params[nid] = {
                "w": jax.random.normal(sub, (fin, fout), dtype) / jnp.sqrt(fin),
                "b": jnp.zeros((fout,), dtype),
            }
    return params


def _eval_graph(graph: Graph, lowering: Lowering,
                params: Params, x: jax.Array,
                use_pallas: bool, interpret: Optional[bool],
                avg_pool_via: str = "jnp",
                conv_tap: Optional[Callable[[int, jax.Array], None]] = None
                ) -> jax.Array:
    """Walk the graph once; with ``x`` a tracer this IS the trace that
    ``compile_plan`` stages out — all dict lookups and dispatch below happen
    at trace time only.

    Inter-layer values travel in the store formats the ``LoweredProgram``
    realized from ``plan.store_formats``: a producer stages its edge's
    format once (conv layers fuse the conversion via ``out_layout``,
    non-conv producers materialize it here), matched consumers read it
    directly (``in_layout``), and mismatched consumers restore to NHWC —
    the Table 2 converting load. A plain ``{nid: ConvLowering}`` dict (no
    transitions) reproduces the layout-agnostic walk.

    ``conv_tap`` (calibration hook) is called with ``(nid, nhwc_input)``
    for every conv node — ``core.quant.calibrate_act_scales`` uses it to
    observe per-layer activation ranges on an eager f32 walk."""
    batched = x.ndim == 4
    store_specs: Dict[int, LayoutSpec] = getattr(lowering, "store_specs", {})
    values: Dict[int, _Staged] = {}

    def _stage(nid: int, y: jax.Array) -> None:
        """Stage a non-conv producer's NHWC output in its edge's format."""
        spec = store_specs.get(nid)
        values[nid] = _Staged(materialize(y, spec), spec)

    for nid in graph.topo_order():
        node = graph.nodes[nid]
        preds = graph.predecessors(nid)
        if node.kind is LayerKind.INPUT:
            _stage(nid, x)
            continue
        if node.kind is LayerKind.CONV:
            low = lowering[nid]
            m = node.conv
            pad = "SAME" if m.pad == "same" else "VALID"
            epi = low.epilogue
            bias = params[nid].get("b") if epi.startswith("bias") else None
            if epi.startswith("bias") and bias is None:
                # Bias-free legacy params under a bias-carrying lowering:
                # degrade to the bias-less epilogue (conv math unchanged).
                epi = "relu" if epi.endswith("relu") else "none"
            in_layout = getattr(low, "in_layout", None)
            out_layout = getattr(low, "out_layout", None)
            if conv_tap is not None:
                conv_tap(nid, values[preds[0]].nhwc())
            xin = values[preds[0]].in_layout(in_layout)
            y = overlay.apply_conv(xin, params[nid]["w"], low.algo,
                                   low.dataflow, low.p1, low.p2,
                                   stride=m.stride, padding=pad,
                                   use_pallas=use_pallas,
                                   backend=(None if low.backend == "auto"
                                            else low.backend),
                                   interpret=interpret,
                                   epilogue=epi, bias=bias,
                                   in_layout=in_layout,
                                   out_layout=out_layout,
                                   precision=getattr(low, "precision",
                                                     "bf16"),
                                   in_scale=getattr(low, "in_scale", None),
                                   out_scale=getattr(low, "out_scale", None),
                                   in_quantized=getattr(low, "in_quantized",
                                                        False))
            if not epi.endswith("relu"):
                # The graph semantics are CONV→ReLU; a relu-carrying
                # epilogue already ran it inside the overlay call — ONE
                # call, fused. ReLU commutes with the (linear-gather)
                # store formats, so an unfused ReLU applies to the staged
                # value directly.
                y = L.relu(y)
            values[nid] = _Staged(y, out_layout)
            continue
        # Non-conv consumers read the 3-D tensor; restored here only when
        # a predecessor staged a non-NHWC format (the converting load) —
        # conv consumers above never touch this view.
        ins = [values[p].nhwc() for p in preds]
        if node.kind is LayerKind.POOL_MAX:
            pad = "SAME" if node.attrs.get("pad", "same") == "same" else "VALID"
            y = L.max_pool(ins[0], int(node.attrs["k"]),
                           int(node.attrs["stride"]), pad)
        elif node.kind is LayerKind.POOL_AVG:
            pad = "SAME" if node.attrs.get("pad", "same") == "same" else "VALID"
            y = L.avg_pool(ins[0], int(node.attrs["k"]),
                           int(node.attrs["stride"]), pad,
                           via=avg_pool_via,
                           use_pallas=use_pallas,
                           interpret=interpret)
        elif node.kind is LayerKind.CONCAT:
            y = jnp.concatenate(ins, axis=-1)
        elif node.kind is LayerKind.ADD:
            y = L.relu(sum(ins))
        elif node.kind is LayerKind.GLOBAL_POOL:
            gap = L.global_avg_pool(ins[0])          # (C,) or (B, C)
            y = (gap[:, None, None, :] if batched
                 else gap[None, None, :])
        elif node.kind is LayerKind.FC:
            flat = (ins[0].reshape(ins[0].shape[0], -1) if batched
                    else ins[0].reshape(-1))
            y = L.fc(flat, params[nid]["w"], params[nid]["b"])
        elif node.kind is LayerKind.SOFTMAX:
            y = jax.nn.softmax(ins[0])
        elif node.kind is LayerKind.OUTPUT:
            y = ins[0]
        else:
            raise ValueError(f"unhandled node kind {node.kind}")
        _stage(nid, y)
    return values[graph.sink()].nhwc()


def forward(graph: Graph, params: Params,
            x: jax.Array, plan: Optional[ExecutionPlan] = None,
            default_algo: Algorithm = IM2COL,
            use_pallas: bool = False,
            interpret: Optional[bool] = None,
            epilogue: str = "relu",
            tuning=None,
            tuning_batch: Optional[int] = None,
            elide: bool = True,
            elide_overrides: Optional[Dict[Tuple[int, int], bool]] = None,
            act_scales: Optional[Dict[int, float]] = None,
            conv_tap: Optional[Callable[[int, jax.Array], None]] = None
            ) -> jax.Array:
    """Eager inference. ``x``: (H, W, C) single image (the paper's no-batch
    low-latency setting) or (B, H, W, C) batch. Each call re-interprets the
    plan in Python — use ``compile_plan`` for the dispatch-free hot path.
    ``act_scales`` supplies calibrated activation scales for int8 layers;
    ``conv_tap(nid, nhwc_input)`` observes every conv input (calibration)."""
    lowering = lower_plan(graph, plan, default_algo,
                          epilogue=epilogue, tuning=tuning,
                          batch=tuning_batch, elide=elide,
                          elide_overrides=elide_overrides,
                          act_scales=act_scales)
    return _eval_graph(graph, lowering, params, x, use_pallas, interpret,
                       conv_tap=conv_tap)


def compile_plan(graph: Graph, plan: Optional[ExecutionPlan] = None,
                 default_algo: Algorithm = IM2COL,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 epilogue: str = "relu",
                 tuning=None,
                 tuning_batch: Optional[int] = None,
                 avg_pool_via: str = "jnp",
                 elide: bool = True,
                 elide_overrides: Optional[Dict[Tuple[int, int], bool]] = None,
                 mesh=None,
                 donate: bool = False,
                 fault_hook: Optional[Callable[[], None]] = None,
                 cache: Optional[ExecutableCache] = None,
                 act_scales: Optional[Dict[int, float]] = None,
                 ) -> Callable[[Params, jax.Array], jax.Array]:
    """Lower (graph, plan) into one jit-compiled overlay program.

    Returns ``run(params, x) -> logits`` with ``x``: (H, W, C) or
    (B, H, W, C). The graph topology, every per-layer algorithm and
    dataflow/(p1, p2) block binding, AND every edge's DRAM store format
    (``plan.store_formats``, realized as ``LayoutTransition`` specs) are
    resolved *now* into a static ``LoweredProgram`` and closed over, so the
    traced program contains no Python dispatch; XLA sees the whole network
    and can fuse across layers. With ``elide=True`` (default) consumers
    read matching store formats directly — back-to-back Winograd layers
    stay in the scattered tile domain, im2col chains reuse the Toeplitz
    buffer — and the NHWC round trip survives only where layouts disagree;
    ``elide=False`` compiles the layout-agnostic always-round-trip baseline
    (kept for benchmarking); ``elide_overrides`` flips individual edges.
    One compilation is cached per input shape/dtype (batch sizes compile
    once each — pad to a fixed batch to avoid recompilation, as
    ``CNNServingEngine`` does).

    ``epilogue="relu"`` (the default) fuses each CONV's trailing ReLU into
    its overlay call; ``epilogue="none"`` reproduces the PR-1 unfused
    conv-then-relu lowering (kept for benchmarking). A ``tuning`` record
    from ``core.autotune`` replaces cost-model bindings with measured
    winners, including per-layer pallas/reference backend selection inside
    this single compiled program; ``tuning_batch`` picks the batch bucket
    whose measured winners bind this executable (None → bucket 1), so a
    bucketed serving engine compiles one program per bucket, each under the
    bindings measured at that batch size. ``avg_pool_via="overlay"`` routes
    AvgPool layers through the overlay's GEMM unit (§3.4) instead of the
    jnp reduce-window.

    ``mesh`` (a ``jax.sharding.Mesh``) turns on data-parallel multi-chip
    execution: the batch dimension of ``x`` is placed on the mesh's data
    axes (``distributed.sharding.data_axes`` — a ``("data",)`` mesh from
    ``launch.mesh.make_data_mesh`` in the common case) via
    ``NamedSharding``/``PartitionSpec`` and params are replicated, so every
    chip runs the SAME lowered overlay program on its batch shard — the
    algorithm/layout mapping is untouched; only placement changes, which is
    why sharding composes with tuning, epilogues and layout elision for
    free. Data-parallel conv inference needs no collectives, so scaling is
    communication-free up to the output gather. The returned callable then
    requires batched ``(B, H, W, C)`` input with ``B`` divisible by the
    data-shard count (jit rejects uneven input partitions); callers keeping
    params on-device should pre-place them replicated (as
    ``CNNServingEngine`` does) so the hot path never re-transfers them.

    ``donate=True`` threads ``jax.jit(..., donate_argnums=)`` for the
    batched input ``x``: XLA may reuse its device buffer for outputs and
    intermediates, so a serving loop that re-stages every tick from host
    memory (as the pipelined ``CNNServingEngine`` does) holds a constant
    device footprint across ticks instead of one live input buffer per
    in-flight dispatch. The donated argument is consumed by the call —
    never pass a ``jax.Array`` you still need afterwards (host numpy
    staging buffers are safe: the transfer makes a fresh device copy, and
    only that copy is donated). Donation composes with ``mesh=``: the
    input's ``NamedSharding`` pins placement, donation only allows
    aliasing of the per-chip buffers.

    ``fault_hook`` (robustness testing) is a zero-arg callable invoked on
    the host before EVERY invocation of the compiled program — never at
    compile time, never inside the traced computation. It may raise (an
    injected dispatch failure, e.g. ``distributed.fault.DeviceFault``) or
    sleep (a straggling launch path); the math is untouched, so a hooked
    executable's outputs stay bitwise identical to an unhooked one.
    ``CNNServingEngine(fault_plan=...)`` threads its per-tick fault
    schedule through this hook and wraps the call in a bounded
    retry-with-backoff loop; ``fault_hook=None`` (default) adds no
    wrapper at all.

    ``cache`` (an ``ExecutableCache``) makes compilation shared: the call
    first looks up ``executable_cache_key(...)`` — (graph hash, plan
    fingerprint, bucket, mesh, compile options; params and ``fault_hook``
    excluded) — and only compiles on a miss. Two models with the same
    architecture (equal ``graph_hash``) under the same plan/tuning/options
    therefore share ONE compiled program per bucket; the fault hook is
    wrapped *around* the cached body, so fault-armed and clean engines
    share too. ``cache=None`` (default) compiles unconditionally.

    ``act_scales`` ({conv node id: activation scale}, from
    ``core.quant.calibrate_act_scales``) feeds the plan's int8 layers their
    calibrated per-tensor input scales; it enters the cache key, so plans
    differing only in calibration compile separately. A plan with no int8
    layers ignores it.
    """
    if cache is not None:
        key = executable_cache_key(
            graph, plan, default_algo=default_algo, use_pallas=use_pallas,
            interpret=interpret, epilogue=epilogue, tuning=tuning,
            tuning_batch=tuning_batch, avg_pool_via=avg_pool_via,
            elide=elide, elide_overrides=elide_overrides, mesh=mesh,
            donate=donate, act_scales=act_scales)
        base = cache.get_or_compile(key, lambda: _compile_plan_base(
            graph, plan, default_algo=default_algo, use_pallas=use_pallas,
            interpret=interpret, epilogue=epilogue, tuning=tuning,
            tuning_batch=tuning_batch, avg_pool_via=avg_pool_via,
            elide=elide, elide_overrides=elide_overrides, mesh=mesh,
            donate=donate, act_scales=act_scales))
        return _with_fault_hook(base, fault_hook)
    return _with_fault_hook(
        _compile_plan_base(graph, plan, default_algo=default_algo,
                           use_pallas=use_pallas, interpret=interpret,
                           epilogue=epilogue, tuning=tuning,
                           tuning_batch=tuning_batch,
                           avg_pool_via=avg_pool_via, elide=elide,
                           elide_overrides=elide_overrides, mesh=mesh,
                           donate=donate, act_scales=act_scales),
        fault_hook)


def _compile_plan_base(graph: Graph, plan: Optional[ExecutionPlan], *,
                       default_algo: Algorithm, use_pallas: bool,
                       interpret: Optional[bool], epilogue: str,
                       tuning, tuning_batch: Optional[int],
                       avg_pool_via: str, elide: bool,
                       elide_overrides: Optional[Dict[Tuple[int, int], bool]],
                       mesh, donate: bool,
                       act_scales: Optional[Dict[int, float]] = None
                       ) -> Callable[[Params, jax.Array], jax.Array]:
    """The hookless compile body ``compile_plan`` caches: lower, trace,
    jit, (optionally) shard — everything except the per-engine fault-hook
    wrapper, which must never be shared between engines."""
    lowering = lower_plan(graph, plan, default_algo,
                          epilogue=epilogue, tuning=tuning,
                          batch=tuning_batch, elide=elide,
                          elide_overrides=elide_overrides,
                          act_scales=act_scales)
    donate_argnums = (1,) if donate else ()

    def _run(params: Params, x: jax.Array) -> jax.Array:
        return _eval_graph(graph, lowering, params, x, use_pallas, interpret,
                           avg_pool_via)

    if mesh is None:
        return _quiet_donation(jax.jit(_run, donate_argnums=donate_argnums),
                               donate)

    from repro.distributed.sharding import (batch_input_sharding,
                                            data_shard_count, replicated)
    n_shards = data_shard_count(mesh)
    x_sharding = batch_input_sharding(mesh)
    jitted = jax.jit(_run, in_shardings=(replicated(mesh), x_sharding),
                     donate_argnums=donate_argnums)

    jitted = _quiet_donation(jitted, donate)

    def run(params: Params, x: jax.Array) -> jax.Array:
        if x.ndim != 4:
            raise ValueError(
                "mesh-sharded compiled plans take batched (B, H, W, C) "
                f"input; got shape {tuple(x.shape)}")
        if x.shape[0] % n_shards:
            raise ValueError(
                f"batch {x.shape[0]} does not divide across "
                f"{n_shards} data shards — pad to a multiple (the serving "
                "engine's sharded bucket ladder guarantees this)")
        return jitted(params, x)

    run.mesh = mesh
    run.data_shards = n_shards
    return run


def _with_fault_hook(run: Callable, fault_hook: Optional[Callable[[], None]]
                     ) -> Callable:
    """Outermost wrapper: call ``fault_hook()`` before each invocation so
    injected dispatch faults/delays surface exactly where a real device
    error would — at the call, after any mesh validation wrapper built
    the arguments. No hook, no wrapper (the common path stays
    unchanged)."""
    if fault_hook is None:
        return run

    def hooked(params: Params, x: jax.Array) -> jax.Array:
        fault_hook()
        return run(params, x)

    for attr in ("mesh", "data_shards"):
        if hasattr(run, attr):
            setattr(hooked, attr, getattr(run, attr))
    return hooked


def _quiet_donation(jitted: Callable, donate: bool) -> Callable:
    """Donation is an *allowance*: when no output of the program can alias
    the donated input (a CNN's logits never match the image shape), XLA
    ignores it and jax emits an advisory UserWarning at compile time.
    That is the expected outcome on such programs — donation still pays
    off wherever an intermediate or output CAN take the buffer (and on
    runtimes that reuse donated space for temporaries) — so the advisory
    is suppressed for donated executables rather than logged once per
    bucket compile."""
    if not donate:
        return jitted

    def run(params: Params, x: jax.Array) -> jax.Array:
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return jitted(params, x)

    return run
