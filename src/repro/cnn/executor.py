"""Graph executor: runs a CNN under a DYNAMAP ExecutionPlan.

The central Computing Unit analogy holds here too: every conv dispatches to
the same GEMM machinery, only the algorithm wrapper differs per layer
(algorithm switching, §3). Because all three algorithms compute the same
convolution, executing under *any* plan must produce identical outputs —
that invariant is what the integration tests assert.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.cnn import layers as L
from repro.core.algorithms import Algorithm, IM2COL
from repro.core.graph import Graph, LayerKind
from repro.core.mapper import ExecutionPlan


def init_params(graph: Graph, key: jax.Array,
                dtype=jnp.float32) -> Dict[int, Dict[str, jax.Array]]:
    params: Dict[int, Dict[str, jax.Array]] = {}
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        if node.kind is LayerKind.CONV:
            m = node.conv
            key, sub = jax.random.split(key)
            fan_in = m.k1 * m.k2 * m.c_in
            w = jax.random.normal(sub, (m.k1, m.k2, m.c_in, m.c_out),
                                  dtype) / jnp.sqrt(fan_in)
            params[nid] = {"w": w}
        elif node.kind is LayerKind.FC:
            key, sub = jax.random.split(key)
            fin = int(node.attrs["in_features"])
            fout = int(node.attrs["out_features"])
            params[nid] = {
                "w": jax.random.normal(sub, (fin, fout), dtype) / jnp.sqrt(fin),
                "b": jnp.zeros((fout,), dtype),
            }
    return params


def forward(graph: Graph, params: Dict[int, Dict[str, jax.Array]],
            x: jax.Array, plan: Optional[ExecutionPlan] = None,
            default_algo: Algorithm = IM2COL,
            use_pallas: bool = False,
            interpret: Optional[bool] = None) -> jax.Array:
    """Run inference. ``x``: (H, W, C) single image (the paper's no-batch
    low-latency setting)."""
    values: Dict[int, jax.Array] = {}
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        preds = graph.predecessors(nid)
        if node.kind is LayerKind.INPUT:
            values[nid] = x
            continue
        ins = [values[p] for p in preds]
        if node.kind is LayerKind.CONV:
            algo = (plan.assignment.get(nid, default_algo) if plan
                    else default_algo)
            m = node.conv
            pad = "SAME" if m.pad == "same" else "VALID"
            y = L.conv2d(ins[0], params[nid]["w"], algo, stride=m.stride,
                         padding=pad, use_pallas=use_pallas,
                         interpret=interpret)
            values[nid] = L.relu(y)
        elif node.kind is LayerKind.POOL_MAX:
            pad = "SAME" if node.attrs.get("pad", "same") == "same" else "VALID"
            values[nid] = L.max_pool(ins[0], int(node.attrs["k"]),
                                     int(node.attrs["stride"]), pad)
        elif node.kind is LayerKind.POOL_AVG:
            pad = "SAME" if node.attrs.get("pad", "same") == "same" else "VALID"
            values[nid] = L.avg_pool(ins[0], int(node.attrs["k"]),
                                     int(node.attrs["stride"]), pad)
        elif node.kind is LayerKind.CONCAT:
            values[nid] = jnp.concatenate(ins, axis=-1)
        elif node.kind is LayerKind.ADD:
            values[nid] = L.relu(sum(ins))
        elif node.kind is LayerKind.GLOBAL_POOL:
            values[nid] = L.global_avg_pool(ins[0])[None, None, :]
        elif node.kind is LayerKind.FC:
            values[nid] = L.fc(ins[0], params[nid]["w"], params[nid]["b"])
        elif node.kind is LayerKind.SOFTMAX:
            values[nid] = jax.nn.softmax(ins[0])
        elif node.kind is LayerKind.OUTPUT:
            values[nid] = ins[0]
        else:
            raise ValueError(f"unhandled node kind {node.kind}")
    return values[graph.sink()]
