"""Graph executor: runs a CNN under a DYNAMAP ExecutionPlan.

The central Computing Unit analogy holds here too: every conv dispatches to
the same overlay (``overlay.apply_conv``), only the per-layer binding —
algorithm wrapper plus dataflow/(p1, p2) GEMM blocks — differs (algorithm
and dataflow switching, §3). Because all three algorithms compute the same
convolution, executing under *any* plan must produce identical outputs —
that invariant is what the integration tests assert.

Two execution modes:

* ``forward`` — eager: Python walks the graph per call, dispatching each
  layer. Convenient for experiments; slow under traffic.
* ``compile_plan`` — the plan-compilation pipeline: graph topology and the
  plan's per-layer algorithm/dataflow choices are lowered to a static
  spec (``core.mapper.lower_plan``) and closed over at trace time, yielding
  ONE ``jax.jit``-compiled program per (graph, plan) with no Python dispatch
  on the hot path. The compiled program is batched: it accepts ``(H, W, C)``
  or ``(B, H, W, C)`` inputs, so it can serve batched traffic directly
  (see ``serving.cnn_engine.CNNServingEngine``).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.cnn import layers as L
from repro.cnn import overlay
from repro.core.algorithms import Algorithm, IM2COL
from repro.core.graph import Graph, LayerKind
from repro.core.mapper import ConvLowering, ExecutionPlan, lower_plan

Params = Dict[int, Dict[str, jax.Array]]


def init_params(graph: Graph, key: jax.Array,
                dtype=jnp.float32, conv_bias: bool = True) -> Params:
    """Per-layer parameter pytree. Convs get a zero-initialized per-channel
    bias (``conv_bias=False`` reproduces the bias-free PR-2 layout) which
    the ``bias``/``bias_relu`` fused epilogues consume — so GoogleNet /
    Inception lower CONV+bias+ReLU to ONE overlay call per layer."""
    params: Params = {}
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        if node.kind is LayerKind.CONV:
            m = node.conv
            key, sub = jax.random.split(key)
            fan_in = m.k1 * m.k2 * m.c_in
            w = jax.random.normal(sub, (m.k1, m.k2, m.c_in, m.c_out),
                                  dtype) / jnp.sqrt(fan_in)
            params[nid] = {"w": w}
            if conv_bias:
                params[nid]["b"] = jnp.zeros((m.c_out,), dtype)
        elif node.kind is LayerKind.FC:
            key, sub = jax.random.split(key)
            fin = int(node.attrs["in_features"])
            fout = int(node.attrs["out_features"])
            params[nid] = {
                "w": jax.random.normal(sub, (fin, fout), dtype) / jnp.sqrt(fin),
                "b": jnp.zeros((fout,), dtype),
            }
    return params


def _eval_graph(graph: Graph, lowering: Dict[int, ConvLowering],
                params: Params, x: jax.Array,
                use_pallas: bool, interpret: Optional[bool],
                avg_pool_via: str = "jnp") -> jax.Array:
    """Walk the graph once; with ``x`` a tracer this IS the trace that
    ``compile_plan`` stages out — all dict lookups and dispatch below happen
    at trace time only."""
    batched = x.ndim == 4
    values: Dict[int, jax.Array] = {}
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        preds = graph.predecessors(nid)
        if node.kind is LayerKind.INPUT:
            values[nid] = x
            continue
        ins = [values[p] for p in preds]
        if node.kind is LayerKind.CONV:
            low = lowering[nid]
            m = node.conv
            pad = "SAME" if m.pad == "same" else "VALID"
            epi = low.epilogue
            bias = params[nid].get("b") if epi.startswith("bias") else None
            if epi.startswith("bias") and bias is None:
                # Bias-free legacy params under a bias-carrying lowering:
                # degrade to the bias-less epilogue (conv math unchanged).
                epi = "relu" if epi.endswith("relu") else "none"
            y = overlay.apply_conv(ins[0], params[nid]["w"], low.algo,
                                   low.dataflow, low.p1, low.p2,
                                   stride=m.stride, padding=pad,
                                   use_pallas=use_pallas,
                                   backend=(None if low.backend == "auto"
                                            else low.backend),
                                   interpret=interpret,
                                   epilogue=epi, bias=bias)
            # The graph semantics are CONV→ReLU; a relu-carrying epilogue
            # already ran it inside the overlay call — ONE call, fused.
            values[nid] = y if epi.endswith("relu") else L.relu(y)
        elif node.kind is LayerKind.POOL_MAX:
            pad = "SAME" if node.attrs.get("pad", "same") == "same" else "VALID"
            values[nid] = L.max_pool(ins[0], int(node.attrs["k"]),
                                     int(node.attrs["stride"]), pad)
        elif node.kind is LayerKind.POOL_AVG:
            pad = "SAME" if node.attrs.get("pad", "same") == "same" else "VALID"
            values[nid] = L.avg_pool(ins[0], int(node.attrs["k"]),
                                     int(node.attrs["stride"]), pad,
                                     via=avg_pool_via,
                                     use_pallas=use_pallas,
                                     interpret=interpret)
        elif node.kind is LayerKind.CONCAT:
            values[nid] = jnp.concatenate(ins, axis=-1)
        elif node.kind is LayerKind.ADD:
            values[nid] = L.relu(sum(ins))
        elif node.kind is LayerKind.GLOBAL_POOL:
            gap = L.global_avg_pool(ins[0])          # (C,) or (B, C)
            values[nid] = (gap[:, None, None, :] if batched
                           else gap[None, None, :])
        elif node.kind is LayerKind.FC:
            flat = (ins[0].reshape(ins[0].shape[0], -1) if batched
                    else ins[0].reshape(-1))
            values[nid] = L.fc(flat, params[nid]["w"], params[nid]["b"])
        elif node.kind is LayerKind.SOFTMAX:
            values[nid] = jax.nn.softmax(ins[0])
        elif node.kind is LayerKind.OUTPUT:
            values[nid] = ins[0]
        else:
            raise ValueError(f"unhandled node kind {node.kind}")
    return values[graph.sink()]


def forward(graph: Graph, params: Params,
            x: jax.Array, plan: Optional[ExecutionPlan] = None,
            default_algo: Algorithm = IM2COL,
            use_pallas: bool = False,
            interpret: Optional[bool] = None,
            epilogue: str = "relu",
            tuning=None,
            tuning_batch: Optional[int] = None) -> jax.Array:
    """Eager inference. ``x``: (H, W, C) single image (the paper's no-batch
    low-latency setting) or (B, H, W, C) batch. Each call re-interprets the
    plan in Python — use ``compile_plan`` for the dispatch-free hot path."""
    lowering = lower_plan(graph, plan, default_algo,
                          epilogue=epilogue, tuning=tuning,
                          batch=tuning_batch)
    return _eval_graph(graph, lowering, params, x, use_pallas, interpret)


def compile_plan(graph: Graph, plan: Optional[ExecutionPlan] = None,
                 default_algo: Algorithm = IM2COL,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 epilogue: str = "relu",
                 tuning=None,
                 tuning_batch: Optional[int] = None,
                 avg_pool_via: str = "jnp"
                 ) -> Callable[[Params, jax.Array], jax.Array]:
    """Lower (graph, plan) into one jit-compiled overlay program.

    Returns ``run(params, x) -> logits`` with ``x``: (H, W, C) or
    (B, H, W, C). The graph topology and every per-layer algorithm and
    dataflow/(p1, p2) block binding are resolved *now* into a static
    ``ConvLowering`` spec and closed over, so the traced program contains
    no Python dispatch; XLA sees the whole network and can fuse across
    layers. (``plan.store_formats`` stays cost-model-only for now — see
    ROADMAP.) One compilation is cached per input shape/dtype (batch sizes
    compile once each — pad to a fixed batch to avoid recompilation, as
    ``CNNServingEngine`` does).

    ``epilogue="relu"`` (the default) fuses each CONV's trailing ReLU into
    its overlay call; ``epilogue="none"`` reproduces the PR-1 unfused
    conv-then-relu lowering (kept for benchmarking). A ``tuning`` record
    from ``core.autotune`` replaces cost-model bindings with measured
    winners, including per-layer pallas/reference backend selection inside
    this single compiled program; ``tuning_batch`` picks the batch bucket
    whose measured winners bind this executable (None → bucket 1), so a
    bucketed serving engine compiles one program per bucket, each under the
    bindings measured at that batch size. ``avg_pool_via="overlay"`` routes
    AvgPool layers through the overlay's GEMM unit (§3.4) instead of the
    jnp reduce-window.
    """
    lowering = lower_plan(graph, plan, default_algo,
                          epilogue=epilogue, tuning=tuning,
                          batch=tuning_batch)

    @jax.jit
    def run(params: Params, x: jax.Array) -> jax.Array:
        return _eval_graph(graph, lowering, params, x, use_pallas, interpret,
                           avg_pool_via)

    return run
