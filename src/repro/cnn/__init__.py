"""CNN substrate: Computing Unit overlay, executable layers, model-graph
builders, eager executor + plan compiler."""
from repro.cnn.executor import (ExecutableCache, compile_plan, forward,
                                graph_hash, init_params)
from repro.cnn.models import (MODELS, alexnet, googlenet, inception_v4,
                              resnet18, vgg16)
from repro.cnn.overlay import apply_conv
