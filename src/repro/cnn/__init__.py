"""CNN substrate: executable layers, model-graph builders, executor."""
from repro.cnn.executor import forward, init_params
from repro.cnn.models import (MODELS, alexnet, googlenet, inception_v4,
                              resnet18, vgg16)
