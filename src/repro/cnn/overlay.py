"""The DYNAMAP Computing Unit overlay — single entry point for every conv.

The paper's §3 overlay is one GEMM engine reused by all layers; per layer
only the *algorithm wrapper* (im2col / kn2row / Winograd) and the *dataflow
binding* of the (P_SA1, P_SA2) array dims change. ``apply_conv`` is that
unit in software: it takes the plan's per-layer ``(algo, dataflow, p1, p2)``
and routes the convolution through the dataflow-bound GEMM blocks in
``kernels/gemm`` (Pallas path) or the pure-jnp oracles (reference path).

Layout semantics (§3.3, Table 2): ``in_layout``/``out_layout`` carry the
plan's DRAM store formats. A matched ``in_layout`` means ``x`` arrives in
the layer's own input layout (its Toeplitz matrix, or its scattered
Winograd tiles) — the matched streaming load, no re-gather; a non-NHWC
``out_layout`` makes the call emit its consumer's store format (the
store-side conversion fused into the producing layer). Backends that
cannot consume a layout directly (``lax``; mismatched specs) restore to
NHWC first — the converting load — so every (backend, layout) combination
computes the same function.

Batching semantics: every path accepts a single sample or a batch with one
leading dim and returns the matching rank; the un-batched rank follows the
layout (NHWC 3, Toeplitz 2, Winograd tiles 4). The Pallas kernels batch
through ``pallas_call``'s batching rule (an outer grid dimension), so the
compiled overlay program serves batched traffic without Python dispatch.

``compile_plan`` (executor.py) closes over these per-layer bindings at trace
time; tests monkeypatch this module's ``apply_conv`` to observe exactly
which (algorithm, dataflow, layouts) each layer was lowered with — wrap a
plain NHWC oracle with ``nhwc_conv`` so it honors the layout contract.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.core.algorithms import Algorithm, AlgoFamily
from repro.core.cost_model import Dataflow
from repro.core.layouts import LayoutSpec, is_nhwc
from repro.kernels.common import (PRECISIONS, apply_epilogue, dequantize,
                                  quantize, requantize, weight_scales)
from repro.kernels.conv_im2col.ops import conv_im2col
from repro.kernels.conv_im2col.ref import (conv_from_toeplitz_ref, conv_ref,
                                           conv_via_toeplitz_ref)
from repro.kernels.kn2row.ops import conv_kn2row
from repro.kernels.kn2row.ref import kn2row_ref
from repro.kernels.layouts import materialize, restore
from repro.kernels.winograd.ops import conv_winograd
from repro.kernels.winograd.ref import winograd_from_tiles_ref, winograd_ref


def nhwc_conv(fn):
    """Adapt a plain NHWC conv ``fn(x, w, ...)`` to the overlay's
    layout-carrying call contract: restore a non-NHWC input, materialize a
    requested output format. Reference executors (and tests that
    monkeypatch ``apply_conv`` with an oracle) wrap with this so a
    layout-aware compiled plan can still be replayed against them."""
    @functools.wraps(fn)
    def wrapper(x, w, *args, in_layout=None, out_layout=None, **kw):
        y = fn(restore(x, in_layout), w, *args, **kw)
        return materialize(y, out_layout)
    return wrapper


def apply_conv(x: jax.Array, w: jax.Array, algo: Algorithm,
               dataflow: Dataflow = Dataflow.NS,
               p1: int = 128, p2: int = 128, *,
               stride: int = 1, padding: str = "SAME",
               use_pallas: bool = False,
               backend: Optional[str] = None,
               interpret: Optional[bool] = None,
               epilogue: str = "none",
               bias: Optional[jax.Array] = None,
               in_layout: Optional[LayoutSpec] = None,
               out_layout: Optional[LayoutSpec] = None,
               precision: str = "bf16",
               in_scale: Optional[float] = None,
               out_scale: Optional[float] = None,
               in_quantized: bool = False) -> jax.Array:
    """Run one conv layer on the overlay under a plan binding.

    x: the layer input in ``in_layout`` (default NHWC): (H, W, Cin) /
    (B, H, W, Cin), a Toeplitz matrix (O1O2, K1K2·Cin), or Winograd tiles
    (tiles, T, T, Cin); w: (K1, K2, Cin, Cout). ``dataflow``/(p1, p2)
    select the Eq. 9 GEMM block binding — they only shape the Pallas
    execution schedule, never the math, so any binding produces identical
    outputs (the §3 invariant the tests assert); the same holds for every
    layout combination.

    ``backend`` (when given) overrides ``use_pallas``: "pallas" runs the
    Pallas kernels, "reference" the per-algorithm jnp oracles, and "lax"
    XLA's native spatial convolution — the "tiny convs via jnp" leg of a
    mixed-backend plan, and the strongest conv this host's XLA can emit
    (the autotuner measures it against the overlay algorithms per layer).

    ``epilogue`` ("none" | "relu" | "bias" | "bias_relu") streams the conv
    output through the §3 in-pipeline auxiliary units: on the Pallas path it
    fuses into the kernel's output flush (no DRAM round trip); the jnp
    reference/lax paths apply it post-hoc (XLA fuses it there) so every
    backend computes the same function — CONV+ReLU is ONE overlay call
    either way.

    ``precision`` ("bf16" | "int8") selects the quantized overlay path:
    int8 layers quantize their weights per-output-channel in-trace and
    their input per-tensor at the calibrated static ``in_scale`` (skipped
    when ``in_quantized`` says the producer already emitted int8 at this
    layer's scale — the fused precision edge), accumulate in int32, and
    fuse dequant+bias+relu(+``out_scale`` requant) into the kernel flush.
    Winograd layers reject int8 (the transforms amplify quantization
    error; the mapper never assigns it). Non-Pallas backends emulate int8
    with fake-quantized f32 operands — same quantization error, so the
    accuracy gate can measure on any backend.
    """
    in_layout = None if is_nhwc(in_layout) else in_layout
    out_layout = None if is_nhwc(out_layout) else out_layout
    if backend is not None and backend not in ("lax", "pallas", "reference"):
        raise ValueError(f"unknown backend {backend!r}")
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; want {PRECISIONS}")
    quant_kw = {}
    post_requant = None
    if precision == "int8":
        if algo.family is AlgoFamily.WINOGRAD:
            raise ValueError("Winograd is bf16-only: its input/output "
                             "transforms amplify quantization error")
        if in_scale is None:
            raise ValueError("int8 precision needs a calibrated in_scale")
        w_scale = weight_scales(w)
        use_p = use_pallas if backend is None else backend == "pallas"
        if use_p:
            # True int8 kernels: quantized operands, int32 accumulation,
            # dequant/requant fused into the epilogue flush. NHWC and
            # Toeplitz inputs hold raw activations, so quantization
            # commutes with the layout; anything else (e.g. a Winograd
            # store format holding transformed tiles) restores first.
            if not in_quantized:
                if in_layout is not None and in_layout.kind != "toeplitz":
                    x, in_layout = restore(x, in_layout), None
                x = quantize(x, in_scale)
            w = quantize(w, w_scale)
            quant_kw = dict(scale=in_scale * w_scale, out_scale=out_scale)
        else:
            # Fake-quant emulation (lax / reference): dequantized f32
            # operands carry the identical quantization error.
            if in_quantized:
                x = dequantize(x, in_scale)
            else:
                if in_layout is not None and in_layout.kind != "toeplitz":
                    x, in_layout = restore(x, in_layout), None
                x = dequantize(quantize(x, in_scale), in_scale)
            w = dequantize(quantize(w, w_scale), w_scale)
            post_requant = out_scale
    if backend == "lax":
        # XLA's conv wants spatial NHWC: converting load + store.
        y = apply_epilogue(
            conv_ref(restore(x, in_layout), w,
                     stride=stride, padding=padding),
            epilogue, bias)
        y = materialize(y, out_layout)
        return requantize(y, post_requant) if post_requant else y
    if backend is not None:
        use_pallas = backend == "pallas"
    fam = algo.family
    if fam is AlgoFamily.IM2COL:
        if use_pallas:
            return conv_im2col(x, w, stride=stride, padding=padding,
                               dataflow=dataflow, p1=p1, p2=p2,
                               interpret=interpret,
                               epilogue=epilogue, bias=bias,
                               in_layout=in_layout, out_layout=out_layout,
                               **quant_kw)
        if in_layout is not None and in_layout.kind == "toeplitz":
            y = apply_epilogue(
                conv_from_toeplitz_ref(x, w, in_layout.o1, in_layout.o2),
                epilogue, bias)
            y = materialize(y, out_layout)
            return requantize(y, post_requant) if post_requant else y
        y = apply_epilogue(
            conv_via_toeplitz_ref(restore(x, in_layout), w,
                                  stride=stride, padding=padding),
            epilogue, bias)
        y = materialize(y, out_layout)
        return requantize(y, post_requant) if post_requant else y
    if fam is AlgoFamily.KN2ROW:
        if use_pallas:
            return conv_kn2row(x, w, stride=stride, padding=padding,
                               dataflow=dataflow, p1=p1, p2=p2,
                               interpret=interpret,
                               epilogue=epilogue, bias=bias,
                               in_layout=in_layout, out_layout=out_layout,
                               **quant_kw)
        y = apply_epilogue(
            kn2row_ref(restore(x, in_layout), w,
                       stride=stride, padding=padding), epilogue, bias)
        y = materialize(y, out_layout)
        return requantize(y, post_requant) if post_requant else y
    # Winograd — stride-1 square kernels only (menu_for guarantees this);
    # non-square/strided layers never receive a Winograd assignment.
    assert stride == 1 and w.shape[0] == w.shape[1]
    if use_pallas:
        return conv_winograd(x, w, m=algo.m, padding=padding,
                             dataflow=dataflow, p1=p1, p2=p2,
                             interpret=interpret,
                             epilogue=epilogue, bias=bias,
                             in_layout=in_layout, out_layout=out_layout)
    if in_layout is not None and in_layout.kind == "winograd" \
            and in_layout.m == algo.m and w.shape[0] == in_layout.r:
        spec = in_layout
        tiles_conv = functools.partial(
            winograd_from_tiles_ref, w=w, m=algo.m, tiles_y=spec.tiles_y,
            tiles_x=spec.tiles_x, o1=spec.o1, o2=spec.o2)
        y = jax.vmap(tiles_conv)(x) if x.ndim == 5 else tiles_conv(x)
        return materialize(apply_epilogue(y, epilogue, bias), out_layout)
    x = restore(x, in_layout)
    if w.shape[0] == 3:
        y = apply_epilogue(winograd_ref(x, w, m=algo.m, padding=padding),
                           epilogue, bias)
        return materialize(y, out_layout)
    # K>r multi-round path has no standalone jnp ref; fall back to the
    # Pallas implementation in interpret mode (still winograd math).
    return conv_winograd(x, w, m=algo.m, padding=padding,
                         dataflow=dataflow, p1=p1, p2=p2, interpret=True,
                         epilogue=epilogue, bias=bias,
                         out_layout=out_layout)
