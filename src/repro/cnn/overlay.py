"""The DYNAMAP Computing Unit overlay — single entry point for every conv.

The paper's §3 overlay is one GEMM engine reused by all layers; per layer
only the *algorithm wrapper* (im2col / kn2row / Winograd) and the *dataflow
binding* of the (P_SA1, P_SA2) array dims change. ``apply_conv`` is that
unit in software: it takes the plan's per-layer ``(algo, dataflow, p1, p2)``
and routes the convolution through the dataflow-bound GEMM blocks in
``kernels/gemm`` (Pallas path) or the pure-jnp oracles (reference path).

Batching semantics: every path accepts a single image ``(H, W, C)`` or a
batch ``(B, H, W, C)`` and returns the matching rank. The Pallas kernels
batch through ``pallas_call``'s batching rule (an outer grid dimension), so
the compiled overlay program serves batched traffic without Python dispatch.

``compile_plan`` (executor.py) closes over these per-layer bindings at trace
time; tests monkeypatch this module's ``apply_conv`` to observe exactly
which (algorithm, dataflow) each layer was lowered with.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.algorithms import Algorithm, AlgoFamily
from repro.core.cost_model import Dataflow
from repro.kernels.common import apply_epilogue
from repro.kernels.conv_im2col.ops import conv_im2col
from repro.kernels.conv_im2col.ref import conv_ref, conv_via_toeplitz_ref
from repro.kernels.kn2row.ops import conv_kn2row
from repro.kernels.kn2row.ref import kn2row_ref
from repro.kernels.winograd.ops import conv_winograd
from repro.kernels.winograd.ref import winograd_ref


def apply_conv(x: jax.Array, w: jax.Array, algo: Algorithm,
               dataflow: Dataflow = Dataflow.NS,
               p1: int = 128, p2: int = 128, *,
               stride: int = 1, padding: str = "SAME",
               use_pallas: bool = False,
               backend: Optional[str] = None,
               interpret: Optional[bool] = None,
               epilogue: str = "none",
               bias: Optional[jax.Array] = None) -> jax.Array:
    """Run one conv layer on the overlay under a plan binding.

    x: (H, W, Cin) or (B, H, W, Cin); w: (K1, K2, Cin, Cout).
    ``dataflow``/(p1, p2) select the Eq. 9 GEMM block binding — they only
    shape the Pallas execution schedule, never the math, so any binding
    produces identical outputs (the §3 invariant the tests assert).

    ``backend`` (when given) overrides ``use_pallas``: "pallas" runs the
    Pallas kernels, "reference" the per-algorithm jnp oracles, and "lax"
    XLA's native spatial convolution — the "tiny convs via jnp" leg of a
    mixed-backend plan, and the strongest conv this host's XLA can emit
    (the autotuner measures it against the overlay algorithms per layer).

    ``epilogue`` ("none" | "relu" | "bias" | "bias_relu") streams the conv
    output through the §3 in-pipeline auxiliary units: on the Pallas path it
    fuses into the kernel's output flush (no DRAM round trip); the jnp
    reference/lax paths apply it post-hoc (XLA fuses it there) so every
    backend computes the same function — CONV+ReLU is ONE overlay call
    either way.
    """
    if backend is not None:
        if backend == "lax":
            return apply_epilogue(
                conv_ref(x, w, stride=stride, padding=padding),
                epilogue, bias)
        if backend not in ("pallas", "reference"):
            raise ValueError(f"unknown backend {backend!r}")
        use_pallas = backend == "pallas"
    fam = algo.family
    if fam is AlgoFamily.IM2COL:
        if use_pallas:
            return conv_im2col(x, w, stride=stride, padding=padding,
                               dataflow=dataflow, p1=p1, p2=p2,
                               interpret=interpret,
                               epilogue=epilogue, bias=bias)
        return apply_epilogue(
            conv_via_toeplitz_ref(x, w, stride=stride, padding=padding),
            epilogue, bias)
    if fam is AlgoFamily.KN2ROW:
        if use_pallas:
            return conv_kn2row(x, w, stride=stride, padding=padding,
                               dataflow=dataflow, p1=p1, p2=p2,
                               interpret=interpret,
                               epilogue=epilogue, bias=bias)
        return apply_epilogue(
            kn2row_ref(x, w, stride=stride, padding=padding), epilogue, bias)
    # Winograd — stride-1 square kernels only (menu_for guarantees this);
    # non-square/strided layers never receive a Winograd assignment.
    assert stride == 1 and w.shape[0] == w.shape[1]
    if use_pallas:
        return conv_winograd(x, w, m=algo.m, padding=padding,
                             dataflow=dataflow, p1=p1, p2=p2,
                             interpret=interpret,
                             epilogue=epilogue, bias=bias)
    if w.shape[0] == 3:
        return apply_epilogue(winograd_ref(x, w, m=algo.m, padding=padding),
                              epilogue, bias)
    # K>r multi-round path has no standalone jnp ref; fall back to the
    # Pallas implementation in interpret mode (still winograd math).
    return conv_winograd(x, w, m=algo.m, padding=padding,
                         dataflow=dataflow, p1=p1, p2=p2, interpret=True,
                         epilogue=epilogue, bias=bias)
