"""Executable non-conv CNN layers (pool / norm / FC helpers).

Convolutions live on the Computing Unit overlay (``overlay.apply_conv``) —
the single entry point for all conv algorithms; the executor calls it
directly with the plan's per-layer binding.

All layers here are rank-polymorphic: they accept a single image
``(H, W, C)`` or a batch ``(B, H, W, C)`` and preserve the input rank.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0)


def _window(x: jax.Array, k: int, stride: int):
    """Window/stride tuples covering an optional leading batch dim."""
    lead = (1,) * (x.ndim - 3)
    return lead + (k, k, 1), lead + (stride, stride, 1)


def max_pool(x: jax.Array, k: int, stride: int,
             padding: str = "SAME") -> jax.Array:
    win, strides = _window(x, k, stride)
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, win, strides,
                                 padding)


def avg_pool(x: jax.Array, k: int, stride: int,
             padding: str = "SAME", *, via: str = "jnp",
             use_pallas: bool = False,
             interpret: Optional[bool] = None) -> jax.Array:
    """§3.4: AvgPool expressed as a K×K conv with 1/(K1·K2) weights so it
    can route through the overlay's GEMM unit.

    ``via="overlay"`` runs that formulation literally — a K×K conv with the
    channel-diagonal 1/(K1K2) weight streamed through ``overlay.apply_conv``
    (Pallas or reference backend, like any conv layer); ``via="jnp"`` is the
    reduce-window fallback. Both divide by the number of *valid* (unpadded)
    window elements, so the two paths are numerically equivalent.
    """
    if via == "overlay":
        return _avg_pool_overlay(x, k, stride, padding, use_pallas, interpret)
    if via != "jnp":
        raise ValueError(f"unknown avg_pool via {via!r}")
    win, strides = _window(x, k, stride)
    s = jax.lax.reduce_window(x, 0.0, jax.lax.add, win, strides, padding)
    n = jax.lax.reduce_window(jnp.ones_like(x), 0.0, jax.lax.add, win,
                              strides, padding)
    return s / n


def _avg_pool_overlay(x: jax.Array, k: int, stride: int, padding: str,
                      use_pallas: bool, interpret: Optional[bool]
                      ) -> jax.Array:
    """AvgPool on the Computing Unit: K×K conv, weight (ci==co)/(K·K).

    With SAME padding the GEMM sums zero-padded windows (÷K² everywhere),
    while pooling semantics divide by the valid-element count — rescale by
    K²/n so edges match the jnp path exactly.
    """
    from repro.cnn import overlay              # deferred: executor-level dep
    from repro.core.algorithms import IM2COL
    c = x.shape[-1]
    w = jnp.broadcast_to(jnp.eye(c, dtype=x.dtype) / (k * k),
                         (k, k, c, c))
    y = overlay.apply_conv(x, w, IM2COL, stride=stride, padding=padding,
                           use_pallas=use_pallas, interpret=interpret)
    if padding == "SAME":
        win, strides = _window(x, k, stride)
        n = jax.lax.reduce_window(jnp.ones_like(x), 0.0, jax.lax.add, win,
                                  strides, padding)
        y = y * (k * k) / n
    return y


def global_avg_pool(x: jax.Array) -> jax.Array:
    """(…, H, W, C) → (…, C)."""
    return jnp.mean(x, axis=(-3, -2))


def fc(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    """Fully connected layer over pre-flattened features: x is (f,) or
    (B, f). The executor flattens — it knows whether a batch dim exists;
    this layer never guesses from rank."""
    y = x @ w
    return y + b if b is not None else y
