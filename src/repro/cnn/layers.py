"""Executable non-conv CNN layers (pool / norm / FC helpers).

Convolutions live on the Computing Unit overlay (``overlay.apply_conv``) —
the single entry point for all conv algorithms; the executor calls it
directly with the plan's per-layer binding.

All layers here are rank-polymorphic: they accept a single image
``(H, W, C)`` or a batch ``(B, H, W, C)`` and preserve the input rank.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0)


def _window(x: jax.Array, k: int, stride: int):
    """Window/stride tuples covering an optional leading batch dim."""
    lead = (1,) * (x.ndim - 3)
    return lead + (k, k, 1), lead + (stride, stride, 1)


def max_pool(x: jax.Array, k: int, stride: int,
             padding: str = "SAME") -> jax.Array:
    win, strides = _window(x, k, stride)
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, win, strides,
                                 padding)


def avg_pool(x: jax.Array, k: int, stride: int,
             padding: str = "SAME") -> jax.Array:
    """§3.4: AvgPool expressed as a K×K conv with 1/(K1·K2) weights —
    we keep that formulation so it can route through the GEMM unit."""
    win, strides = _window(x, k, stride)
    s = jax.lax.reduce_window(x, 0.0, jax.lax.add, win, strides, padding)
    n = jax.lax.reduce_window(jnp.ones_like(x), 0.0, jax.lax.add, win,
                              strides, padding)
    return s / n


def global_avg_pool(x: jax.Array) -> jax.Array:
    """(…, H, W, C) → (…, C)."""
    return jnp.mean(x, axis=(-3, -2))


def fc(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    """Fully connected layer over pre-flattened features: x is (f,) or
    (B, f). The executor flattens — it knows whether a batch dim exists;
    this layer never guesses from rank."""
    y = x @ w
    return y + b if b is not None else y
