"""Executable CNN layers with per-layer algorithm dispatch.

Every conv can run under any of the paper's three algorithm families; the
``use_pallas`` switch picks between the Pallas kernels (interpret-mode on
CPU, compiled on TPU) and the pure-jnp reference implementations (fast on
CPU — used for full-network functional tests). Both paths are validated
against ``jax.lax.conv_general_dilated`` in tests/.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.algorithms import Algorithm, AlgoFamily
from repro.kernels.conv_im2col.ops import conv_im2col
from repro.kernels.conv_im2col.ref import conv_ref, conv_via_toeplitz_ref
from repro.kernels.kn2row.ops import conv_kn2row
from repro.kernels.kn2row.ref import kn2row_ref
from repro.kernels.winograd.ops import conv_winograd
from repro.kernels.winograd.ref import winograd_ref


def conv2d(x: jax.Array, w: jax.Array, algo: Algorithm, stride: int = 1,
           padding: str = "SAME", use_pallas: bool = False,
           interpret: Optional[bool] = None) -> jax.Array:
    """x: (H, W, Cin), w: (K1, K2, Cin, Cout)."""
    fam = algo.family
    if fam is AlgoFamily.IM2COL:
        if use_pallas:
            return conv_im2col(x, w, stride=stride, padding=padding,
                               interpret=interpret)
        return conv_via_toeplitz_ref(x, w, stride=stride, padding=padding)
    if fam is AlgoFamily.KN2ROW:
        if use_pallas:
            return conv_kn2row(x, w, stride=stride, padding=padding,
                               interpret=interpret)
        return kn2row_ref(x, w, stride=stride, padding=padding)
    # Winograd — stride-1 square kernels only (menu_for guarantees this);
    # non-square/strided layers never receive a Winograd assignment.
    assert stride == 1 and w.shape[0] == w.shape[1]
    if use_pallas:
        return conv_winograd(x, w, m=algo.m, padding=padding,
                             interpret=interpret)
    if w.shape[0] == 3:
        return winograd_ref(x, w, m=algo.m, padding=padding)
    # K>r multi-round path has no standalone jnp ref; fall back to the
    # Pallas implementation in interpret mode (still winograd math).
    return conv_winograd(x, w, m=algo.m, padding=padding, interpret=True)


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0)


def max_pool(x: jax.Array, k: int, stride: int,
             padding: str = "SAME") -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (k, k, 1), (stride, stride, 1), padding)


def avg_pool(x: jax.Array, k: int, stride: int,
             padding: str = "SAME") -> jax.Array:
    """§3.4: AvgPool expressed as a K×K conv with 1/(K1·K2) weights —
    we keep that formulation so it can route through the GEMM unit."""
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (k, k, 1), (stride, stride, 1), padding)
    n = jax.lax.reduce_window(
        jnp.ones_like(x), 0.0, jax.lax.add, (k, k, 1), (stride, stride, 1),
        padding)
    return s / n


def global_avg_pool(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(0, 1))


def fc(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = x.reshape(-1) @ w
    return y + b if b is not None else y
