"""CNN model-graph builders: GoogleNet, Inception-v4 (the paper's two
evaluation networks), plus VGG-16 / ResNet-18 / AlexNet (Lemma 4.3 coverage).

All builders emit ``repro.core.graph.Graph`` with ConvMeta per conv vertex
and ``out_shape`` annotations on every non-conv vertex so the mapper can
price transitions. A ``scale`` factor shrinks spatial dims and channels for
CPU-runnable smoke configurations while preserving graph topology.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.core.graph import ConvMeta, Graph, LayerKind


def _c(x: float, scale: float, minimum: int = 1) -> int:
    return max(minimum, int(round(x * scale)))


@dataclasses.dataclass
class _Cursor:
    """Tracks the frontier node and its (H, W, C) while chaining layers."""
    g: Graph
    node: int
    h: int
    w: int
    c: int

    def conv(self, c_out: int, k1: int, k2: int, stride: int = 1,
             pad: str = "same", name: str = "") -> "_Cursor":
        meta = ConvMeta(c_in=self.c, c_out=c_out, h1=self.h, h2=self.w,
                        k1=k1, k2=k2, stride=stride, pad=pad)
        nid = self.g.add_node(LayerKind.CONV, name=name, conv=meta)
        self.g.add_edge(self.node, nid)
        return _Cursor(self.g, nid, meta.o1, meta.o2, c_out)

    def pool(self, k: int, stride: int, kind: LayerKind = LayerKind.POOL_MAX,
             pad: str = "same", name: str = "") -> "_Cursor":
        if pad == "same":
            oh, ow = -(-self.h // stride), -(-self.w // stride)
        else:
            oh = (self.h - k) // stride + 1
            ow = (self.w - k) // stride + 1
        nid = self.g.add_node(kind, name=name, out_shape=(oh, ow, self.c),
                              k=k, stride=stride, pad=pad)
        self.g.add_edge(self.node, nid)
        return _Cursor(self.g, nid, oh, ow, self.c)

    def global_pool(self, name: str = "gap") -> "_Cursor":
        nid = self.g.add_node(LayerKind.GLOBAL_POOL, name=name,
                              out_shape=(1, 1, self.c))
        self.g.add_edge(self.node, nid)
        return _Cursor(self.g, nid, 1, 1, self.c)

    def fc(self, out_features: int, name: str = "fc") -> "_Cursor":
        nid = self.g.add_node(LayerKind.FC, name=name,
                              out_shape=(1, 1, out_features),
                              in_features=self.h * self.w * self.c,
                              out_features=out_features)
        self.g.add_edge(self.node, nid)
        return _Cursor(self.g, nid, 1, 1, out_features)


def _concat(g: Graph, branches: Sequence[_Cursor], name: str) -> _Cursor:
    h, w = branches[0].h, branches[0].w
    for b in branches:
        assert (b.h, b.w) == (h, w), \
            f"{name}: branch shapes differ: {[(b.h, b.w, b.c) for b in branches]}"
    c = sum(b.c for b in branches)
    nid = g.add_node(LayerKind.CONCAT, name=name, out_shape=(h, w, c))
    for b in branches:
        g.add_edge(b.node, nid)
    return _Cursor(g, nid, h, w, c)


def _add(g: Graph, a: _Cursor, b: _Cursor, name: str) -> _Cursor:
    assert (a.h, a.w, a.c) == (b.h, b.w, b.c)
    nid = g.add_node(LayerKind.ADD, name=name, out_shape=(a.h, a.w, a.c))
    g.add_edge(a.node, nid)
    g.add_edge(b.node, nid)
    return _Cursor(g, nid, a.h, a.w, a.c)


def _start(res: int, c_in: int = 3) -> Tuple[Graph, _Cursor]:
    g = Graph()
    nid = g.add_node(LayerKind.INPUT, name="input", out_shape=(res, res, c_in))
    return g, _Cursor(g, nid, res, res, c_in)


def _finish(cur: _Cursor, classes: int) -> Graph:
    cur = cur.global_pool().fc(classes)
    out = cur.g.add_node(LayerKind.OUTPUT, name="output",
                         out_shape=(1, 1, classes))
    cur.g.add_edge(cur.node, out)
    return cur.g


# ---------------------------------------------------------------------------
# GoogleNet (Inception-v1) — Szegedy et al. 2015, Table 1.
# ---------------------------------------------------------------------------

def _inception_v1(cur: _Cursor, n1: int, r3: int, n3: int, r5: int, n5: int,
                  pp: int, name: str) -> _Cursor:
    g = cur.g
    b1 = cur.conv(n1, 1, 1, name=f"{name}/1x1")
    b2 = cur.conv(r3, 1, 1, name=f"{name}/3x3r").conv(n3, 3, 3,
                                                      name=f"{name}/3x3")
    b3 = cur.conv(r5, 1, 1, name=f"{name}/5x5r").conv(n5, 5, 5,
                                                      name=f"{name}/5x5")
    b4 = cur.pool(3, 1, name=f"{name}/pool").conv(pp, 1, 1,
                                                  name=f"{name}/poolproj")
    return _concat(g, [b1, b2, b3, b4], f"{name}/concat")


def googlenet(res: int = 224, classes: int = 1000,
              scale: float = 1.0) -> Graph:
    s = scale
    g, cur = _start(res)
    cur = cur.conv(_c(64, s), 7, 7, stride=2, name="conv1")
    cur = cur.pool(3, 2, name="pool1")
    cur = cur.conv(_c(64, s), 1, 1, name="conv2r")
    cur = cur.conv(_c(192, s), 3, 3, name="conv2")
    cur = cur.pool(3, 2, name="pool2")
    cfg = [
        ("3a", 64, 96, 128, 16, 32, 32), ("3b", 128, 128, 192, 32, 96, 64),
        ("pool", 0, 0, 0, 0, 0, 0),
        ("4a", 192, 96, 208, 16, 48, 64), ("4b", 160, 112, 224, 24, 64, 64),
        ("4c", 128, 128, 256, 24, 64, 64), ("4d", 112, 144, 288, 32, 64, 64),
        ("4e", 256, 160, 320, 32, 128, 128),
        ("pool", 0, 0, 0, 0, 0, 0),
        ("5a", 256, 160, 320, 32, 128, 128),
        ("5b", 384, 192, 384, 48, 128, 128),
    ]
    for row in cfg:
        if row[0] == "pool":
            cur = cur.pool(3, 2, name="pool")
        else:
            name, n1, r3, n3, r5, n5, pp = row
            cur = _inception_v1(cur, _c(n1, s), _c(r3, s), _c(n3, s),
                                _c(r5, s), _c(n5, s), _c(pp, s),
                                f"inception_{name}")
    return _finish(cur, classes)


# ---------------------------------------------------------------------------
# Inception-v4 — Szegedy et al. 2016 (Figures 3-9).
# ---------------------------------------------------------------------------

def _stem_v4(cur: _Cursor, s: float) -> _Cursor:
    g = cur.g
    cur = cur.conv(_c(32, s), 3, 3, stride=2, pad="valid", name="stem/c1")
    cur = cur.conv(_c(32, s), 3, 3, pad="valid", name="stem/c2")
    cur = cur.conv(_c(64, s), 3, 3, name="stem/c3")
    p = cur.pool(3, 2, pad="valid", name="stem/p1")
    c = cur.conv(_c(96, s), 3, 3, stride=2, pad="valid", name="stem/c4")
    cur = _concat(g, [p, c], "stem/cat1")
    a = cur.conv(_c(64, s), 1, 1, name="stem/a1").conv(
        _c(96, s), 3, 3, pad="valid", name="stem/a2")
    b = (cur.conv(_c(64, s), 1, 1, name="stem/b1")
         .conv(_c(64, s), 7, 1, name="stem/b2")
         .conv(_c(64, s), 1, 7, name="stem/b3")
         .conv(_c(96, s), 3, 3, pad="valid", name="stem/b4"))
    cur = _concat(g, [a, b], "stem/cat2")
    c2 = cur.conv(_c(192, s), 3, 3, stride=2, pad="valid", name="stem/c5")
    p2 = cur.pool(3, 2, pad="valid", name="stem/p2")
    return _concat(g, [c2, p2], "stem/cat3")


def _inception_a(cur: _Cursor, s: float, name: str) -> _Cursor:
    g = cur.g
    b1 = cur.pool(3, 1, kind=LayerKind.POOL_AVG, name=f"{name}/ap").conv(
        _c(96, s), 1, 1, name=f"{name}/b1")
    b2 = cur.conv(_c(96, s), 1, 1, name=f"{name}/b2")
    b3 = cur.conv(_c(64, s), 1, 1, name=f"{name}/b3a").conv(
        _c(96, s), 3, 3, name=f"{name}/b3b")
    b4 = (cur.conv(_c(64, s), 1, 1, name=f"{name}/b4a")
          .conv(_c(96, s), 3, 3, name=f"{name}/b4b")
          .conv(_c(96, s), 3, 3, name=f"{name}/b4c"))
    return _concat(g, [b1, b2, b3, b4], f"{name}/cat")


def _reduction_a(cur: _Cursor, s: float, name: str = "redA") -> _Cursor:
    g = cur.g
    b1 = cur.pool(3, 2, pad="valid", name=f"{name}/mp")
    b2 = cur.conv(_c(384, s), 3, 3, stride=2, pad="valid", name=f"{name}/b2")
    b3 = (cur.conv(_c(192, s), 1, 1, name=f"{name}/b3a")
          .conv(_c(224, s), 3, 3, name=f"{name}/b3b")
          .conv(_c(256, s), 3, 3, stride=2, pad="valid", name=f"{name}/b3c"))
    return _concat(g, [b1, b2, b3], f"{name}/cat")


def _inception_b(cur: _Cursor, s: float, name: str) -> _Cursor:
    g = cur.g
    b1 = cur.pool(3, 1, kind=LayerKind.POOL_AVG, name=f"{name}/ap").conv(
        _c(128, s), 1, 1, name=f"{name}/b1")
    b2 = cur.conv(_c(384, s), 1, 1, name=f"{name}/b2")
    b3 = (cur.conv(_c(192, s), 1, 1, name=f"{name}/b3a")
          .conv(_c(224, s), 1, 7, name=f"{name}/b3b")
          .conv(_c(256, s), 7, 1, name=f"{name}/b3c"))
    b4 = (cur.conv(_c(192, s), 1, 1, name=f"{name}/b4a")
          .conv(_c(192, s), 7, 1, name=f"{name}/b4b")
          .conv(_c(224, s), 1, 7, name=f"{name}/b4c")
          .conv(_c(224, s), 7, 1, name=f"{name}/b4d")
          .conv(_c(256, s), 1, 7, name=f"{name}/b4e"))
    return _concat(g, [b1, b2, b3, b4], f"{name}/cat")


def _reduction_b(cur: _Cursor, s: float, name: str = "redB") -> _Cursor:
    g = cur.g
    b1 = cur.pool(3, 2, pad="valid", name=f"{name}/mp")
    b2 = cur.conv(_c(192, s), 1, 1, name=f"{name}/b2a").conv(
        _c(192, s), 3, 3, stride=2, pad="valid", name=f"{name}/b2b")
    b3 = (cur.conv(_c(256, s), 1, 1, name=f"{name}/b3a")
          .conv(_c(256, s), 1, 7, name=f"{name}/b3b")
          .conv(_c(320, s), 7, 1, name=f"{name}/b3c")
          .conv(_c(320, s), 3, 3, stride=2, pad="valid", name=f"{name}/b3d"))
    return _concat(g, [b1, b2, b3], f"{name}/cat")


def _inception_c(cur: _Cursor, s: float, name: str) -> _Cursor:
    g = cur.g
    b1 = cur.pool(3, 1, kind=LayerKind.POOL_AVG, name=f"{name}/ap").conv(
        _c(256, s), 1, 1, name=f"{name}/b1")
    b2 = cur.conv(_c(256, s), 1, 1, name=f"{name}/b2")
    # Branch 3: the 1x1 output *splits* into two parallel convs (out-degree
    # 2 → a store-format vertex in the cost graph).
    b3 = cur.conv(_c(384, s), 1, 1, name=f"{name}/b3a")
    b3l = b3.conv(_c(256, s), 1, 3, name=f"{name}/b3b")
    b3r = b3.conv(_c(256, s), 3, 1, name=f"{name}/b3c")
    b4 = (cur.conv(_c(384, s), 1, 1, name=f"{name}/b4a")
          .conv(_c(448, s), 1, 3, name=f"{name}/b4b")
          .conv(_c(512, s), 3, 1, name=f"{name}/b4c"))
    b4l = b4.conv(_c(256, s), 3, 1, name=f"{name}/b4d")
    b4r = b4.conv(_c(256, s), 1, 3, name=f"{name}/b4e")
    return _concat(g, [b1, b2, b3l, b3r, b4l, b4r], f"{name}/cat")


def inception_v4(res: int = 299, classes: int = 1000, scale: float = 1.0,
                 n_a: int = 4, n_b: int = 7, n_c: int = 3) -> Graph:
    s = scale
    g, cur = _start(res)
    cur = _stem_v4(cur, s)
    for i in range(n_a):
        cur = _inception_a(cur, s, f"incA{i}")
    cur = _reduction_a(cur, s)
    for i in range(n_b):
        cur = _inception_b(cur, s, f"incB{i}")
    cur = _reduction_b(cur, s)
    for i in range(n_c):
        cur = _inception_c(cur, s, f"incC{i}")
    return _finish(cur, classes)


# ---------------------------------------------------------------------------
# Chain / residual networks (Lemma 4.3).
# ---------------------------------------------------------------------------

def vgg16(res: int = 224, classes: int = 1000, scale: float = 1.0) -> Graph:
    s = scale
    g, cur = _start(res)
    for block, (n, reps) in enumerate([(64, 2), (128, 2), (256, 3),
                                       (512, 3), (512, 3)]):
        for i in range(reps):
            cur = cur.conv(_c(n, s), 3, 3, name=f"conv{block}_{i}")
        cur = cur.pool(2, 2, name=f"pool{block}")
    return _finish(cur, classes)


def alexnet(res: int = 224, classes: int = 1000, scale: float = 1.0) -> Graph:
    s = scale
    g, cur = _start(res)
    cur = cur.conv(_c(64, s), 11, 11, stride=4, name="conv1").pool(3, 2)
    cur = cur.conv(_c(192, s), 5, 5, name="conv2").pool(3, 2)
    cur = cur.conv(_c(384, s), 3, 3, name="conv3")
    cur = cur.conv(_c(256, s), 3, 3, name="conv4")
    cur = cur.conv(_c(256, s), 3, 3, name="conv5").pool(3, 2)
    return _finish(cur, classes)


def resnet18(res: int = 224, classes: int = 1000, scale: float = 1.0) -> Graph:
    s = scale
    g, cur = _start(res)
    cur = cur.conv(_c(64, s), 7, 7, stride=2, name="conv1").pool(3, 2)
    chans = [(64, 1), (64, 1), (128, 2), (128, 1), (256, 2), (256, 1),
             (512, 2), (512, 1)]
    for i, (c, stride) in enumerate(chans):
        c_ = _c(c, s)
        main = cur.conv(c_, 3, 3, stride=stride, name=f"res{i}a")
        main = main.conv(c_, 3, 3, name=f"res{i}b")
        if stride != 1 or cur.c != c_:
            skip = cur.conv(c_, 1, 1, stride=stride, name=f"res{i}s")
        else:
            skip = cur
        cur = _add(g, main, skip, f"res{i}add")
    return _finish(cur, classes)


MODELS = {
    "googlenet": googlenet,
    "inception_v4": inception_v4,
    "vgg16": vgg16,
    "alexnet": alexnet,
    "resnet18": resnet18,
}
