"""Deterministic synthetic data pipeline.

Design goals for 1000+-node runs:
  * **Determinism under restart/elasticity**: every batch is a pure function
    of (seed, step) — a restarted or re-sharded job replays the exact token
    stream with no host coordination or state files.
  * **Host-sharded**: each host materializes only its slice of the global
    batch (jax.make_array_from_callback), so no host ever holds the global
    batch.
  * **Prefetch**: a background thread keeps ``depth`` batches ready, hiding
    host-side generation behind device compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 256
    seq_len: int = 4096
    # Synthetic-stream flavor: zipfian token draws mimic natural-language
    # unigram statistics so losses are non-degenerate.
    zipf_a: float = 1.2


def _tokens_for(cfg: DataConfig, model: ModelConfig, step: int,
                lo: int, hi: int) -> np.ndarray:
    """Rows [lo, hi) of the global batch at ``step`` — pure function."""
    n_front = model.frontend_tokens if model.frontend != "none" else 0
    seq = cfg.seq_len - n_front
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, lo, hi]))
    z = rng.zipf(cfg.zipf_a, size=(hi - lo, seq)).astype(np.int64)
    return (z % model.vocab).astype(np.int32)


def _frontend_for(cfg: DataConfig, model: ModelConfig, step: int,
                  lo: int, hi: int) -> Optional[np.ndarray]:
    if model.frontend == "none":
        return None
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed + 7, step, lo, hi]))
    return rng.standard_normal(
        (hi - lo, model.frontend_tokens, model.frontend_dim)
    ).astype(np.float32)


def make_batch(cfg: DataConfig, model: ModelConfig, step: int,
               mesh: Optional[Mesh] = None) -> Dict[str, jax.Array]:
    """Global batch at ``step``; device-sharded when a mesh is given."""
    n_front = model.frontend_tokens if model.frontend != "none" else 0
    tok_shape = (cfg.global_batch, cfg.seq_len - n_front)

    if mesh is None:
        batch = {"tokens": jax.numpy.asarray(
            _tokens_for(cfg, model, step, 0, cfg.global_batch))}
        fe = _frontend_for(cfg, model, step, 0, cfg.global_batch)
        if fe is not None:
            batch["frontend_embeds"] = jax.numpy.asarray(fe)
        return batch

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = P(dp) if cfg.global_batch % int(
        np.prod([mesh.shape[a] for a in dp])) == 0 else P()

    def cb_tokens(index) -> np.ndarray:
        lo = index[0].start or 0
        hi = index[0].stop or cfg.global_batch
        return _tokens_for(cfg, model, step, lo, hi)

    sharding = NamedSharding(mesh, P(*([spec[0]] + [None])))
    batch = {"tokens": jax.make_array_from_callback(
        tok_shape, sharding, cb_tokens)}
    if n_front:
        fe_shape = (cfg.global_batch, model.frontend_tokens,
                    model.frontend_dim)
        fe_shard = NamedSharding(mesh, P(spec[0], None, None))

        def cb_fe(index) -> np.ndarray:
            lo = index[0].start or 0
            hi = index[0].stop or cfg.global_batch
            return _frontend_for(cfg, model, step, lo, hi)

        batch["frontend_embeds"] = jax.make_array_from_callback(
            fe_shape, fe_shard, cb_fe)
    return batch


class PrefetchIterator:
    """Background-thread prefetch of ``depth`` upcoming batches."""

    def __init__(self, cfg: DataConfig, model: ModelConfig,
                 mesh: Optional[Mesh] = None, start_step: int = 0,
                 depth: int = 2) -> None:
        self.cfg = cfg
        self.model = model
        self.mesh = mesh
        self.step = start_step
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        s = self.step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, self.model, s, self.mesh)
            while not self._stop.is_set():
                try:
                    self.q.put((s, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self) -> None:
        self._stop.set()
