"""Closed-loop plan supervision: measure → calibrate → re-solve → hot-swap.

DYNAMAP's DSE is a one-shot offline step; this module turns it into the
control loop the ROADMAP asks for. A ``PlanSupervisor`` rides shotgun on a
``CNNServingEngine``: it watches the engine's per-bucket service EMAs and
tick wall times, distills them (plus any directly-observed transition
measurements) into a ``TransitionCalibration``, periodically re-solves the
PBQP with calibrated edge prices (``core.mapper.replan``), compiles the
winning plan's bucket ladder — optionally on a background thread, through
the engine's shared ``ExecutableCache`` — and swaps it in atomically
between ticks (``CNNServingEngine.swap_plan``). A probation window after
every swap re-arms the previous ladder if the new plan's first N measured
ticks regress.

State machine (documented in docs/architecture.md)::

    MONITOR --(calibrated re-solve adopts a cheaper plan)--> COMPILING
    COMPILING --(ladder ready, next tick boundary)--> PROBATION (swap)
    PROBATION --(first N ticks healthy)--> MONITOR (new baseline)
    PROBATION --(median tick regression > rollback_factor)--> MONITOR
               (old ladder re-armed, cooldown before the next attempt)

Every decision input is injectable — the engine clock, the calibration
(``observe_calibration`` / ``calibration_source``), the fault plan — so
the whole loop is deterministic under test: an injected service-time
shift provably flips the deployed plan (``tests/test_plan_hotswap.py``).

Calibration attribution: live tick-time inflation (current EMA vs. the
EMA snapshot latched at deployment) is attributed to layout transitions as
a single multiplicative knob — the paper's DDR-contention regime, where
memory-system pressure hits the store/load legs first. That single-knob
inference is deliberately conservative; feeding measured per-layout-pair
ratios via ``observe_calibration`` (e.g. distilled from
``transition_report`` vs. realized wall clock) overrides it with real
per-pair scales, and both compose multiplicatively.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional

from repro.core.autotune import refresh_from_service
from repro.core.cost_model import TransitionCalibration
from repro.core.graph import Graph
from repro.core.mapper import ReplanResult, replan
from repro.serving.cnn_engine import CNNServingEngine

# Supervisor states (stats()["state"]).
MONITOR = "monitor"
COMPILING = "compiling"
PROBATION = "probation"


class PlanSupervisor:
    """Drives the closed re-mapping loop for one serving engine.

    Call ``tick()`` once after every ``engine.step()`` from the serving
    loop (the replay helpers' ``on_tick`` hook does exactly this). All
    supervisor work happens on the serving thread except ladder
    compilation, which runs on a daemon thread when ``background=True``
    — the swap itself always lands between ticks on the serving thread,
    so no tick ever observes a half-deployed ladder.

    ``map_kwargs`` must repeat the kwargs the engine's deployed plan was
    mapped with (``hw=``, ``use_on_chip=``, ...): ``replan`` prices the
    deployed assignment on the re-built cost graph, which must be
    congruent. Serving-tier re-solves typically want
    ``use_on_chip=False``: bucketed ticks multiply every activation by
    the batch size, so the single-image VMEM-residency assumption that
    zeroes edge costs offline does not hold under traffic.

    ``check_every`` counts *completed* ticks between re-solve checks;
    ``hysteresis`` gates both inflation detection and plan adoption (the
    autotuner's 5% default); ``rollback_ticks``/``rollback_factor``
    define probation: after a swap, the median of the first N measured
    tick services (per bucket, vs. the freshest pre-swap walls of the
    same buckets) above the factor re-arms the old ladder. ``refresh_tuning`` also live-refreshes
    the engine's tuning record from the same EMAs
    (``core.autotune.refresh_from_service``) at every check."""

    def __init__(self, engine: CNNServingEngine, graph: Graph, *,
                 map_kwargs: Optional[Dict[str, object]] = None,
                 check_every: int = 8,
                 hysteresis: float = 0.05,
                 rollback_ticks: int = 6,
                 rollback_factor: float = 1.5,
                 cooldown_checks: int = 4,
                 settle_checks: int = 1,
                 background: bool = False,
                 calibration_source: Optional[
                     Callable[[], Optional[TransitionCalibration]]] = None,
                 refresh_tuning: bool = True,
                 on_swap: Optional[Callable[[ReplanResult], None]] = None
                 ) -> None:
        if engine.plan is None:
            raise ValueError(
                "PlanSupervisor needs an engine serving a solved "
                "ExecutionPlan — a default-lowered (plan=None) engine has "
                "no deployed assignment to re-price")
        if check_every < 1:
            raise ValueError(f"check_every must be >= 1, got {check_every}")
        if rollback_ticks < 1:
            raise ValueError(
                f"rollback_ticks must be >= 1, got {rollback_ticks}")
        self.engine = engine
        self.graph = graph
        self.map_kwargs = dict(map_kwargs or {})
        self.check_every = int(check_every)
        self.hysteresis = float(hysteresis)
        self.rollback_ticks = int(rollback_ticks)
        self.rollback_factor = float(rollback_factor)
        self.cooldown_checks = int(cooldown_checks)
        self.settle_checks = int(settle_checks)
        self.background = bool(background)
        self.calibration_source = calibration_source
        self.refresh_tuning = bool(refresh_tuning)
        self.on_swap = on_swap

        self.state = MONITOR
        self.checks = 0
        self.swaps = 0
        self.rollbacks = 0
        self.last_replan: Optional[ReplanResult] = None
        self.refresh_scales: Dict[int, float] = {}
        # Pre-shift EMA baseline: latched lazily per bucket as EMAs first
        # appear, re-latched after every accepted deployment — inflation
        # is always measured against the currently-deployed plan's own
        # steady state.
        self._baseline_svc: Dict[int, float] = {}
        self._baseline_disp: Dict[int, int] = {}
        # Sticky environment scale: each check folds the fresh
        # EMA-vs-baseline ratio in multiplicatively and re-latches, so the
        # stepwise ratios telescope to the cumulative shift since launch —
        # the inference survives swaps (the environment didn't change back
        # just because the plan did) and decays the same way when the
        # machine recovers.
        self._inferred_scale = 1.0
        # Settle windows: for the first ``settle_checks`` checks after
        # startup and after every deployment change, EMA movement is
        # attributable to the engine itself (JIT convergence, the new
        # plan's different steady state) rather than the environment —
        # those checks only re-latch baselines instead of folding the
        # ratio into the sticky scale or re-solving.
        self._settle = self.settle_checks
        self._observed: Optional[TransitionCalibration] = None
        self._ticks_since_check = 0
        self._seen_completed = engine._completed_ticks
        self._cooldown = 0
        # COMPILING handoff: the (replan result, compiled ladder) pair the
        # next tick() installs; under background compile the thread fills
        # it and the serving thread polls.
        self._pending_result: Optional[ReplanResult] = None
        self._pending_runs: Optional[Dict[int, Callable]] = None
        self._compile_thread: Optional[threading.Thread] = None
        # PROBATION bookkeeping: previous deployment for rollback plus the
        # post-swap tick samples measured so far.
        self._prev_deploy: Optional[tuple] = None
        self._probation_samples: list = []
        self._swap_snapshot: Dict[int, float] = {}
        # Last measured wall per bucket under the *deployed* plan, tagged
        # with its completed-tick index. The swap snapshot is built from
        # these (freshness-gated), not from the EMAs: after an environment
        # shift the EMA still carries pre-shift history, and comparing
        # post-swap ticks against that stale mixture reads a genuinely
        # better plan as a regression (false rollback). The last walls of
        # the final check window are exactly the old plan measured in the
        # current environment — the honest comparator.
        self._recent_wall: Dict[int, tuple] = {}

    # ------------------------------------------------------- calibration
    def observe_calibration(self,
                            cal: Optional[TransitionCalibration]) -> None:
        """Feed directly-measured transition scales (e.g. distilled from
        ``transition_report`` predictions vs. realized layout-bench wall
        clock). Replaces the previous observation; composes
        multiplicatively with the live-inflation inference."""
        self._observed = cal

    def _latch_baselines(self) -> None:
        for b, ema in self.engine._svc.items():
            if ema is not None and b not in self._baseline_svc:
                self._baseline_svc[b] = ema
                self._baseline_disp[b] = self.engine.dispatches.get(b, 0)

    def _inflation(self) -> float:
        """Median live-EMA / baseline-EMA ratio over *trafficked* buckets
        (those with dispatches since their baseline latched — a bucket no
        tick has exercised carries a frozen EMA whose ratio of exactly 1.0
        would otherwise drown the signal from the buckets actually
        serving). 1.0 when nothing is measurable yet."""
        ratios = sorted(
            self.engine._svc[b] / base
            for b, base in self._baseline_svc.items()
            if self.engine._svc.get(b) is not None and base > 0.0
            and self.engine.dispatches.get(b, 0)
            != self._baseline_disp.get(b, 0))
        if not ratios:
            return 1.0
        return ratios[len(ratios) // 2]

    def _update_inferred(self) -> None:
        """Fold the fresh inflation reading into the sticky scale and
        re-latch baselines — only when it moved beyond hysteresis in
        either direction, so sub-hysteresis noise neither churns the
        calibration nor accumulates through repeated re-latching."""
        med = self._inflation()
        if abs(med - 1.0) > self.hysteresis:
            self._inferred_scale = max(self._inferred_scale * med, 1e-3)
            self._baseline_svc = {}
            self._baseline_disp = {}
            self._latch_baselines()

    def current_calibration(self) -> Optional[TransitionCalibration]:
        """The calibration the next re-solve will price edges with:
        directly-observed per-pair scales (if any) times the sticky
        single-knob environment scale. None = nothing measured yet — the
        analytical model stands."""
        if self.calibration_source is not None:
            return self.calibration_source()
        r = self._inferred_scale
        base = self._observed
        if base is None:
            return None if r == 1.0 else TransitionCalibration(default=r)
        if r == 1.0:
            return base
        return TransitionCalibration(
            scales={k: v * r for k, v in base.scales.items()},
            default=base.default * r)

    # -------------------------------------------------------------- loop
    def tick(self, now: Optional[float] = None) -> None:
        """One supervision step; call after every ``engine.step()``.
        Cheap when idle: until ``check_every`` new ticks completed, this
        only samples counters."""
        self._latch_baselines()
        delta = self.engine._completed_ticks - self._seen_completed
        self._seen_completed = self.engine._completed_ticks
        last = self.engine.last_tick
        if delta > 0 and self.state != PROBATION \
                and last and not last.get("failed"):
            self._recent_wall[last["bucket"]] = (
                float(last["wall_s"]), self.engine._completed_ticks)

        if self.state == COMPILING:
            self._poll_compile()
            return
        if self.state == PROBATION:
            if delta > 0:
                self._observe_probation()
            return

        if delta <= 0:
            return
        self._ticks_since_check += delta
        if self._ticks_since_check < self.check_every:
            return
        self._ticks_since_check = 0
        if self._cooldown > 0:
            self._cooldown -= 1
            return
        self._check()

    def _check(self) -> None:
        """One MONITOR-state decision: live-refresh the tuning record,
        re-solve under the current calibration, and start compiling when
        the candidate clears the hysteresis gate."""
        self.checks += 1
        eng = self.engine
        if self._settle > 0:
            self._settle -= 1
            self._baseline_svc = {}
            self._baseline_disp = {}
            self._latch_baselines()
            return
        self._update_inferred()
        emas = {b: s for b, s in eng._svc.items() if s is not None}
        if self.refresh_tuning and eng.tuning is not None and emas:
            applied = refresh_from_service(
                eng.tuning, self.graph, emas,
                precisions=eng.precisions,
                min_improvement=self.hysteresis)
            for b, r in applied.items():
                self.refresh_scales[b] = \
                    round(self.refresh_scales.get(b, 1.0) * r, 6)
        result = replan(self.graph, eng.plan,
                        calibration=self.current_calibration(),
                        hysteresis=self.hysteresis, **self.map_kwargs)
        self.last_replan = result
        if not result.adopted:
            return
        self.state = COMPILING
        if self.background:
            self._compile_thread = threading.Thread(
                target=self._compile_target, args=(result,), daemon=True)
            self._compile_thread.start()
        else:
            self._pending_runs = eng.compile_ladder(result.plan,
                                                    act_scales=None)
            self._pending_result = result
            self._poll_compile()

    def _compile_target(self, result: ReplanResult) -> None:
        """Background-thread body: compile the candidate ladder through
        the shared cache, then hand it to the serving thread. Only the
        publication order matters — runs before result — because
        ``_poll_compile`` keys readiness off ``_pending_result``."""
        runs = self.engine.compile_ladder(result.plan, act_scales=None)
        self._pending_runs = runs
        self._pending_result = result

    def _poll_compile(self) -> None:
        """Install a finished ladder at the next tick boundary (the caller
        is between ticks by construction)."""
        if self._pending_result is None:
            return
        result, runs = self._pending_result, self._pending_runs
        self._pending_result = self._pending_runs = None
        self._compile_thread = None
        eng = self.engine
        # Freshness gate: only buckets measured within the last check
        # window — the evidence that triggered this adoption — qualify as
        # probation comparators (see _recent_wall above).
        fresh_after = eng._completed_ticks - self.check_every
        self._swap_snapshot = {b: w for b, (w, at)
                               in self._recent_wall.items()
                               if at >= fresh_after}
        self._prev_deploy = eng.swap_plan(result.plan, runs)
        self.swaps += 1
        self._probation_samples = []
        self.state = PROBATION
        if self.on_swap is not None:
            self.on_swap(result)

    def _observe_probation(self) -> None:
        """Sample the newest completed tick against the freshest pre-swap
        wall of its bucket; after ``rollback_ticks`` samples, a median
        regression beyond ``rollback_factor`` re-arms the previous
        ladder. Failed ticks contribute no sample (a fault is not a plan
        regression — the fault injector must not trip rollbacks), and
        neither do ticks whose bucket has no fresh pre-swap comparator
        (a stale wall from before the environment shifted would read a
        better plan as a regression)."""
        last = self.engine.last_tick
        if not last or last.get("failed"):
            return
        base = self._swap_snapshot.get(last["bucket"])
        if base is not None and base > 0.0:
            self._probation_samples.append(float(last["wall_s"]) / base)
        if len(self._probation_samples) < self.rollback_ticks:
            return
        samples = sorted(self._probation_samples)
        med = samples[len(samples) // 2]
        if med > self.rollback_factor:
            old_plan, old_runs, old_scales = self._prev_deploy
            self.engine.swap_plan(old_plan, old_runs,
                                  act_scales=old_scales, rollback=True)
            self.rollbacks += 1
            self._cooldown = self.cooldown_checks
        else:
            # Healthy deployment: the new plan's steady state becomes the
            # inflation baseline (re-latched lazily from fresh EMAs).
            self._baseline_svc = {}
            self._baseline_disp = {}
        self._prev_deploy = None
        self._probation_samples = []
        self._ticks_since_check = 0
        self._settle = self.settle_checks
        self.state = MONITOR

    # ------------------------------------------------------ observability
    def stats(self) -> Dict[str, object]:
        cal = self.current_calibration()
        last = self.last_replan
        return {
            "state": self.state,
            "checks": self.checks,
            "swaps": self.swaps,
            "rollbacks": self.rollbacks,
            "cooldown": self._cooldown,
            "settle": self._settle,
            "inflation": self._inflation(),
            "inferred_scale": self._inferred_scale,
            "calibration_default": None if cal is None else cal.default,
            "tuning_refresh_scales": dict(self.refresh_scales),
            "last_replan": None if last is None else {
                "changed": last.changed,
                "adopted": last.adopted,
                "deployed_cost_s": last.deployed_cost_s,
                "candidate_cost_s": last.candidate_cost_s,
            },
        }
