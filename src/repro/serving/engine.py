"""Batched serving engine: prefill/decode split with continuous batching.

The engine keeps a fixed-size decode batch; finished sequences free their
slot, queued requests prefill into the free slot (KV written at the slot's
rows). A paged-lite allocator tracks per-slot lengths. This is the layer a
real cluster deployment drives; the dry-run's ``serve_step`` is its inner
loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import (decode_step, forward, init_cache,
                                logits_from_hidden)

PyTree = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass
class SlotState:
    rid: int = -1
    pos: int = 0
    remaining: int = 0


class ServingEngine:
    """Greedy-decoding engine over a fixed decode batch."""

    def __init__(self, cfg: ModelConfig, params: PyTree, batch_size: int,
                 max_len: int = 512) -> None:
        self.cfg = cfg
        self.params = params
        self.b = batch_size
        self.max_len = max_len
        self.cache = init_cache(cfg, batch_size, max_len)
        self.slots = [SlotState() for _ in range(batch_size)]
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, t, c, pos, cfg))

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        req.out_tokens = []
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.rid < 0:
                return i
        return None

    def _admit(self) -> None:
        """Continuous batching: prefill queued requests into free slots by
        feeding prompt tokens through the decode path at the slot rows.

        (Single-sequence prefill via decode keeps the engine simple and
        exactly reuses the serving cache layout; the batched prefill path
        exists in launch.steps for throughput-oriented deployments.)"""
        while self.queue and self._free_slot() is not None:
            slot = self._free_slot()
            req = self.queue.pop(0)
            self.slots[slot] = SlotState(rid=req.rid, pos=0,
                                         remaining=req.max_new_tokens)
            self.done[req.rid] = req
            for t in req.prompt:
                self._step_one(slot, int(t), emit=False)

    # ------------------------------------------------------------ decode
    def _step_one(self, slot: int, token: int, emit: bool) -> Optional[int]:
        s = self.slots[slot]
        tokens = np.zeros((self.b, 1), np.int32)
        tokens[slot, 0] = token
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.int32(s.pos))
        s.pos += 1
        if emit:
            return int(jnp.argmax(logits[slot]))
        return None

    def step(self) -> int:
        """One engine tick: admit, then decode one token for every active
        slot. Returns number of active slots."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.rid >= 0]
        if not active:
            return 0
        for i in active:
            s = self.slots[i]
            req = self.done[s.rid]
            last = (int(req.prompt[-1]) if not req.out_tokens
                    else req.out_tokens[-1])
            nxt = self._step_one(i, last, emit=True)
            req.out_tokens.append(nxt)
            s.remaining -= 1
            if s.remaining <= 0 or s.pos >= self.max_len - 1:
                self.slots[i] = SlotState()          # free the slot
        return len(active)

    def run_until_done(self, max_ticks: int = 1000) -> Dict[int, List[int]]:
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return {rid: r.out_tokens for rid, r in self.done.items()}
