"""Multi-tenant CNN serving: one engine process, many registered models.

``CNNServingEngine`` assumes one graph per process; serving a fleet that
way means one process per model, each with its own compile cache and its
own greedy tick loop — no coordination over the shared device, and every
tenant recompiles executables an identical architecture next door already
built. f-CNNx (arXiv 1805.10174) makes the FPGA version of this argument:
co-scheduled CNNs need a *joint* resource mapping, not per-model greedy
scheduling. This module is that layer on top of the PR 3-7 serving stack:

* ``register_model(name, graph, params, plan, slo_s=...)`` builds one
  ``CNNServingEngine`` per tenant, all sharing this engine's clock and
  one ``ExecutableCache`` — tenants whose graphs hash equal (same
  architecture, any params) share every ``(graph, plan, bucket, mesh)``
  bucket executable instead of recompiling, because compiled programs
  take params as call arguments and close over nothing model-specific.
* ``submit(model, req)`` routes to the tenant's own bounded admission
  (its ``max_queue``), after a *global* queue cap across all tenants —
  a globally rejected request still lands in the tenant's own outcome
  ledger (``CNNServingEngine.reject``), so per-tenant conservation
  (``completed + rejected_full + shed_deadline + failed + pending ==
  submitted``) holds with or without the global cap.
* ``step(now)`` is the joint tick scheduler: tenants are ranked by the
  deadline of their oldest queued request (``oldest_deadline``) and
  stepped in that order; each tenant's own wait policy
  (``dispatch_due``) and housekeeping (reap / shed / degrade) run
  unchanged, and successive ticks within one joint step see a clock
  advanced by the measured wall time of the ticks before them — the
  serial-device accounting virtual-clock replays rely on. An optional
  ``global_budget_s`` caps the wall time one joint step may spend:
  once the budget would be exceeded, remaining due tenants are skipped
  until the next step (their housekeeping waits with them — the cost
  of not dispatching is also not paying the bookkeeping).

Per-tenant SLOs, outcome ledgers, robustness knobs (``max_queue``,
``shed_deadline``, ``fault_plan``, ``degrade``) and ``stats()`` all keep
their single-model semantics — the joint layer only decides *which*
tenant ticks next, never how a tenant ticks.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.cnn.executor import ExecutableCache
from repro.serving.cnn_engine import CNNRequest, CNNServingEngine


class MultiModelEngine:
    """Joint deadline-ordered tick scheduler over per-model engines.

    ``cache`` defaults to a fresh ``ExecutableCache`` shared by every
    registered tenant (pass one in to share across MultiModelEngine
    instances too). ``global_max_queue`` bounds the *sum* of tenant
    queues — submissions past it are rejected into the owning tenant's
    ledger. ``global_budget_s`` caps the measured wall time one
    ``step()`` may spend dispatching across tenants (the first due
    tick always runs: a budget smaller than any single tick must not
    starve the engine). ``clock`` is shared by all tenants so joint
    deadline ordering compares like timestamps.
    """

    def __init__(self,
                 clock: Callable[[], float] = time.monotonic,
                 global_budget_s: Optional[float] = None,
                 global_max_queue: Optional[int] = None,
                 cache: Optional[ExecutableCache] = None) -> None:
        if global_max_queue is not None and global_max_queue < 1:
            raise ValueError(
                f"global_max_queue must be >= 1, got {global_max_queue}")
        if global_budget_s is not None and global_budget_s <= 0:
            raise ValueError(
                f"global_budget_s must be > 0, got {global_budget_s}")
        self._clock = clock
        self.global_budget_s = global_budget_s
        self.global_max_queue = global_max_queue
        self.cache = cache if cache is not None else ExecutableCache()
        self.engines: Dict[str, CNNServingEngine] = {}
        self._order: List[str] = []        # registration order (tiebreak)
        self.last_step: Optional[Dict[str, object]] = None

    # ---------------------------------------------------------- tenants
    def register_model(self, name: str, graph, params, plan,
                       slo_s: Optional[float] = None,
                       **engine_kwargs) -> CNNServingEngine:
        """Build and register one tenant engine. The engine shares this
        multi-engine's clock and executable cache; every other
        ``CNNServingEngine`` knob passes through ``engine_kwargs``
        (``buckets``, ``mesh``, ``max_queue``, ``fault_plan``, ...).
        ``pipeline_depth`` must stay 1: the joint scheduler charges each
        tick's measured wall time to the shared virtual clock, which an
        asynchronously retiring tick would misreport."""
        if name in self.engines:
            raise ValueError(f"model {name!r} already registered")
        for k in ("clock", "cache"):
            if k in engine_kwargs:
                raise ValueError(
                    f"{k!r} is owned by MultiModelEngine — every tenant "
                    "shares the joint clock and executable cache")
        if int(engine_kwargs.get("pipeline_depth", 1)) != 1:
            raise ValueError(
                "multi-model tenants must use pipeline_depth=1: joint "
                "virtual-time accounting assumes synchronous ticks")
        eng = CNNServingEngine(graph, params, plan, slo_s=slo_s,
                               clock=self._clock, cache=self.cache,
                               **engine_kwargs)
        self.engines[name] = eng
        self._order.append(name)
        return eng

    def model_names(self) -> List[str]:
        return list(self._order)

    def swap_plan(self, model: str, plan, runs=None, *,
                  act_scales=None, rollback: bool = False) -> tuple:
        """Hot-swap one tenant's deployed plan
        (``CNNServingEngine.swap_plan`` on that tenant, between joint
        ticks). Tenant isolation holds by construction: the shared
        ``ExecutableCache`` never evicts, so compiling the new ladder can
        only *add* entries (other tenants' executables stay resident),
        and every other tenant's ladder, ledger, queue, and EMAs are
        untouched (``tests/test_multi_model.py`` pins this)."""
        return self._engine(model).swap_plan(
            plan, runs, act_scales=act_scales, rollback=rollback)

    def _engine(self, model: str) -> CNNServingEngine:
        try:
            return self.engines[model]
        except KeyError:
            raise KeyError(
                f"unknown model {model!r}; registered: {self._order}"
            ) from None

    # ------------------------------------------------------------ intake
    def submit(self, model: str, req: CNNRequest) -> str:
        """Route one request to its tenant. The global queue cap is
        checked first; past it the request is rejected *into the
        tenant's ledger* so per-tenant conservation survives the global
        policy. Otherwise the tenant's own admission (its ``max_queue``)
        decides. Returns the admission verdict."""
        eng = self._engine(model)
        if (self.global_max_queue is not None
                and self.queued_total() >= self.global_max_queue):
            return eng.reject(req)
        return eng.submit(req)

    def queued_total(self) -> int:
        """Requests currently queued across all tenants (the quantity
        the global cap bounds; in-flight and done are not queued)."""
        return sum(len(eng.queue) for eng in self.engines.values())

    # ------------------------------------------------------------- serve
    def next_dispatch_at(self) -> Optional[float]:
        """Earliest engine-clock time any tenant would dispatch without
        new arrivals — None when every queue is empty. Trace replays use
        this as the joint wake-up."""
        times = [eng.next_dispatch_at() for eng in self.engines.values()]
        times = [t for t in times if t is not None]
        return min(times) if times else None

    def _deadline_rank(self, now: float):
        """Tenant names ranked for this joint step: earliest oldest-
        request deadline first, empty queues last, registration order
        breaking ties."""
        def key(item):
            idx, name = item
            d = self.engines[name].oldest_deadline()
            return (d is None, d if d is not None else 0.0, idx)
        return [name for _, name in
                sorted(enumerate(self._order), key=lambda it: key(it))]

    def step(self, now: Optional[float] = None, flush: bool = False) -> int:
        """One joint tick round: step tenants in deadline order, each
        seeing the shared clock advanced by the measured wall time of
        the ticks dispatched before it this round (the device is serial
        — tenant B's tick cannot start until tenant A's finished). Each
        tenant's own ``step`` applies its wait policy and housekeeping
        unchanged, so a not-yet-due tenant contributes 0 and loses
        nothing. Under ``global_budget_s``, once at least one tick ran,
        a due tenant whose estimated next tick would blow the budget is
        skipped until the next round (``flush=True`` ignores the
        budget: drains must terminate). Returns total requests
        dispatched; details land in ``last_step``."""
        if now is None:
            now = self._clock()
        served, ticks, spent = 0, 0, 0.0
        skipped: List[str] = []
        for name in self._deadline_rank(now):
            eng = self.engines[name]
            if (not flush and self.global_budget_s is not None
                    and ticks > 0 and eng.queue
                    and eng.dispatch_due(now + spent)):
                est = eng.service_estimate(
                    eng.covering_bucket(len(eng.queue)))
                if spent + est > self.global_budget_s:
                    skipped.append(name)
                    continue
            n = eng.step(now=now + spent, flush=flush)
            if n:
                served += n
                ticks += 1
                if eng.last_tick is not None:
                    spent += float(eng.last_tick["wall_s"])
        self.last_step = {"served": served, "ticks": ticks,
                          "wall_s": spent, "skipped": tuple(skipped)}
        return served

    # ----------------------------------------------------------- results
    def poll(self, model: str, rid: int) -> Optional[np.ndarray]:
        return self._engine(model).poll(rid)

    def drain(self) -> Dict[str, Dict[int, np.ndarray]]:
        """Retire everything in flight, per tenant. Queued requests are
        NOT dispatched — ``run_until_done`` is the drain-the-world
        loop."""
        return {name: self.engines[name].drain() for name in self._order}

    def run_until_done(self, max_ticks: int = 1000
                       ) -> Dict[str, Dict[int, np.ndarray]]:
        """Flush joint rounds until every tenant queue is empty, then
        drain. Returns each tenant's ``done`` map."""
        for _ in range(max_ticks):
            if not any(eng.queue for eng in self.engines.values()):
                break
            self.step(flush=True)
        else:
            raise RuntimeError(f"queues not drained in {max_ticks} rounds")
        self.drain()
        return {name: dict(self.engines[name].done)
                for name in self._order}

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict[str, object]:
        """Joint view: per-model ``CNNServingEngine.stats()`` under
        ``"models"`` (unchanged schema), shared-cache counters under
        ``"cache"``, and the joint scheduler's knobs/aggregates under
        ``"global"``."""
        models = {name: self.engines[name].stats() for name in self._order}
        return {
            "models": models,
            "cache": self.cache.stats(),
            "global": {
                "models": len(self._order),
                "submitted": sum(e.submitted_total
                                 for e in self.engines.values()),
                "queued": self.queued_total(),
                "global_max_queue": self.global_max_queue,
                "global_budget_s": self.global_budget_s,
                "last_step": self.last_step,
            },
        }
