"""Bucketed dynamic-batching CNN serving engine over compiled overlay
programs.

PR-2's engine ran ONE fixed batch shape: a lone request paid the full
batch-8 latency and bursts queued behind a single executable — the
utilization cliff DYNAMAP's dynamic-mapping overlay exists to avoid (§3).
This engine compiles one overlay program per *batch bucket* (powers of two
up to ``batch_size``) and schedules ticks against a per-request latency
SLO:

* each bucket's executable is lowered under the ``(signature, bucket)``
  tuning winner (``compile_plan(..., tuning_batch=bucket)``) — the binding
  measured *at that batch size*, not the batch-1 winner;
* ``step()`` picks the smallest bucket covering the queue. While the
  oldest request still has deadline budget (``slo_s`` minus the bucket's
  estimated service time), the tick *waits* to fill a larger bucket;
  once the budget is nearly spent — or the largest bucket fills — it
  dispatches, zero-padding any empty tail slots;
* with ``slo_s=None`` every tick dispatches immediately through the
  smallest covering bucket (the latency-greedy policy; also the PR-2
  compatible default).

Staging buffers sized for the largest bucket are allocated once; bucket
dispatches slice their leading rows, and only stale slots left by a
previous larger tick are re-zeroed (never the whole buffer).

Pipelined execution (``pipeline_depth >= 2``) makes the tick loop
asynchronous: ``step()`` *launches* the bucket executable (JAX dispatch
is async — the call returns an in-flight array, not a result) and
records an ``InflightTick`` instead of blocking, so the host packs tick
N+1 while the device computes tick N. Completion — ``block_until_ready``
+ unpack + ``RequestTrace`` — happens lazily: at the start of the next
``step()`` for ticks whose results are already ready, when the pipeline
is full and the oldest tick's staging buffer must be reclaimed, on an
explicit ``drain()``, or when a requester ``poll()``s for its result.
Staging rotates across ``pipeline_depth`` host buffers so the buffer a
tick was packed from is never overwritten while that tick may still be
reading it (the JAX CPU backend can alias host memory). Bucket
executables are compiled with ``donate=True`` so each tick's device
input buffer is reused across ticks instead of growing the live set.
``pipeline_depth=1`` (default) is the fully synchronous engine with
byte-for-byte identical scheduling, accounting and trace semantics.

Robustness (overload + faults) — every request ends in exactly one
``RequestOutcome``, and the four counters conserve
(``completed + rejected_full + shed_deadline + failed + pending ==
submitted``):

* **bounded admission** — ``max_queue=N`` rejects at ``submit()`` once
  the queue holds N requests (outcome ``rejected_full``) instead of
  growing without limit;
* **deadline shedding** — ``shed_deadline=True`` (with an ``slo_s``)
  drops queued requests whose deadline is already unmeetable *even by
  the cheapest bucket's measured service estimate* before they occupy a
  bucket slot (outcome ``shed_deadline``);
* **fault-injected tick retry** — a ``distributed.fault.FaultPlan``
  fails or delays planned ticks (dispatch- or completion-surfaced,
  emulating async device faults/stragglers on this CPU-only host);
  dispatch wraps in a bounded retry-with-backoff loop (``max_retries``,
  ``retry_backoff_s``) replaying from the tick's pinned staging buffer,
  and a tick that exhausts retries fails its requests cleanly (outcome
  ``failed``; pipeline slot and staging buffer reclaimed, service EMAs
  untouched, later ticks unaffected — including in-flight ticks at
  ``pipeline_depth >= 2``);
* **graceful degradation** — ``degrade=DegradeConfig(...)`` arms a
  hysteresis controller: sustained queue pressure or consecutive
  service-time spikes (``distributed.fault.robust_zscore`` over the
  recent tick history) switch the scheduler to dispatch-immediately
  smallest-bucket mode; SLO batching is restored only after the queue
  stays below the exit watermark for ``exit_ticks`` consecutive ticks.

All four knobs default OFF, in which case scheduling, outputs and
accounting are bit-for-bit the pre-robustness engine.
``stats()["robustness"]`` reports outcome counters, retries, failed
ticks, degrade transitions and the queue high-water mark either way.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Sequence, Set

import jax
import numpy as np

from repro.cnn.executor import compile_plan
from repro.core.algorithms import Algorithm, IM2COL
from repro.core.graph import Graph
from repro.core.mapper import ExecutionPlan
from repro.distributed.fault import DeviceFault, FaultPlan, robust_zscore

# The four terminal request outcomes (RequestTrace.outcome). Exactly one
# per submitted request; the engine's conservation invariant is
#   completed + rejected_full + shed_deadline + failed + pending
#     == submitted.
OUTCOME_COMPLETED = "completed"
OUTCOME_REJECTED = "rejected_full"
OUTCOME_SHED = "shed_deadline"
OUTCOME_FAILED = "failed"


def batch_buckets(max_batch: int, shard: int = 1) -> List[int]:
    """Power-of-two bucket ladder up to ``max_batch`` (inclusive — a
    non-power-of-two cap becomes the top bucket). ``shard`` > 1 builds the
    mesh-sharded ladder: every bucket is a multiple of the data-shard
    count (``shard``, ``2*shard``, ``4*shard``, ...), so each bucket's
    padded batch splits evenly across the mesh's data axes — jit input
    shardings reject uneven partitions, and a bucket a mesh cannot place
    would be a compile-time landmine. The cap itself must divide."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    if shard < 1:
        raise ValueError(f"shard must be >= 1, got {shard}")
    if max_batch % shard:
        raise ValueError(
            f"max_batch {max_batch} is not a multiple of the data-shard "
            f"count {shard}; the top bucket could not be placed on the mesh")
    out = []
    b = shard
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return out


@dataclasses.dataclass
class CNNRequest:
    rid: int
    image: np.ndarray                  # (H, W, C)
    # Stamped at submit() (engine clock) unless the caller provides it —
    # trace replays inject virtual arrival times here.
    t_submit: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RequestTrace:
    """Per-request lifecycle accounting (engine-clock timestamps; the
    service leg is the tick's measured wall time, so with a virtual clock
    latency still combines simulated queueing with real service time —
    the same accounting the bench replay harness uses). ``outcome`` is
    the request's terminal state: ``completed`` requests carry the full
    submit→dispatch→done timeline; ``rejected_full`` / ``shed_deadline``
    / ``failed`` records stamp the decision time into ``t_dispatch`` /
    ``t_done`` with ``service_s == 0`` (no device work was billed to
    them) and ``bucket`` the tick's bucket for failures, 0 otherwise."""
    rid: int
    t_submit: float
    t_dispatch: float
    t_done: float
    bucket: int
    queue_s: float
    service_s: float
    latency_s: float
    slo_ok: bool
    outcome: str = OUTCOME_COMPLETED


@dataclasses.dataclass(frozen=True)
class DegradeConfig:
    """Hysteresis thresholds for the overload degrade mode.

    Enter when the queue reaches ``enter_queue`` (default: 3× the top
    bucket) OR the last ``straggler_patience`` completed ticks were all
    service-time spikes (``robust_zscore`` over the trailing ``window``
    tick history exceeding ``straggler_k`` — the same median/MAD
    statistic ``StragglerMonitor`` applies across hosts). While active,
    ``step()`` dispatches immediately through the smallest covering
    bucket (no SLO waiting — under sustained overload, batching up
    latency-optimal buckets only deepens the backlog). Exit after the
    queue has stayed at or below ``exit_queue`` (default: the top
    bucket) with no fresh spike for ``exit_ticks`` consecutive ticks —
    entry and exit thresholds are deliberately separated so the mode
    cannot flap around a single watermark."""
    enter_queue: Optional[int] = None
    exit_queue: Optional[int] = None
    exit_ticks: int = 3
    straggler_k: float = 4.0
    straggler_patience: int = 2
    window: int = 32


@dataclasses.dataclass
class InflightTick:
    """One dispatched-but-not-retired tick: the in-flight device output
    plus everything completion needs to unpack it and write traces. The
    staging buffer index pins which rotating host buffer this tick was
    packed from — that buffer is not reused until this tick retires.
    ``run`` pins the bucket executable the tick was dispatched on: a plan
    hot-swap between dispatch and retirement must not change what an
    in-flight tick computes, so completion-surfaced fault replays re-run
    THIS callable, never the (possibly swapped) current ladder's."""
    bucket: int
    reqs: List[CNNRequest]
    out: object                        # in-flight jax.Array
    t_dispatch: float                  # engine clock at dispatch
    t_launch_pc: float                 # perf_counter at dispatch
    t_launched_pc: float               # perf_counter after dispatch returned
    ready_at_pc: float                 # t_launch_pc + injected device delay
    buf_index: int
    tick_idx: int = 0                  # global dispatch index (FaultPlan key)
    fault: object = None               # planned TickFault for this tick
    attempt: int = 0                   # dispatch attempts already burned
    run: object = None                 # executable the tick dispatched on


class CNNServingEngine:
    """Batches single-image requests through per-bucket compiled plans.

    ``batch_size`` caps the largest bucket; ``buckets`` overrides the
    power-of-two ladder (must be ascending, e.g. ``(2, 8)`` to forbid
    singleton dispatches). ``slo_s`` is the per-request latency objective
    driving the tick scheduler; ``clock`` injects a time source (tests and
    trace replays pass a virtual clock). ``warmup=True`` runs one padded
    tick per bucket at construction, pre-compiling every executable and
    priming the per-bucket service-time estimates the scheduler uses.
    ``trace_window`` bounds the per-request ``RequestTrace`` log backing
    the ``stats()`` latency aggregates (totals and SLO-violation counters
    keep counting past the window).

    ``mesh`` (a ``jax.sharding.Mesh``, e.g. ``launch.mesh.make_data_mesh``)
    turns on data-parallel multi-chip serving: every bucket executable is
    compiled with its batch dimension sharded across the mesh's data axes
    and params replicated (placed once, at construction). The bucket
    ladder is then built in multiples of the data-shard count so every
    padded dispatch splits evenly across chips, and tuning-record lookups
    key off the *per-chip* batch (``bucket // data_shards``) — a winner
    measured at per-chip batch N on one chip is exactly the workload each
    chip runs in a sharded bucket of ``N * data_shards``, so existing
    single-device records transfer unchanged.

    ``pipeline_depth`` >= 2 turns on asynchronous, double-buffered ticks:
    up to ``pipeline_depth`` dispatches stay in flight, staging rotates
    across that many host buffers, executables donate their batched input
    (device memory reused tick to tick), and results land in ``done``
    lazily — on later ``step()`` calls, on ``drain()``, or via
    ``poll(rid)``. Depth 1 (default) is the synchronous engine unchanged.
    ``device_delay_s`` injects a per-tick device-side delay (a tick is not
    considered ready until that long after its dispatch) — a test/bench
    hook that emulates a slower real accelerator on fast-host/slow-device
    ratios CPU CI cannot otherwise produce.

    Robustness knobs (all default OFF — see the module docstring for the
    outcome/conservation model): ``max_queue`` bounds admission,
    ``shed_deadline`` drops already-hopeless queued requests,
    ``fault_plan`` injects deterministic per-tick faults/delays with
    ``max_retries`` bounded re-dispatches (``retry_backoff_s`` base
    backoff, doubling per attempt) and ``degrade`` arms the overload
    degrade controller. ``submit()`` returns the admission verdict
    (``"queued"`` or ``"rejected_full"``) and raises ``ValueError`` on a
    duplicate ``rid`` — a reused rid would silently overwrite the
    earlier result in ``done`` and corrupt ``poll()``/``drain()``
    accounting.

    ``cache`` (an ``ExecutableCache``) shares compiled bucket executables
    across engines: tenants of the multi-model engine whose graphs hash
    equal reuse one jitted program per ``(graph, plan, bucket, mesh)``
    instead of recompiling. Safe because compiled programs take params as
    call arguments (nothing model-specific is closed over); per-engine
    fault hooks wrap *outside* the cached callable.
    """

    def __init__(self, graph: Graph, params, plan: Optional[ExecutionPlan],
                 batch_size: int = 8,
                 buckets: Optional[Sequence[int]] = None,
                 slo_s: Optional[float] = None,
                 default_algo: Algorithm = IM2COL,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 dtype=np.float32,
                 epilogue: str = "bias_relu",
                 tuning=None,
                 clock: Callable[[], float] = time.monotonic,
                 warmup: bool = False,
                 trace_window: int = 2048,
                 mesh=None,
                 pipeline_depth: int = 1,
                 device_delay_s: float = 0.0,
                 max_queue: Optional[int] = None,
                 shed_deadline: bool = False,
                 fault_plan: Optional[FaultPlan] = None,
                 max_retries: int = 2,
                 retry_backoff_s: float = 0.0,
                 degrade: Optional[DegradeConfig] = None,
                 cache=None,
                 act_scales: Optional[Dict[int, float]] = None) -> None:
        self.graph = graph
        self.mesh = mesh
        self.cache = cache
        # Per-layer precision map of the served plan (bf16 when the plan
        # carries none) — surfaced by stats()["precision"]; act_scales
        # feed every bucket executable's int8 layers and key the shared
        # executable cache (see compile_plan).
        self.act_scales = act_scales
        self.precisions = dict(getattr(plan, "precisions", None) or {}) \
            if plan is not None else {}
        if pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}")
        self.pipeline_depth = int(pipeline_depth)
        self.device_delay_s = float(device_delay_s)
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.max_queue = max_queue
        self.shed_deadline = bool(shed_deadline)
        self.fault_plan = fault_plan
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        if mesh is not None:
            from repro.distributed.sharding import (data_shard_count,
                                                    replicated)
            self.data_shards = data_shard_count(mesh)
            # Replicate params across the mesh ONCE — jit would otherwise
            # re-transfer them to every chip on every tick.
            params = jax.device_put(params, replicated(mesh))
        else:
            self.data_shards = 1
        self.params = params
        self.buckets = (sorted(set(int(b) for b in buckets)) if buckets
                        else batch_buckets(batch_size, self.data_shards))
        if self.buckets[0] < 1:
            raise ValueError(f"buckets must be >= 1, got {self.buckets}")
        bad = [b for b in self.buckets if b % self.data_shards]
        if bad:
            raise ValueError(
                f"buckets {bad} are not multiples of the mesh's data-shard "
                f"count {self.data_shards} — their padded batches could "
                "not be placed")
        self.b = self.buckets[-1]              # largest bucket (PR-2 name)
        self.slo_s = slo_s
        self.dtype = np.dtype(dtype)
        self.queue: List[CNNRequest] = []
        self.done: Dict[int, np.ndarray] = {}
        self._clock = clock
        # The graph's input node pins the only image shape the compiled
        # programs can accept — validate against it, never against traffic.
        src = graph.nodes[graph.source()]
        self._shape = tuple(int(d) for d in src.attrs["out_shape"])
        # One executable per bucket: the bucket's tuning winner (measured
        # at that batch size) binds its lowering, so executables genuinely
        # differ — this is the multi-executable cache the fixed-batch
        # engine could not have. Under a mesh, each chip runs a per-chip
        # slice of the bucket, so the tuning lookup keys off that per-chip
        # batch — the workload a chip actually executes. Pipelined engines
        # donate the batched input: ticks are re-staged from host buffers
        # every dispatch, so the device-side input buffer of tick N is
        # dead the moment N's outputs exist and XLA may reuse it.
        # Fault-plan engines thread a dispatch hook through every bucket
        # executable (fault_plan=None threads nothing — the executables
        # are the exact unhooked callables). The hook reads the
        # (tick index, attempt) context the dispatch path sets around
        # each invocation; warmup never sets one, so warmup ticks can
        # neither consume nor trip planned faults.
        self._fault_ctx: tuple = (None, 0)
        # The deployed plan plus everything needed to rebuild the ladder
        # for a DIFFERENT plan with identical compile options — the
        # hot-swap path (``compile_ladder``/``swap_plan``) recompiles with
        # exactly these, so a swapped engine differs from a fresh one only
        # in the plan.
        self.plan = plan
        self.tuning = tuning
        self._compile_kw = dict(default_algo=default_algo,
                                use_pallas=use_pallas, interpret=interpret,
                                epilogue=epilogue, tuning=tuning)
        self.plan_swaps = 0
        self.plan_rollbacks = 0
        self._runs = self.compile_ladder(plan, act_scales=act_scales,
                                         warm=False)
        # Rotating staging buffers sized for the largest bucket, allocated
        # ONCE (one per pipeline slot; the synchronous engine keeps the
        # single PR-3 buffer). _filled tracks, per buffer, how many leading
        # slots hold stale images from the tick that last used it, so only
        # slots a dispatch would leak are re-zeroed.
        self._batch_bufs = [np.zeros((self.b,) + self._shape, self.dtype)
                            for _ in range(self.pipeline_depth)]
        self._filled = [0] * self.pipeline_depth
        self._buf_cursor = 0
        # In-flight dispatches, oldest first (completion is FIFO: the
        # device executes ticks in dispatch order).
        self._inflight: Deque[InflightTick] = collections.deque()
        # Serial-device completion model: a tick's service time is its
        # completion minus max(its launch, the previous completion) — the
        # device-occupancy time, NOT the host-blocking wall time, which
        # under pipelining would double-count time spent queued behind the
        # previous tick.
        self._last_ready_pc = float("-inf")
        self._last_done = float("-inf")        # engine-clock completion
        # Overlap accounting: how much device-busy time elapsed while the
        # host was NOT blocked waiting on it (stats()["pipeline"]).
        self._overlap_s = 0.0
        self._device_busy_s = 0.0
        self._dispatched_ticks = 0
        self._completed_ticks = 0
        # Measured per-bucket service time (EMA) — the scheduler's estimate
        # of how much deadline budget a dispatch will consume.
        self._svc: Dict[int, Optional[float]] = {b: None for b in self.buckets}
        self.dispatches: Dict[int, int] = {b: 0 for b in self.buckets}
        self.last_tick: Optional[Dict[str, object]] = None
        # --- observability (ROADMAP item): per-request lifecycle records
        # in a bounded window plus running totals, surfaced by stats().
        self.request_log: Deque[RequestTrace] = \
            collections.deque(maxlen=trace_window)
        self.submitted_total = 0
        self.served_total = 0
        self.slo_violations = 0
        # --- robustness accounting (outcome conservation + retry/degrade
        # bookkeeping; all zero and inert when the knobs are off).
        self.rejected_total = 0
        self.shed_total = 0
        self.failed_total = 0
        self.retries_total = 0
        self.failed_ticks = 0
        self.queue_high_water = 0
        self.failed: Dict[int, int] = {}       # rid -> faulted tick index
        self.shed_rids: Set[int] = set()
        self._pending_rids: Set[int] = set()   # queued, not yet dispatched
        self._inflight_rids: Set[int] = set()  # dispatched, not retired
        # Global dispatch index (FaultPlan key): every tick that consumes
        # requests burns one, whether or not its launch ever succeeds —
        # fault schedules must stay aligned with the dispatch sequence.
        self._tick_seq = 0
        # --- degrade controller (armed only when a config is passed).
        self._degrade_cfg = degrade
        self._degrade_active = False
        self._degrade_entries = 0
        self._degrade_exits = 0
        self._degrade_calm = 0                 # consecutive calm ticks
        self._spikes_total = 0
        self._spike_streak = 0
        if degrade is not None:
            self._enter_q = (degrade.enter_queue
                             if degrade.enter_queue is not None
                             else 3 * self.b)
            self._exit_q = (degrade.exit_queue
                            if degrade.exit_queue is not None else self.b)
            if self._exit_q >= self._enter_q:
                raise ValueError(
                    f"degrade exit_queue {self._exit_q} must be below "
                    f"enter_queue {self._enter_q} (hysteresis)")
            self._svc_hist: Deque[float] = \
                collections.deque(maxlen=degrade.window)
        if warmup:
            self._warmup()

    @property
    def _batch_buf(self) -> np.ndarray:
        """The synchronous engine's single staging buffer (buffer 0) —
        kept as the PR-3 name for tests and tooling."""
        return self._batch_bufs[0]

    # ------------------------------------------------------------ intake
    def submit(self, req: CNNRequest) -> str:
        """Enqueue one request; returns the admission verdict —
        ``"queued"``, or ``"rejected_full"`` when ``max_queue`` is set
        and already reached (the rejection is a first-class outcome:
        counted, traced, conserved — never a silent drop). Images are
        cast to the engine dtype and validated against the graph's
        (H, W, C) input shape here, so a bad request can never crash a
        tick or drag good requests down with it; a ``rid`` already live
        anywhere in the engine (queued, in flight, completed or failed)
        raises — a reused rid would overwrite the earlier result in
        ``done`` and corrupt ``poll()``/``drain()`` accounting."""
        img = np.asarray(req.image, dtype=self.dtype)
        if img.shape != self._shape:
            raise ValueError(
                f"request {req.rid}: image shape {img.shape} != "
                f"graph input shape {self._shape}")
        if (req.rid in self._pending_rids or req.rid in self._inflight_rids
                or req.rid in self.done or req.rid in self.failed):
            raise ValueError(
                f"request {req.rid}: duplicate rid — already "
                + ("queued" if req.rid in self._pending_rids else
                   "in flight" if req.rid in self._inflight_rids else
                   "completed" if req.rid in self.done else "failed"))
        req.image = img                # persist the validated array
        if req.t_submit is None:
            req.t_submit = self._clock()
        self.submitted_total += 1
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            return self._record_rejection(req)
        self.queue.append(req)
        self._pending_rids.add(req.rid)
        self.queue_high_water = max(self.queue_high_water, len(self.queue))
        return "queued"

    def reject(self, req: CNNRequest) -> str:
        """Externally imposed admission rejection — the multi-model
        engine's *global* queue cap lands here: the request is counted
        as submitted and rejected in THIS engine's ledger (traced,
        conserved — a cap above the engine must not break the per-tenant
        conservation invariant), without entering the queue. Like a
        ``max_queue`` rejection, the rid never entered the engine and may
        be resubmitted."""
        if req.t_submit is None:
            req.t_submit = self._clock()
        self.submitted_total += 1
        return self._record_rejection(req)

    def _record_rejection(self, req: CNNRequest) -> str:
        """Stamp one rejection into the ledger (counter + trace): the
        shared tail of ``submit()``'s bounded-admission path and the
        external ``reject()`` path."""
        self.rejected_total += 1
        self.request_log.append(RequestTrace(
            rid=req.rid, t_submit=req.t_submit,
            t_dispatch=req.t_submit, t_done=req.t_submit,
            bucket=0, queue_s=0.0, service_s=0.0, latency_s=0.0,
            slo_ok=False, outcome=OUTCOME_REJECTED))
        return OUTCOME_REJECTED

    # --------------------------------------------------------- scheduling
    def covering_bucket(self, n: int) -> int:
        """Smallest bucket holding ``n`` requests (the largest bucket for
        any overflow — excess requests wait for the next tick)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.b

    def service_estimate(self, bucket: int) -> float:
        """Expected service time of one ``bucket`` dispatch. Unmeasured
        buckets borrow the largest measured smaller bucket's time (a lower
        bound — batched ticks only get slower), else 0: the scheduler then
        waits the full SLO before dispatching, which is the conservative
        larger-batch-favoring choice."""
        est = self._svc.get(bucket)
        if est is not None:
            return est
        known = [b for b in self._svc
                 if self._svc[b] is not None and b < bucket]
        return self._svc[max(known)] if known else 0.0

    def next_dispatch_at(self) -> Optional[float]:
        """Engine-clock time at which ``step()`` will dispatch without new
        arrivals — None when the queue is empty. Trace replays and serving
        loops use this as the next tick wake-up."""
        if not self.queue:
            return None
        oldest = self.queue[0]
        assert oldest.t_submit is not None
        if (self.slo_s is None or self._degrade_active
                or len(self.queue) >= self.b):
            return oldest.t_submit          # dispatch immediately
        bucket = self.covering_bucket(len(self.queue))
        wait = max(0.0, self.slo_s - self.service_estimate(bucket))
        return oldest.t_submit + wait

    def oldest_deadline(self) -> Optional[float]:
        """Deadline of the oldest queued request (``t_submit + slo_s``, or
        bare ``t_submit`` with no SLO) — None when the queue is empty. The
        multi-model scheduler orders due tenants by this: earliest
        deadline across models dispatches first."""
        if not self.queue:
            return None
        oldest = self.queue[0]
        assert oldest.t_submit is not None
        if self.slo_s is None:
            return oldest.t_submit
        return oldest.t_submit + self.slo_s

    def dispatch_due(self, now: float) -> bool:
        """True when ``step(now)`` would dispatch rather than wait: a full
        largest bucket, active degrade mode (batching for latency is
        pointless under overload), or the SLO wait budget of the oldest
        request is spent. The per-model policy predicate the joint
        multi-model scheduler consults without mutating engine state."""
        if not self.queue:
            return False
        if len(self.queue) >= self.b or self._degrade_active:
            return True
        at = self.next_dispatch_at()
        return at is None or now >= at

    # ------------------------------------------------------------- serve
    def step(self, now: Optional[float] = None, flush: bool = False) -> int:
        """One engine tick. Picks the smallest bucket covering the queue;
        under an SLO it *waits* (returns 0) while the oldest request still
        has deadline budget to fill a larger bucket, and dispatches early
        once that budget is nearly spent — ``flush=True`` dispatches
        unconditionally (drain/shutdown). Returns the number dispatched.

        Synchronous (depth 1) the dispatch blocks and results are in
        ``done`` on return; pipelined, the tick is launched asynchronously
        and retires lazily (any already-ready older ticks retire here
        first, and the oldest is force-retired when the pipeline is
        full). A tick whose planned fault exhausts ``max_retries`` still
        returns its batch size — its requests were consumed (outcome
        ``failed``), not left queued.

        Structured as housekeeping → wait policy (``dispatch_due``) →
        ``_dispatch_tick``; the multi-model engine reuses the same pieces
        but ranks tenants between the policy check and the dispatch."""
        if self._inflight:
            self._reap()                    # lazy completion of ready ticks
        if self._degrade_cfg is not None:
            self._degrade_update()
        if not self.queue:
            return 0
        if now is None:
            now = self._clock()
        if self.shed_deadline and self.slo_s is not None:
            self._shed_hopeless(now)
            if not self.queue:
                return 0
        if not flush and not self.dispatch_due(now):
            return 0                        # wait to fill a larger bucket
        return self._dispatch_tick(now)

    def _dispatch_tick(self, now: float) -> int:
        """The tick core: carve the covering bucket off the queue, stage,
        launch (with fault retry), and either complete synchronously or
        enqueue the in-flight tick. Callers are responsible for the wait
        policy — this always dispatches."""
        bucket = self.covering_bucket(len(self.queue))
        batch, self.queue = self.queue[:bucket], self.queue[bucket:]
        for req in batch:
            self._pending_rids.discard(req.rid)
            self._inflight_rids.add(req.rid)
        if len(self._inflight) >= self.pipeline_depth:
            # Pipeline full: the next staging buffer still belongs to the
            # oldest in-flight tick — retire it (blocking) to reclaim.
            self._complete(self._inflight.popleft())
        x = self._stage(batch)
        tick_idx = self._tick_seq
        self._tick_seq += 1
        fault = (self.fault_plan.get(tick_idx)
                 if self.fault_plan is not None else None)
        t_launch = time.perf_counter()
        out, attempt = self._launch(bucket, x, tick_idx, fault)
        t_launched = time.perf_counter()
        tick = InflightTick(bucket=bucket, reqs=batch, out=out,
                            t_dispatch=now, t_launch_pc=t_launch,
                            t_launched_pc=t_launched,
                            ready_at_pc=(t_launch + self.device_delay_s
                                         + (fault.delay_s if fault else 0.0)),
                            buf_index=self._last_buf_index,
                            tick_idx=tick_idx, fault=fault, attempt=attempt,
                            run=self._runs[bucket])
        if out is None:
            # Launch retries exhausted: fail cleanly — requests get their
            # terminal outcome, the staging buffer is simply left to the
            # normal stale-slot reclaim, and no pipeline slot was taken.
            self._fail_tick(tick)
            return len(batch)
        self.dispatches[bucket] += 1
        self._dispatched_ticks += 1
        if self.pipeline_depth == 1:
            self._complete(tick)            # synchronous: block right here
        else:
            self._inflight.append(tick)
        return len(batch)

    def _launch(self, bucket: int, x: np.ndarray, tick_idx: int,
                fault) -> tuple:
        """Invoke the bucket executable under the fault context, retrying
        dispatch-surfaced ``DeviceFault``s with bounded backoff. Returns
        ``(in-flight output, attempts burned)`` — ``(None, n)`` when
        retries are exhausted. Completion-surfaced faults never raise
        here; ``_complete`` replays them from the pinned staging
        buffer."""
        attempt = 0
        while True:
            try:
                self._fault_ctx = (tick_idx, attempt)
                return self._runs[bucket](self.params, x[:bucket]), attempt
            except DeviceFault:
                if attempt >= self.max_retries:
                    return None, attempt
                self.retries_total += 1
                self._backoff_sleep(attempt)
                attempt += 1
            finally:
                self._fault_ctx = (None, 0)

    def _fault_hook(self) -> None:
        """Per-invocation dispatch hook threaded through ``compile_plan``
        when a ``fault_plan`` is armed: raises for planned
        dispatch-surfaced failures of the current (tick, attempt)
        context. Delays do NOT sleep here — they ride ``ready_at_pc`` so
        a straggling device never blocks the dispatching host."""
        tick_idx, attempt = self._fault_ctx
        fault = self.fault_plan.get(tick_idx)
        if (fault is not None and fault.at_dispatch
                and attempt < fault.failures):
            raise DeviceFault(
                f"injected dispatch fault: tick {tick_idx} "
                f"attempt {attempt}")

    def _backoff_sleep(self, attempt: int) -> None:
        """Exponential backoff between retry attempts (base doubles per
        burned attempt; base 0.0 retries immediately)."""
        delay = self.retry_backoff_s * (2 ** attempt)
        if delay > 0:
            time.sleep(delay)

    def _shed_hopeless(self, now: float) -> None:
        """Drop queued requests whose SLO is already unmeetable even by
        an immediate smallest-bucket dispatch (the cheapest measured
        service estimate) — hopeless work must not occupy a bucket slot
        that a still-meetable request could use. Conservative by
        construction: with no measured estimate yet (0.0) nothing is
        ever shed."""
        floor = self.service_estimate(self.buckets[0])
        if floor <= 0.0:
            return
        keep: List[CNNRequest] = []
        for req in self.queue:
            assert req.t_submit is not None
            if (now - req.t_submit) + floor > self.slo_s:
                self.shed_total += 1
                self.shed_rids.add(req.rid)
                self._pending_rids.discard(req.rid)
                queue_s = max(0.0, now - req.t_submit)
                self.request_log.append(RequestTrace(
                    rid=req.rid, t_submit=req.t_submit, t_dispatch=now,
                    t_done=now, bucket=0, queue_s=queue_s, service_s=0.0,
                    latency_s=queue_s, slo_ok=False, outcome=OUTCOME_SHED))
            else:
                keep.append(req)
        if len(keep) != len(self.queue):
            self.queue = keep

    def _degrade_update(self) -> None:
        """Advance the degrade hysteresis one tick: enter on queue
        pressure or a sustained straggler-spike streak; exit only after
        ``exit_ticks`` consecutive calm ticks at or below the exit
        watermark."""
        cfg = self._degrade_cfg
        q = len(self.queue)
        if not self._degrade_active:
            if (q >= self._enter_q
                    or self._spike_streak >= cfg.straggler_patience):
                self._degrade_active = True
                self._degrade_entries += 1
                self._degrade_calm = 0
        else:
            if q <= self._exit_q and self._spike_streak == 0:
                self._degrade_calm += 1
                if self._degrade_calm >= cfg.exit_ticks:
                    self._degrade_active = False
                    self._degrade_exits += 1
                    self._degrade_calm = 0
            else:
                self._degrade_calm = 0

    # --------------------------------------------------- staging buffers
    def _stage(self, batch: List[CNNRequest]) -> np.ndarray:
        """Pack ``batch`` into the next rotating staging buffer, zeroing
        only slots still holding images a *previous* tick staged there — a
        smaller bucket after a larger one must not leak stale images into
        its padded tail. Rotation guarantees the buffer's previous tick
        has already retired (pipeline depth == buffer count)."""
        idx = self._buf_cursor
        self._buf_cursor = (idx + 1) % len(self._batch_bufs)
        self._last_buf_index = idx
        x = self._batch_bufs[idx]
        for i, req in enumerate(batch):
            x[i] = req.image
        if self._filled[idx] > len(batch):
            x[len(batch):self._filled[idx]] = 0
        self._filled[idx] = len(batch)
        return x

    # ------------------------------------------------------- completion
    def _reap(self) -> None:
        """Retire in-flight ticks whose results are already ready, without
        blocking (completion is FIFO — the device runs ticks in dispatch
        order, so a ready head implies nothing about later ticks)."""
        while self._inflight:
            head = self._inflight[0]
            if time.perf_counter() < head.ready_at_pc:
                break
            is_ready = getattr(head.out, "is_ready", None)
            if is_ready is None or not is_ready():
                break
            self._complete(self._inflight.popleft())

    def _complete(self, tick: InflightTick) -> None:
        """Blocking completion of one tick: wait for the device, unpack
        results into ``done``, update the bucket's service EMA from the
        *device-completion* time, and write ``RequestTrace`` records.
        Planned completion-surfaced faults are discovered here — the
        async result turns out bad when blocked on — and replayed from
        the tick's pinned staging buffer under the bounded retry budget;
        exhaustion fails the tick cleanly (slot and buffer reclaimed,
        EMAs untouched, later in-flight ticks unaffected)."""
        t_block = time.perf_counter()
        out = jax.block_until_ready(tick.out)
        remaining = tick.ready_at_pc - time.perf_counter()
        if remaining > 0:
            time.sleep(remaining)           # emulated device still busy
        fault = tick.fault
        if fault is not None and not fault.at_dispatch:
            while tick.attempt < fault.failures:
                if tick.attempt >= self.max_retries:
                    self._fail_tick(tick)
                    return
                self.retries_total += 1
                self._backoff_sleep(tick.attempt)
                tick.attempt += 1
                # Replay from the pinned staging buffer — rotation
                # guarantees it still holds exactly this tick's images —
                # on the tick's pinned executable: a hot-swap between
                # dispatch and this replay must not change the math.
                x = self._batch_bufs[tick.buf_index]
                run = tick.run if tick.run is not None \
                    else self._runs[tick.bucket]
                try:
                    self._fault_ctx = (tick.tick_idx, tick.attempt)
                    tick.out = run(self.params, x[:tick.bucket])
                finally:
                    self._fault_ctx = (None, 0)
                out = jax.block_until_ready(tick.out)
        t_ready = time.perf_counter()
        # Serial-device occupancy: this tick could only start once the
        # previous one finished, so its service time is completion minus
        # max(launch, previous completion) — under pipelining the naive
        # (completion - launch) would fold queueing behind older ticks
        # into the EMA and wreck the scheduler's deadline budgets.
        start = max(tick.t_launch_pc, self._last_ready_pc)
        service = max(t_ready - start, 1e-9)
        self._last_ready_pc = t_ready
        # Overlap = the part of this tick's device time that elapsed
        # between its dispatch call *returning* and the host blocking on
        # the result — i.e. device time during which the host was free to
        # pack/dispatch other ticks. Synchronous ticks block immediately
        # after dispatch, so their overlap is ~0; the dispatch call
        # itself (tracing, transfer) is host work and never counts.
        free_from = max(tick.t_launched_pc, start)
        self._overlap_s += min(max(t_block - free_from, 0.0), service)
        self._device_busy_s += service
        self._completed_ticks += 1
        arr = np.asarray(out)
        for i, req in enumerate(tick.reqs):
            self.done[req.rid] = arr[i]
            self._inflight_rids.discard(req.rid)
        prev = self._svc[tick.bucket]
        self._svc[tick.bucket] = (service if prev is None
                                  else 0.5 * prev + 0.5 * service)
        self.served_total += len(tick.reqs)
        if self._degrade_cfg is not None:
            self._observe_service(service)
        # Engine-clock completion: pipelined ticks finish no earlier than
        # the previous tick's completion (the serial device again), which
        # keeps t_done monotone across out-of-order drains. The
        # synchronous engine keeps the PR-4 stamp (dispatch + wall).
        if self.pipeline_depth > 1:
            t_done = max(tick.t_dispatch, self._last_done) + service
        else:
            t_done = tick.t_dispatch + service
        self._last_done = t_done
        for req in tick.reqs:
            assert req.t_submit is not None
            queue_s = max(0.0, tick.t_dispatch - req.t_submit)
            latency_s = queue_s + (t_done - tick.t_dispatch)
            slo_ok = self.slo_s is None or latency_s <= self.slo_s
            if not slo_ok:
                self.slo_violations += 1
            self.request_log.append(RequestTrace(
                rid=req.rid, t_submit=req.t_submit,
                t_dispatch=tick.t_dispatch, t_done=t_done,
                bucket=tick.bucket, queue_s=queue_s, service_s=service,
                latency_s=latency_s, slo_ok=slo_ok))
        self.last_tick = {"bucket": tick.bucket, "served": len(tick.reqs),
                          "wall_s": service, "now": tick.t_dispatch,
                          "per_chip_batch": tick.bucket // self.data_shards}

    def _observe_service(self, service: float) -> None:
        """Feed one completed tick's service time to the degrade
        controller's spike detector: robust z-score against the trailing
        history (``distributed.fault.robust_zscore`` — median/MAD, the
        ``StragglerMonitor`` statistic), streak-counted so only
        *consecutive* spikes trip the degrade entry."""
        cfg = self._degrade_cfg
        if len(self._svc_hist) >= 5:
            if robust_zscore(service, self._svc_hist) > cfg.straggler_k:
                self._spikes_total += 1
                self._spike_streak += 1
            else:
                self._spike_streak = 0
        self._svc_hist.append(service)

    def _fail_tick(self, tick: InflightTick) -> None:
        """Terminal failure of one tick after its retry budget is spent:
        every request gets outcome ``failed`` (traced, counted,
        conserved), the pipeline slot and staging buffer return to the
        pool, and — deliberately — the bucket's service EMA and the
        degrade spike history are NOT updated: a failed tick produced no
        service-time measurement, and polluting the scheduler's deadline
        budgets with fault wall time would punish the requests that
        follow."""
        self.failed_ticks += 1
        wall = max(time.perf_counter() - tick.t_launch_pc, 1e-9)
        if tick.out is not None:
            # The device was genuinely occupied by the doomed attempts:
            # later ticks' serial-device service accounting must not
            # back-date their start to before this tick ended.
            self._last_ready_pc = max(self._last_ready_pc,
                                      time.perf_counter())
        t_done = tick.t_dispatch
        for req in tick.reqs:
            self._inflight_rids.discard(req.rid)
            self.failed[req.rid] = tick.tick_idx
            assert req.t_submit is not None
            queue_s = max(0.0, tick.t_dispatch - req.t_submit)
            self.request_log.append(RequestTrace(
                rid=req.rid, t_submit=req.t_submit,
                t_dispatch=tick.t_dispatch, t_done=t_done,
                bucket=tick.bucket, queue_s=queue_s, service_s=0.0,
                latency_s=queue_s, slo_ok=False, outcome=OUTCOME_FAILED))
        self.failed_total += len(tick.reqs)
        self.last_tick = {"bucket": tick.bucket, "served": 0,
                          "wall_s": wall, "now": tick.t_dispatch,
                          "per_chip_batch": tick.bucket // self.data_shards,
                          "failed": True}

    def drain(self) -> Dict[int, np.ndarray]:
        """Retire every in-flight tick (blocking, in dispatch order) so
        ``done`` holds all dispatched results. No-op when synchronous or
        idle; never dispatches — pair with ``step(flush=True)`` /
        ``run_until_done()`` to also empty the queue."""
        while self._inflight:
            self._complete(self._inflight.popleft())
        return self.done

    def poll(self, rid: int) -> Optional[np.ndarray]:
        """Requester-side completion: the result for ``rid`` if its tick
        has retired, retiring in-flight ticks (oldest first) until that
        tick retires. ``None`` — with NO side effects — when ``rid`` is
        not in flight: never submitted, still queued, rejected, shed, or
        failed. (An unknown rid must not drain the pipeline as a side
        effect; only a rid genuinely riding an in-flight tick forces
        retirement, and only up to its own tick.)"""
        if rid in self.done:
            return self.done[rid]
        while rid in self._inflight_rids and self._inflight:
            self._complete(self._inflight.popleft())
        return self.done.get(rid)

    def reset(self) -> None:
        """Drop queued/served request state and observability counters
        (trace replays reuse one warmed engine across traces). In-flight
        ticks are retired first (their measurements still update the
        EMAs). Compiled executables, the staging buffers and the measured
        service-time estimates are kept — resetting never forgets what
        the device taught us."""
        self.drain()
        self.queue.clear()
        self.done.clear()
        self.dispatches = {b: 0 for b in self.buckets}
        self.last_tick = None
        self.request_log.clear()
        self.submitted_total = 0
        self.served_total = 0
        self.slo_violations = 0
        self._last_done = float("-inf")
        self._overlap_s = 0.0
        self._device_busy_s = 0.0
        self._dispatched_ticks = 0
        self._completed_ticks = 0
        # Robustness accounting resets with the request state; measured
        # knowledge (service EMAs, degrade spike history) is kept, and
        # the degrade mode itself stands down — a fresh trace starts
        # from the normal scheduling policy.
        self.rejected_total = 0
        self.shed_total = 0
        self.failed_total = 0
        self.retries_total = 0
        self.failed_ticks = 0
        self.queue_high_water = 0
        self.failed.clear()
        self.shed_rids.clear()
        self._pending_rids.clear()
        self._inflight_rids.clear()
        self._degrade_active = False
        self._degrade_entries = 0
        self._degrade_exits = 0
        self._degrade_calm = 0
        self._spikes_total = 0
        self._spike_streak = 0
        # Fault plans are keyed by dispatch index: replays that reset the
        # engine between traces expect the plan to re-apply from tick 0.
        self._tick_seq = 0

    # ------------------------------------------------------ observability
    def stats(self) -> Dict[str, object]:
        """Snapshot of the engine's request accounting: totals, per-bucket
        dispatch counts and service EMAs, SLO-violation count, latency /
        queue-wait aggregates over the bounded ``request_log`` window
        (submit→dispatch→done timestamps live in the individual
        ``RequestTrace`` records), and the pipeline's in-flight/overlap
        counters. Pure read — never mutates state (in particular it never
        retires in-flight ticks; ``served`` counts *completed* requests,
        dispatched-but-inflight ones appear under ``pipeline``)."""
        def _agg(vals: List[float]) -> Optional[Dict[str, float]]:
            if not vals:
                return None
            arr = np.asarray(vals)
            return {"mean_ms": float(arr.mean()) * 1e3,
                    "p50_ms": float(np.percentile(arr, 50)) * 1e3,
                    "p99_ms": float(np.percentile(arr, 99)) * 1e3,
                    "max_ms": float(arr.max()) * 1e3}

        # Latency/queue aggregates describe COMPLETED requests only —
        # rejected/shed/failed records carry no service leg and would
        # drag the percentiles toward their (zero-cost) decision times.
        window = [t for t in self.request_log
                  if t.outcome == OUTCOME_COMPLETED]
        return {
            "submitted": self.submitted_total,
            "served": self.served_total,
            "queued": len(self.queue),
            "slo_s": self.slo_s,
            "slo_violations": self.slo_violations,
            "dispatches": dict(self.dispatches),
            # Service EMAs are device-completion times under the serial-
            # device model (completion minus max(launch, previous
            # completion)) — NOT host-blocking wall time, so SLO deadline
            # budgets stay correct when ticks retire lazily under
            # pipelining.
            "service_ema_s": {b: s for b, s in self._svc.items()
                              if s is not None},
            "window": len(window),
            "latency": _agg([t.latency_s for t in window]),
            "queue_wait": _agg([t.queue_s for t in window]),
            "pipeline": {
                "depth": self.pipeline_depth,
                "inflight": len(self._inflight),
                "dispatched_ticks": self._dispatched_ticks,
                "completed_ticks": self._completed_ticks,
                "device_busy_s": self._device_busy_s,
                "overlap_s": self._overlap_s,
                # Fraction of device-busy time that elapsed while the host
                # was free to pack/dispatch other ticks: ~0 synchronous,
                # → 1 when packing fully hides behind device compute.
                "overlap_ratio": (self._overlap_s / self._device_busy_s
                                  if self._device_busy_s > 0 else 0.0),
            },
            # Sharded dispatch accounting: how each bucket splits across
            # the mesh (None = single-device engine). Service EMAs above
            # are wall times of the *sharded* dispatch — the scheduler's
            # deadline budgets automatically reflect multi-chip speed.
            "sharding": None if self.mesh is None else {
                "data_shards": self.data_shards,
                "mesh_devices": int(self.mesh.size),
                "per_chip_batch": {b: b // self.data_shards
                                   for b in self.buckets},
            },
            # Deployment history of the served plan: how many times the
            # ladder was hot-swapped (supervisor adoptions) and rolled
            # back. Counters survive reset() — deployment events are
            # engine-lifetime history, not per-trace request accounting.
            "plan": {
                "swaps": self.plan_swaps,
                "rollbacks": self.plan_rollbacks,
            },
            # Per-layer precision mix of the served plan: conv layer
            # counts per precision plus the int8 layer ids — the
            # operator-facing audit of what the quantization gate kept.
            "precision": {
                "mix": {
                    "int8": sum(1 for p in self.precisions.values()
                                if p == "int8"),
                    "bf16": (sum(1 for p in self.precisions.values()
                                 if p != "int8")
                             + sum(1 for n in self.graph.conv_nodes()
                                   if n.id not in self.precisions)),
                },
                "int8_layers": sorted(
                    n for n, p in self.precisions.items() if p == "int8"),
                "calibrated": self.act_scales is not None,
            },
            # Overload/fault accounting. Every submitted request is
            # conserved across the four terminal outcomes plus the
            # not-yet-terminal pending set (queued + riding an in-flight
            # tick): outcomes sum + pending == submitted, always.
            "robustness": {
                "max_queue": self.max_queue,
                "shed_deadline": self.shed_deadline,
                "outcomes": {
                    OUTCOME_COMPLETED: self.served_total,
                    OUTCOME_REJECTED: self.rejected_total,
                    OUTCOME_SHED: self.shed_total,
                    OUTCOME_FAILED: self.failed_total,
                },
                "pending": (len(self.queue)
                            + sum(len(t.reqs) for t in self._inflight)),
                "retries": self.retries_total,
                "failed_ticks": self.failed_ticks,
                "queue_high_water": self.queue_high_water,
                "degrade": {
                    "enabled": self._degrade_cfg is not None,
                    "active": self._degrade_active,
                    "entries": self._degrade_entries,
                    "exits": self._degrade_exits,
                    "straggler_spikes": self._spikes_total,
                },
            },
        }

    def run_until_done(self, max_ticks: int = 1000) -> Dict[int, np.ndarray]:
        """Drain the queue, ignoring SLO waits (shutdown/offline replay),
        then retire every in-flight tick."""
        for _ in range(max_ticks):
            if self.step(flush=True) == 0:
                break
        return self.drain()

    # ----------------------------------------------------- plan hot-swap
    def compile_ladder(self, plan: Optional[ExecutionPlan],
                       act_scales: Optional[Dict[int, float]] = None,
                       warm: bool = True) -> Dict[int, Callable]:
        """Compile one bucket ladder for ``plan`` under this engine's
        compile options (backend, epilogue, tuning record, mesh, donation,
        fault hook, shared cache) — the same call the constructor makes,
        so a ladder compiled here and swapped in is indistinguishable from
        constructing a fresh engine on ``plan``. Pure with respect to
        engine state: safe to call from a background thread (the shared
        ``ExecutableCache`` serializes concurrent compiles internally) and
        hand the result to ``swap_plan`` on the serving thread.

        ``warm=True`` invokes each executable once on an all-zeros batch
        (result discarded) so the JIT trace is paid here — on the compile
        thread — rather than by the first post-swap serving tick, whose
        wall time feeds the service EMAs and the supervisor's probation
        check."""
        hook = self._fault_hook if self.fault_plan is not None else None
        runs = {
            bucket: compile_plan(self.graph, plan,
                                 tuning_batch=bucket // self.data_shards,
                                 mesh=self.mesh,
                                 donate=self.pipeline_depth > 1,
                                 fault_hook=hook, cache=self.cache,
                                 act_scales=act_scales,
                                 **self._compile_kw)
            for bucket in self.buckets
        }
        if warm:
            for bucket, run in runs.items():
                x = np.zeros((bucket,) + self._shape, self.dtype)
                jax.block_until_ready(run(self.params, x))
        return runs

    def swap_plan(self, plan: Optional[ExecutionPlan],
                  runs: Optional[Dict[int, Callable]] = None, *,
                  act_scales: Optional[Dict[int, float]] = None,
                  rollback: bool = False) -> tuple:
        """Atomically deploy a new plan between ticks.

        Replaces the bucket ladder (``runs``, or compiled here via
        ``compile_ladder`` when None) plus the plan-derived state
        (``plan``/``precisions``/``act_scales``) in one step on the
        serving thread — the engine is single-threaded, so "atomic" means
        no tick can observe a half-swapped ladder: every dispatch before
        this call ran entirely on the old ladder, every one after runs
        entirely on the new.

        Everything else is deliberately preserved: the outcome ledger
        (conservation holds across the swap — a swap is not a request
        outcome), queued requests, in-flight ticks (each pinned its
        executable at dispatch and retires against the OLD ladder, fault
        replays included), and the per-bucket service EMAs (they are the
        scheduler's only deadline estimate; the 0.5/0.5 EMA re-converges
        on the new plan within a few ticks, and the supervisor snapshots
        pre-swap values for its regression check).

        Returns ``(old_plan, old_runs, old_act_scales)`` so the caller can
        re-arm the previous deployment (``rollback=True`` books the swap
        under the rollback counter instead)."""
        if runs is None:
            runs = self.compile_ladder(plan, act_scales=act_scales)
        missing = [b for b in self.buckets if b not in runs]
        if missing:
            raise ValueError(
                f"swap_plan ladder is missing buckets {missing} — a "
                "partial ladder would strand those buckets on the old "
                "plan; compile via compile_ladder(plan)")
        old = (self.plan, self._runs, self.act_scales)
        self.plan = plan
        self._runs = {b: runs[b] for b in self.buckets}
        self.act_scales = act_scales
        self.precisions = dict(getattr(plan, "precisions", None) or {}) \
            if plan is not None else {}
        if rollback:
            self.plan_rollbacks += 1
        else:
            self.plan_swaps += 1
        return old

    # ------------------------------------------------------------ warmup
    def _warmup(self) -> None:
        """Compile every bucket's executable and prime service estimates
        by timing two all-zeros dispatches per bucket — the first pays
        compilation, the second's wall time is the steady-state estimate
        (results discarded; the injected device delay is excluded so the
        estimate stays the raw device time)."""
        for bucket in self.buckets:
            x = np.zeros((bucket,) + self._shape, self.dtype)
            for _ in range(2):
                t0 = time.perf_counter()
                jax.block_until_ready(self._runs[bucket](self.params, x))
                wall = time.perf_counter() - t0
            self._svc[bucket] = wall
