"""Batched CNN serving engine over a compiled overlay program.

Mirrors ``serving.engine``'s queue/slot pattern for the CNN side: incoming
single-image requests queue up; each tick packs up to ``batch_size`` of them
into one fixed-shape batch and runs the ``compile_plan``-lowered program —
one XLA dispatch for the whole batch, no per-request Python graph walk.

The batch shape is fixed (short ticks are zero-padded) so exactly one
compiled executable serves all traffic; there is no recompilation between
a full batch and a trailing partial one.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.cnn.executor import compile_plan
from repro.core.algorithms import Algorithm, IM2COL
from repro.core.graph import Graph
from repro.core.mapper import ExecutionPlan


@dataclasses.dataclass
class CNNRequest:
    rid: int
    image: np.ndarray                  # (H, W, C)


class CNNServingEngine:
    """Batches single-image requests through one compiled plan."""

    def __init__(self, graph: Graph, params, plan: Optional[ExecutionPlan],
                 batch_size: int = 8,
                 default_algo: Algorithm = IM2COL,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 dtype=np.float32,
                 epilogue: str = "relu",
                 tuning=None) -> None:
        self.graph = graph
        self.params = params
        self.b = batch_size
        self.dtype = np.dtype(dtype)
        self.queue: List[CNNRequest] = []
        self.done: Dict[int, np.ndarray] = {}
        # The graph's input node pins the only image shape the compiled
        # program can accept — validate against it, never against traffic.
        src = graph.nodes[graph.source()]
        self._shape = tuple(int(d) for d in src.attrs["out_shape"])
        self._run = compile_plan(graph, plan, default_algo=default_algo,
                                 use_pallas=use_pallas, interpret=interpret,
                                 epilogue=epilogue, tuning=tuning)
        # The batch shape never changes, so allocate the staging buffer ONCE
        # and reuse it every tick; _filled tracks how many leading slots
        # hold stale images from the previous tick so only the padded tail
        # that would leak them needs re-zeroing.
        self._batch_buf = np.zeros((self.b,) + self._shape, self.dtype)
        self._filled = 0

    # ------------------------------------------------------------ intake
    def submit(self, req: CNNRequest) -> None:
        """Enqueue one request. Images are cast to the engine dtype and
        validated against the graph's (H, W, C) input shape here, so a bad
        request can never crash a tick or drag good requests down with
        it."""
        img = np.asarray(req.image, dtype=self.dtype)
        if img.shape != self._shape:
            raise ValueError(
                f"request {req.rid}: image shape {img.shape} != "
                f"graph input shape {self._shape}")
        req.image = img                # persist the validated array
        self.queue.append(req)

    # ------------------------------------------------------------- serve
    def step(self) -> int:
        """One engine tick: pack up to ``batch_size`` queued requests into
        the fixed-shape batch, run the compiled program once, scatter the
        outputs. Returns the number of requests served."""
        if not self.queue:
            return 0
        batch, self.queue = self.queue[:self.b], self.queue[self.b:]
        x = self._batch_buf
        for i, req in enumerate(batch):
            x[i] = req.image
        # Zero only the tail slots still holding last tick's images.
        if self._filled > len(batch):
            x[len(batch):self._filled] = 0
        self._filled = len(batch)
        out = jax.block_until_ready(self._run(self.params, x))
        out = np.asarray(out)
        for i, req in enumerate(batch):
            self.done[req.rid] = out[i]
        return len(batch)

    def run_until_done(self, max_ticks: int = 1000) -> Dict[int, np.ndarray]:
        for _ in range(max_ticks):
            if self.step() == 0:
                break
        return self.done
