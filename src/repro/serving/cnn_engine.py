"""Bucketed dynamic-batching CNN serving engine over compiled overlay
programs.

PR-2's engine ran ONE fixed batch shape: a lone request paid the full
batch-8 latency and bursts queued behind a single executable — the
utilization cliff DYNAMAP's dynamic-mapping overlay exists to avoid (§3).
This engine compiles one overlay program per *batch bucket* (powers of two
up to ``batch_size``) and schedules ticks against a per-request latency
SLO:

* each bucket's executable is lowered under the ``(signature, bucket)``
  tuning winner (``compile_plan(..., tuning_batch=bucket)``) — the binding
  measured *at that batch size*, not the batch-1 winner;
* ``step()`` picks the smallest bucket covering the queue. While the
  oldest request still has deadline budget (``slo_s`` minus the bucket's
  estimated service time), the tick *waits* to fill a larger bucket;
  once the budget is nearly spent — or the largest bucket fills — it
  dispatches, zero-padding any empty tail slots;
* with ``slo_s=None`` every tick dispatches immediately through the
  smallest covering bucket (the latency-greedy policy; also the PR-2
  compatible default).

One staging buffer sized for the largest bucket is allocated once; bucket
dispatches slice its leading rows, and only stale slots left by a previous
larger tick are re-zeroed (never the whole buffer).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.cnn.executor import compile_plan
from repro.core.algorithms import Algorithm, IM2COL
from repro.core.graph import Graph
from repro.core.mapper import ExecutionPlan


def batch_buckets(max_batch: int, shard: int = 1) -> List[int]:
    """Power-of-two bucket ladder up to ``max_batch`` (inclusive — a
    non-power-of-two cap becomes the top bucket). ``shard`` > 1 builds the
    mesh-sharded ladder: every bucket is a multiple of the data-shard
    count (``shard``, ``2*shard``, ``4*shard``, ...), so each bucket's
    padded batch splits evenly across the mesh's data axes — jit input
    shardings reject uneven partitions, and a bucket a mesh cannot place
    would be a compile-time landmine. The cap itself must divide."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    if shard < 1:
        raise ValueError(f"shard must be >= 1, got {shard}")
    if max_batch % shard:
        raise ValueError(
            f"max_batch {max_batch} is not a multiple of the data-shard "
            f"count {shard}; the top bucket could not be placed on the mesh")
    out = []
    b = shard
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return out


@dataclasses.dataclass
class CNNRequest:
    rid: int
    image: np.ndarray                  # (H, W, C)
    # Stamped at submit() (engine clock) unless the caller provides it —
    # trace replays inject virtual arrival times here.
    t_submit: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RequestTrace:
    """Per-request lifecycle accounting (engine-clock timestamps; the
    service leg is the tick's measured wall time, so with a virtual clock
    latency still combines simulated queueing with real service time —
    the same accounting the bench replay harness uses)."""
    rid: int
    t_submit: float
    t_dispatch: float
    t_done: float
    bucket: int
    queue_s: float
    service_s: float
    latency_s: float
    slo_ok: bool


class CNNServingEngine:
    """Batches single-image requests through per-bucket compiled plans.

    ``batch_size`` caps the largest bucket; ``buckets`` overrides the
    power-of-two ladder (must be ascending, e.g. ``(2, 8)`` to forbid
    singleton dispatches). ``slo_s`` is the per-request latency objective
    driving the tick scheduler; ``clock`` injects a time source (tests and
    trace replays pass a virtual clock). ``warmup=True`` runs one padded
    tick per bucket at construction, pre-compiling every executable and
    priming the per-bucket service-time estimates the scheduler uses.
    ``trace_window`` bounds the per-request ``RequestTrace`` log backing
    the ``stats()`` latency aggregates (totals and SLO-violation counters
    keep counting past the window).

    ``mesh`` (a ``jax.sharding.Mesh``, e.g. ``launch.mesh.make_data_mesh``)
    turns on data-parallel multi-chip serving: every bucket executable is
    compiled with its batch dimension sharded across the mesh's data axes
    and params replicated (placed once, at construction). The bucket
    ladder is then built in multiples of the data-shard count so every
    padded dispatch splits evenly across chips, and tuning-record lookups
    key off the *per-chip* batch (``bucket // data_shards``) — a winner
    measured at per-chip batch N on one chip is exactly the workload each
    chip runs in a sharded bucket of ``N * data_shards``, so existing
    single-device records transfer unchanged.
    """

    def __init__(self, graph: Graph, params, plan: Optional[ExecutionPlan],
                 batch_size: int = 8,
                 buckets: Optional[Sequence[int]] = None,
                 slo_s: Optional[float] = None,
                 default_algo: Algorithm = IM2COL,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 dtype=np.float32,
                 epilogue: str = "bias_relu",
                 tuning=None,
                 clock: Callable[[], float] = time.monotonic,
                 warmup: bool = False,
                 trace_window: int = 2048,
                 mesh=None) -> None:
        self.graph = graph
        self.mesh = mesh
        if mesh is not None:
            from repro.distributed.sharding import (data_shard_count,
                                                    replicated)
            self.data_shards = data_shard_count(mesh)
            # Replicate params across the mesh ONCE — jit would otherwise
            # re-transfer them to every chip on every tick.
            params = jax.device_put(params, replicated(mesh))
        else:
            self.data_shards = 1
        self.params = params
        self.buckets = (sorted(set(int(b) for b in buckets)) if buckets
                        else batch_buckets(batch_size, self.data_shards))
        if self.buckets[0] < 1:
            raise ValueError(f"buckets must be >= 1, got {self.buckets}")
        bad = [b for b in self.buckets if b % self.data_shards]
        if bad:
            raise ValueError(
                f"buckets {bad} are not multiples of the mesh's data-shard "
                f"count {self.data_shards} — their padded batches could "
                "not be placed")
        self.b = self.buckets[-1]              # largest bucket (PR-2 name)
        self.slo_s = slo_s
        self.dtype = np.dtype(dtype)
        self.queue: List[CNNRequest] = []
        self.done: Dict[int, np.ndarray] = {}
        self._clock = clock
        # The graph's input node pins the only image shape the compiled
        # programs can accept — validate against it, never against traffic.
        src = graph.nodes[graph.source()]
        self._shape = tuple(int(d) for d in src.attrs["out_shape"])
        # One executable per bucket: the bucket's tuning winner (measured
        # at that batch size) binds its lowering, so executables genuinely
        # differ — this is the multi-executable cache the fixed-batch
        # engine could not have. Under a mesh, each chip runs a per-chip
        # slice of the bucket, so the tuning lookup keys off that per-chip
        # batch — the workload a chip actually executes.
        self._runs = {
            bucket: compile_plan(graph, plan, default_algo=default_algo,
                                 use_pallas=use_pallas, interpret=interpret,
                                 epilogue=epilogue, tuning=tuning,
                                 tuning_batch=bucket // self.data_shards,
                                 mesh=mesh)
            for bucket in self.buckets
        }
        # One staging buffer sized for the largest bucket, allocated ONCE;
        # _filled tracks how many leading slots hold stale images from the
        # previous tick so only slots a dispatch would leak are re-zeroed.
        self._batch_buf = np.zeros((self.b,) + self._shape, self.dtype)
        self._filled = 0
        # Measured per-bucket service time (EMA) — the scheduler's estimate
        # of how much deadline budget a dispatch will consume.
        self._svc: Dict[int, Optional[float]] = {b: None for b in self.buckets}
        self.dispatches: Dict[int, int] = {b: 0 for b in self.buckets}
        self.last_tick: Optional[Dict[str, object]] = None
        # --- observability (ROADMAP item): per-request lifecycle records
        # in a bounded window plus running totals, surfaced by stats().
        self.request_log: Deque[RequestTrace] = \
            collections.deque(maxlen=trace_window)
        self.submitted_total = 0
        self.served_total = 0
        self.slo_violations = 0
        if warmup:
            self._warmup()

    # ------------------------------------------------------------ intake
    def submit(self, req: CNNRequest) -> None:
        """Enqueue one request. Images are cast to the engine dtype and
        validated against the graph's (H, W, C) input shape here, so a bad
        request can never crash a tick or drag good requests down with
        it."""
        img = np.asarray(req.image, dtype=self.dtype)
        if img.shape != self._shape:
            raise ValueError(
                f"request {req.rid}: image shape {img.shape} != "
                f"graph input shape {self._shape}")
        req.image = img                # persist the validated array
        if req.t_submit is None:
            req.t_submit = self._clock()
        self.submitted_total += 1
        self.queue.append(req)

    # --------------------------------------------------------- scheduling
    def covering_bucket(self, n: int) -> int:
        """Smallest bucket holding ``n`` requests (the largest bucket for
        any overflow — excess requests wait for the next tick)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.b

    def service_estimate(self, bucket: int) -> float:
        """Expected service time of one ``bucket`` dispatch. Unmeasured
        buckets borrow the largest measured smaller bucket's time (a lower
        bound — batched ticks only get slower), else 0: the scheduler then
        waits the full SLO before dispatching, which is the conservative
        larger-batch-favoring choice."""
        est = self._svc.get(bucket)
        if est is not None:
            return est
        known = [b for b in self._svc
                 if self._svc[b] is not None and b < bucket]
        return self._svc[max(known)] if known else 0.0

    def next_dispatch_at(self) -> Optional[float]:
        """Engine-clock time at which ``step()`` will dispatch without new
        arrivals — None when the queue is empty. Trace replays and serving
        loops use this as the next tick wake-up."""
        if not self.queue:
            return None
        oldest = self.queue[0]
        assert oldest.t_submit is not None
        if self.slo_s is None or len(self.queue) >= self.b:
            return oldest.t_submit          # dispatch immediately
        bucket = self.covering_bucket(len(self.queue))
        wait = max(0.0, self.slo_s - self.service_estimate(bucket))
        return oldest.t_submit + wait

    # ------------------------------------------------------------- serve
    def step(self, now: Optional[float] = None, flush: bool = False) -> int:
        """One engine tick. Picks the smallest bucket covering the queue;
        under an SLO it *waits* (returns 0) while the oldest request still
        has deadline budget to fill a larger bucket, and dispatches early
        once that budget is nearly spent — ``flush=True`` dispatches
        unconditionally (drain/shutdown). Returns the number served."""
        if not self.queue:
            return 0
        if now is None:
            now = self._clock()
        if not flush and len(self.queue) < self.b:
            at = self.next_dispatch_at()
            if at is not None and now < at:
                return 0                    # wait to fill a larger bucket
        bucket = self.covering_bucket(len(self.queue))
        batch, self.queue = self.queue[:bucket], self.queue[bucket:]
        x = self._batch_buf
        for i, req in enumerate(batch):
            x[i] = req.image
        # Zero only slots still holding images a *previous* tick staged —
        # a smaller bucket after a larger one must not leak stale images
        # into its padded tail.
        if self._filled > len(batch):
            x[len(batch):self._filled] = 0
        self._filled = len(batch)
        t0 = time.perf_counter()
        out = jax.block_until_ready(self._runs[bucket](self.params,
                                                       x[:bucket]))
        wall = time.perf_counter() - t0
        out = np.asarray(out)
        for i, req in enumerate(batch):
            self.done[req.rid] = out[i]
        prev = self._svc[bucket]
        self._svc[bucket] = wall if prev is None else 0.5 * prev + 0.5 * wall
        self.dispatches[bucket] += 1
        self.served_total += len(batch)
        for req in batch:
            assert req.t_submit is not None
            queue_s = max(0.0, now - req.t_submit)
            latency_s = queue_s + wall
            slo_ok = self.slo_s is None or latency_s <= self.slo_s
            if not slo_ok:
                self.slo_violations += 1
            self.request_log.append(RequestTrace(
                rid=req.rid, t_submit=req.t_submit, t_dispatch=now,
                t_done=now + wall, bucket=bucket, queue_s=queue_s,
                service_s=wall, latency_s=latency_s, slo_ok=slo_ok))
        self.last_tick = {"bucket": bucket, "served": len(batch),
                          "wall_s": wall, "now": now,
                          "per_chip_batch": bucket // self.data_shards}
        return len(batch)

    def reset(self) -> None:
        """Drop queued/served request state and observability counters
        (trace replays reuse one warmed engine across traces). Compiled
        executables, the staging buffer and the measured service-time
        estimates are kept — resetting never forgets what the device
        taught us."""
        self.queue.clear()
        self.done.clear()
        self.dispatches = {b: 0 for b in self.buckets}
        self.last_tick = None
        self.request_log.clear()
        self.submitted_total = 0
        self.served_total = 0
        self.slo_violations = 0

    # ------------------------------------------------------ observability
    def stats(self) -> Dict[str, object]:
        """Snapshot of the engine's request accounting: totals, per-bucket
        dispatch counts and service EMAs, SLO-violation count, and latency
        / queue-wait aggregates over the bounded ``request_log`` window
        (submit→dispatch→done timestamps live in the individual
        ``RequestTrace`` records). Pure read — never mutates state."""
        def _agg(vals: List[float]) -> Optional[Dict[str, float]]:
            if not vals:
                return None
            arr = np.asarray(vals)
            return {"mean_ms": float(arr.mean()) * 1e3,
                    "p50_ms": float(np.percentile(arr, 50)) * 1e3,
                    "p99_ms": float(np.percentile(arr, 99)) * 1e3,
                    "max_ms": float(arr.max()) * 1e3}

        window = list(self.request_log)
        return {
            "submitted": self.submitted_total,
            "served": self.served_total,
            "queued": len(self.queue),
            "slo_s": self.slo_s,
            "slo_violations": self.slo_violations,
            "dispatches": dict(self.dispatches),
            "service_ema_s": {b: s for b, s in self._svc.items()
                              if s is not None},
            "window": len(window),
            "latency": _agg([t.latency_s for t in window]),
            "queue_wait": _agg([t.queue_s for t in window]),
            # Sharded dispatch accounting: how each bucket splits across
            # the mesh (None = single-device engine). Service EMAs above
            # are wall times of the *sharded* dispatch — the scheduler's
            # deadline budgets automatically reflect multi-chip speed.
            "sharding": None if self.mesh is None else {
                "data_shards": self.data_shards,
                "mesh_devices": int(self.mesh.size),
                "per_chip_batch": {b: b // self.data_shards
                                   for b in self.buckets},
            },
        }

    def run_until_done(self, max_ticks: int = 1000) -> Dict[int, np.ndarray]:
        """Drain the queue, ignoring SLO waits (shutdown/offline replay)."""
        for _ in range(max_ticks):
            if self.step(flush=True) == 0:
                break
        return self.done

    # ------------------------------------------------------------ warmup
    def _warmup(self) -> None:
        """Compile every bucket's executable and prime service estimates by
        timing one all-zeros tick per bucket (results discarded)."""
        for bucket in self.buckets:
            x = np.zeros((bucket,) + self._shape, self.dtype)
            t0 = time.perf_counter()
            jax.block_until_ready(self._runs[bucket](self.params, x))
            t0 = time.perf_counter()        # second run: steady-state time
            jax.block_until_ready(self._runs[bucket](self.params, x))
            self._svc[bucket] = time.perf_counter() - t0
