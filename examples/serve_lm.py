"""Batched serving example (deliverable b): continuous batching through the
serving engine with greedy decoding.

    PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

if __name__ == "__main__":
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", "qwen2.5-14b", "--reduced",
           "--requests", "6", "--batch", "4", "--prompt-len", "12",
           "--max-new", "8"]
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root"}
    raise SystemExit(subprocess.call(cmd, env=env, cwd=str(REPO)))
