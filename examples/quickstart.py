"""Quickstart: the full DYNAMAP flow on GoogleNet in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. Build the CNN graph (GoogleNet — the paper's first evaluation network).
2. Run Algorithm 1 (hardware DSE → virtual-array shape + per-(layer, algo)
   dataflow).
3. Build the cost graph and solve the PBQP optimally via series-parallel
   reduction (Theorem 4.1).
4. Compare against the paper's fixed-algorithm baselines (Table 4).
5. Execute the network under the chosen plan and check it matches the
   im2col-only reference bit-for-bit semantics.
6. Lower the plan with ``compile_plan`` into ONE jit-compiled, batched
   overlay program (no Python dispatch on the hot path) and serve a batch.
"""
import sys
import time
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.cnn.executor import compile_plan, forward, init_params
from repro.cnn.models import googlenet
from repro.core import IM2COL
from repro.core.cost_model import FPGA_LIKE
from repro.core.dse import identify_parameters
from repro.core.graph import is_series_parallel
from repro.core.mapper import evaluate_fixed_mapping, map_network


def main() -> None:
    # Reduced spatial size so the executor runs in seconds on CPU; the cost
    # model itself prices the full-size network just as fast.
    g = googlenet(res=56, scale=0.25)
    print(f"GoogleNet graph: {len(g.nodes)} nodes, "
          f"{len(g.conv_nodes())} conv layers, "
          f"series-parallel={is_series_parallel(g)}")

    hw = identify_parameters(g, spec=FPGA_LIKE, max_dim=512, k_panel=256)
    print(f"Algorithm 1 → virtual array ({hw.p1}×{hw.p2}), "
          f"τ_emp={hw.tau_emp * 1e3:.3f} ms")

    plan = map_network(g, hw=hw, spec=FPGA_LIKE)
    print(f"PBQP optimal mapping (exact={plan.solver.exact}): "
          f"{dict(Counter(str(a) for a in plan.assignment.values()))}")
    print(f"end-to-end latency (cost model): {plan.total_cost_s * 1e3:.3f} ms")
    for pol in ("im2col", "kn2row", "winograd"):
        bl = evaluate_fixed_mapping(g, pol, hw=hw, spec=FPGA_LIKE)
        print(f"  vs {pol:8s}-only: {bl * 1e3:8.3f} ms "
              f"(OPT {100 * (1 - plan.total_cost_s / bl):5.1f}% lower)")

    params = init_params(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (56, 56, 3))
    ref = forward(g, params, x, default_algo=IM2COL)
    opt = forward(g, params, x, plan=plan)
    err = float(np.max(np.abs(np.asarray(opt) - np.asarray(ref))))
    print(f"plan-executed output vs im2col reference: max|Δ| = {err:.2e}")

    # 6. Plan compilation: every per-layer algorithm + dataflow/(p1, p2)
    # choice is closed over at trace time; the result is one XLA program
    # that accepts (H, W, C) or batched (B, H, W, C) inputs. GoogleNet
    # lowers CONV+bias+ReLU fused ("bias_relu" — init_params created the
    # per-conv biases).
    run = compile_plan(g, plan, epilogue="bias_relu")
    xb = jax.random.normal(jax.random.PRNGKey(2), (8, 56, 56, 3))
    yb = jax.block_until_ready(run(params, xb))       # compile + run
    t0 = time.time()
    jax.block_until_ready(run(params, xb))
    t_comp = time.time() - t0
    t0 = time.time()
    for i in range(xb.shape[0]):
        jax.block_until_ready(forward(g, params, xb[i], plan=plan))
    t_eager = time.time() - t0
    err_b = float(np.max(np.abs(np.asarray(yb[0]) - np.asarray(
        forward(g, params, xb[0], plan=plan)))))
    print(f"compiled batched plan: {yb.shape} in {t_comp * 1e3:.1f} ms vs "
          f"eager per-image loop {t_eager * 1e3:.1f} ms "
          f"({t_eager / t_comp:.1f}x); max|Δ| vs eager = {err_b:.2e}")


if __name__ == "__main__":
    main()
