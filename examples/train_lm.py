"""End-to-end LM training driver (deliverable b): trains a reduced config
for a few hundred steps on CPU with checkpointing + restart through the
fault-tolerant supervisor.

    PYTHONPATH=src python examples/train_lm.py --steps 200

This drives exactly the production train_step (microbatched gradient
accumulation, sharded params, deterministic data) — on a cluster the same
driver runs with --mesh pod/multipod.
"""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

if __name__ == "__main__":
    steps = "200"
    for i, a in enumerate(sys.argv):
        if a == "--steps":
            steps = sys.argv[i + 1]
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "h2o-danube-1.8b", "--reduced",
           "--steps", steps, "--batch", "8", "--seq", "128",
           "--microbatches", "2", "--ckpt-every", "50",
           "--ckpt-dir", str(REPO / "checkpoints"), "--log-every", "10"]
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root"}
    raise SystemExit(subprocess.call(cmd, env=env, cwd=str(REPO)))
