"""CNN serving example: GoogleNet through the bucketed-SLO engine.

The CNN-side counterpart of ``serve_lm.py``: build a reduced GoogleNet,
map it (PBQP), autotune-or-load a bucket-keyed tuning record, then push a
short burst+trickle trace through ``CNNServingEngine`` and print its
``stats()`` snapshot.

    PYTHONPATH=src python examples/serve_cnn.py                 # 1 device
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/serve_cnn.py --devices 4 # sharded

With more than one visible device (or ``--devices N``), the engine runs
mesh-sharded: per-bucket executables shard the batch dim across the
mesh's data axis, the bucket ladder is built in multiples of the shard
count, and tuning lookups key off the per-chip batch — the same record
works at any device count.

``--pipeline-depth 2`` turns on async tick dispatch: ``step()`` launches
and returns without blocking (double-buffered staging, donated device
inputs), results retire lazily, and the completion loop must ``drain()``
once everything is dispatched — results may still be in flight when the
queue empties.

``--max-queue N`` bounds admission (overflow requests are rejected with
a first-class ``rejected_full`` outcome instead of growing the queue),
and ``--chaos`` arms the full robustness stack: a seeded ``FaultPlan``
(transient injected device faults absorbed by the bounded retry loop),
deadline shedding, and the degrade-mode hysteresis controller. Either
way the serving loop below terminates on *outcome conservation* — every
submitted request accounted completed/rejected/shed/failed — not on
every request completing, and the ``stats()["robustness"]`` block in
the report shows the ledger.

``--precision auto`` serves the gated mixed-precision plan: the
precision-aware PBQP maps each layer int8-or-bf16 jointly with its
algorithm, a calibration batch fixes per-tensor activation scales, and
the accuracy gate demotes layers whose isolated int8 error exceeds the
tolerance back to bf16 before compiling. ``--precision int8`` keeps the
cost model's picks with the gate disarmed; the default ``bf16`` is the
classic plan. The spot check compares against the eager walk of the
*same* plan, so it stays tight at any precision.

``--models N`` (N >= 2) switches to multi-tenant serving: N copies of
the architecture with independent params register in one
``MultiModelEngine`` — tenant 2..N recompile nothing (shared executable
cache) — and the same burst+trickle trace replays per tenant through
the joint deadline-ordered scheduler. Per-tenant conservation and a
per-tenant reference spot-check gate the run. ``--chaos`` and
``--pipeline-depth`` are single-model-only knobs.

CI's serving-smoke job runs the ``--smoke`` configuration end to end
(plus ``--smoke --chaos --max-queue`` and ``--smoke --models 2``
variants).
"""
import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np


def build_record(g, plan, path, buckets):
    """Autotune-or-load: records are keyed by (conv signature, bucket), so
    a record saved at one graph size transfers to any graph sharing layer
    shapes — and re-tuning is incremental if you pass it back in."""
    from repro.core.autotune import TuningRecord, autotune_buckets

    if path and Path(path).exists():
        record = TuningRecord.load(path)
        print(f"loaded tuning record: {path} ({len(record.entries)} entries)")
        return record
    t0 = time.time()
    record = autotune_buckets(g, plan, buckets=buckets,
                              backends=("lax", "reference"), reps=1)
    print(f"autotuned {len(record.entries)} (signature, bucket) pairs "
          f"in {time.time() - t0:.0f}s")
    if path:
        record.save(path)
        print(f"saved tuning record: {path}")
    return record


def serve_multi(args, g, plan, record, mesh) -> None:
    """N tenants, one engine: replay the burst+trickle trace per tenant
    through the joint scheduler, then gate per-tenant conservation and
    a per-tenant eager-reference spot check."""
    from repro.cnn.executor import forward, init_params
    from repro.serving.cnn_engine import CNNRequest
    from repro.serving.multi_engine import MultiModelEngine

    names = [f"model_{chr(ord('a') + i)}" for i in range(args.models)]
    multi = MultiModelEngine()
    tenant_params = {}
    for i, name in enumerate(names):
        tenant_params[name] = init_params(g, jax.random.PRNGKey(i))
        kw = {"max_queue": args.max_queue} if args.max_queue else {}
        multi.register_model(name, g, tenant_params[name], plan,
                             slo_s=args.slo_ms / 1e3, tuning=record,
                             batch_size=args.batch, mesh=mesh,
                             warmup=True, **kw)
    cs = multi.cache.stats()
    print(f"registered {len(names)} tenants, shared cache: "
          f"{cs['entries']} executables, {cs['hits']} hits "
          f"({cs['hits']} compiles avoided)")

    shape = tuple(g.nodes[g.source()].attrs["out_shape"])
    rng = np.random.default_rng(0)
    per = max(4, args.requests // args.models)
    imgs = {name: rng.standard_normal((per,) + shape).astype(np.float32)
            for name in names}
    n_burst = max(1, (2 * per) // 3)
    for name in names:
        for i in range(n_burst):
            multi.submit(name, CNNRequest(rid=i, image=imgs[name][i]))
    rid = n_burst

    def accounted() -> int:
        return sum(len(e.done) + len(e.failed) + len(e.shed_rids)
                   + e.rejected_total for e in multi.engines.values())

    while accounted() < per * len(names):
        if multi.step() == 0:
            if rid < per:                          # trickle one per tenant
                for name in names:
                    multi.submit(name, CNNRequest(rid=rid,
                                                  image=imgs[name][rid]))
                rid += 1
            elif multi.queued_total():             # waiting on SLO budget
                at = multi.next_dispatch_at()
                time.sleep(max(0.0, min(0.05, (at or 0) - time.monotonic())))
                multi.step(flush=True)
            else:
                multi.drain()

    # Shared executables must serve each tenant under its OWN weights.
    for name in names:
        want = np.asarray(forward(g, tenant_params[name], imgs[name][0],
                                  plan=plan, epilogue="bias_relu"))
        got = multi.engines[name].done[0]
        err = float(np.max(np.abs(got - want)))
        print(f"{name} request 0 vs eager reference: max|delta| = {err:.2e}")
        if not np.allclose(got, want, rtol=2e-2, atol=2e-3):
            raise SystemExit(f"{name}: engine output diverged from reference")
        rb = multi.engines[name].stats()["robustness"]
        if (sum(rb["outcomes"].values()) + rb["pending"]
                != multi.engines[name].submitted_total):
            raise SystemExit(f"{name}: request accounting failed to conserve")
    print(json.dumps(multi.stats(), indent=2, default=str))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--res", type=int, default=56)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--slo-ms", type=float, default=250.0)
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh size (default: all visible devices)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="async tick pipeline depth (1 = synchronous)")
    ap.add_argument("--record", type=str, default=None,
                    help="tuning-record JSON: loaded if it exists, else "
                         "autotuned and saved there")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission: reject submits once this "
                         "many requests are queued")
    ap.add_argument("--chaos", action="store_true",
                    help="arm the robustness stack: seeded fault "
                         "injection + bounded retries, deadline "
                         "shedding, degrade mode")
    ap.add_argument("--precision", choices=("auto", "int8", "bf16"),
                    default="bf16",
                    help="auto: precision-aware PBQP + accuracy gate "
                         "(plan_mixed_precision); int8: precision-aware "
                         "PBQP with the gate disarmed; bf16: the classic "
                         "all-bf16 plan (default)")
    ap.add_argument("--models", type=int, default=1,
                    help="N >= 2 serves N tenants of the architecture "
                         "(independent params) through one "
                         "MultiModelEngine with a shared executable "
                         "cache and joint deadline-ordered ticks")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (res 28, scale 0.1, no tuning)")
    args = ap.parse_args()
    if args.smoke:
        args.res, args.scale, args.requests = 28, 0.1, 12
    if args.models > 1 and (args.chaos or args.pipeline_depth != 1
                            or args.precision != "bf16"):
        raise SystemExit("--models is incompatible with --chaos / "
                         "--pipeline-depth / --precision "
                         "(single-model knobs)")

    from repro.cnn.executor import forward, init_params
    from repro.cnn.models import googlenet
    from repro.core.dse import identify_parameters
    from repro.core.mapper import map_network
    from repro.launch.mesh import make_data_mesh
    from repro.serving.cnn_engine import CNNRequest, CNNServingEngine

    n_dev = args.devices or jax.device_count()
    g = googlenet(res=args.res, scale=args.scale)
    print(f"googlenet res={args.res} scale={args.scale}: "
          f"{len(g.conv_nodes())} conv layers, serving on {n_dev} device(s)")
    hw = identify_parameters(g, max_dim=512)
    params = init_params(g, jax.random.PRNGKey(0))
    act_scales = None
    if args.precision == "bf16":
        plan = map_network(g, hw=hw)
    else:
        # Quantized serving: solve the precision-aware PBQP on a small
        # calibration batch. "auto" arms the accuracy gate (layers whose
        # isolated int8 error exceeds tol demote to bf16); "int8" keeps
        # whatever the cost model picked.
        from repro.core.quant import calibrate_act_scales, \
            plan_mixed_precision
        shape0 = tuple(g.nodes[g.source()].attrs["out_shape"])
        calib = jax.random.normal(jax.random.PRNGKey(7), (2,) + shape0)
        if args.precision == "auto":
            rep = plan_mixed_precision(g, params, calib, tol=0.012, hw=hw)
            plan, act_scales = rep.plan, rep.act_scales
            print(f"precision gate: {rep.precision_mix}, "
                  f"demoted {rep.demoted} (tol {rep.tol})")
        else:
            plan = map_network(g, hw=hw, quantize=True)
            act_scales = calibrate_act_scales(g, params, calib)
            n8 = sum(1 for p in plan.precisions.values() if p == "int8")
            print(f"precision forced int8: {n8}/{len(plan.precisions)} "
                  f"layers int8 (gate disarmed)")
    record = None if args.smoke else \
        build_record(g, plan, args.record, buckets=(1, 2))

    mesh = make_data_mesh(n_dev) if n_dev > 1 else None
    if args.models > 1:
        return serve_multi(args, g, plan, record, mesh)
    robustness = {}
    if args.max_queue is not None:
        robustness["max_queue"] = args.max_queue
    if args.chaos:
        from repro.distributed.fault import FaultPlan
        from repro.serving.cnn_engine import DegradeConfig
        # Transient faults only (the bounded retry loop absorbs every
        # one, so the reference spot-check below still has results);
        # tick 0 is left clean so request 0 always completes.
        plan_f = FaultPlan.seeded(seed=1, n_ticks=2 * args.requests,
                                  fail_rate=0.2, failures=1)
        plan_f.faults.pop(0, None)
        robustness.update(shed_deadline=True, fault_plan=plan_f,
                          max_retries=2, degrade=DegradeConfig())
        print(f"chaos armed: {len(plan_f)} planned transient faults, "
              f"deadline shedding, degrade controller")
    eng = CNNServingEngine(g, params, plan, batch_size=args.batch,
                           slo_s=args.slo_ms / 1e3, tuning=record,
                           mesh=mesh, warmup=True,
                           pipeline_depth=args.pipeline_depth,
                           act_scales=act_scales, **robustness)
    print(f"bucket ladder: {eng.buckets}"
          + (f" (per-chip {[b // eng.data_shards for b in eng.buckets]})"
             if mesh is not None else ""))

    # A short mixed trace: one burst (fills big buckets) then a trickle
    # (SLO-forced small dispatches) — real clock, so the stats below are
    # real queueing + real service time.
    shape = tuple(g.nodes[g.source()].attrs["out_shape"])
    rng = np.random.default_rng(0)
    imgs = rng.standard_normal((args.requests,) + shape).astype(np.float32)
    n_burst = max(1, (2 * args.requests) // 3)
    for i in range(n_burst):
        eng.submit(CNNRequest(rid=i, image=imgs[i]))
    rid = n_burst

    def accounted() -> int:
        # Outcome conservation is the loop invariant: with the
        # robustness knobs armed some requests end rejected/shed/failed
        # instead of completed — all four are terminal.
        return (len(eng.done) + len(eng.failed) + len(eng.shed_rids)
                + eng.rejected_total)

    while accounted() < args.requests:
        if eng.step() == 0:
            if rid < args.requests:                # trickle one more in
                eng.submit(CNNRequest(rid=rid, image=imgs[rid]))
                rid += 1
            elif eng.queue:                        # waiting on SLO budget
                at = eng.next_dispatch_at()
                time.sleep(max(0.0, min(0.05, (at or 0) - eng._clock())))
                eng.step(flush=True)
            else:            # all dispatched — retire in-flight ticks
                eng.drain()

    # Spot-check one output against the eager reference (same plan, same
    # activation scales — a quantized engine is checked against the
    # quantized eager walk, so the tolerance stays tight), then report.
    want = np.asarray(forward(g, params, imgs[0], plan=plan,
                              epilogue="bias_relu", act_scales=act_scales))
    err = float(np.max(np.abs(eng.done[0] - want)))
    print(f"request 0 vs eager reference: max|delta| = {err:.2e}")
    print(json.dumps(eng.stats(), indent=2, default=str))
    if not np.allclose(eng.done[0], want, rtol=2e-2, atol=2e-3):
        raise SystemExit("engine output diverged from reference")
    rb = eng.stats()["robustness"]
    if sum(rb["outcomes"].values()) + rb["pending"] != eng.submitted_total:
        raise SystemExit("request accounting failed to conserve")


if __name__ == "__main__":
    main()
