"""A tour of the algorithm-mapping machinery on all five CNN families
(Lemmas 4.3/4.4): chain nets, residual nets, and both Inception networks —
each reduced to K2 by the series-parallel solver, mapped optimally, and
compared against the greedy baseline the paper argues against (§6.1.2).

    PYTHONPATH=src python examples/algorithm_mapping_tour.py
"""
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.cnn.models import MODELS
from repro.core.cost_model import FPGA_LIKE
from repro.core.dse import identify_parameters
from repro.core.graph import is_series_parallel
from repro.core.mapper import map_network


def main() -> None:
    for name, build in MODELS.items():
        res = 75 if name == "inception_v4" else 64
        g = build(res=res, scale=0.25)
        assert is_series_parallel(g)
        hw = identify_parameters(g, spec=FPGA_LIKE, max_dim=256,
                                 k_panel=256)
        opt = map_network(g, hw=hw, spec=FPGA_LIKE)
        greedy = map_network(g, hw=hw, spec=FPGA_LIKE,
                             solver="greedy_node")
        mix = dict(Counter(a.family.value for a in
                           opt.assignment.values()))
        gain = 100 * (1 - opt.total_cost_s / greedy.total_cost_s)
        print(f"{name:14s} convs={len(g.conv_nodes()):3d} "
              f"reductions={opt.solver.reductions:4d} exact={opt.solver.exact}  "
              f"OPT={opt.total_cost_s * 1e6:9.1f}µs  "
              f"greedy +{gain:4.1f}%  mix={mix}")


if __name__ == "__main__":
    main()
