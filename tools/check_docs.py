"""Docs CI checker: executable snippets + resolvable intra-repo links.

Two guarantees for every Markdown file under the repo root and ``docs/``
(plus ``benchmarks/README.md``):

* every fenced ```python block actually runs — blocks within one file
  share a namespace, in order, so later snippets may build on earlier
  imports exactly as a reader would run them top to bottom;
* every relative Markdown link target exists on disk (external
  http(s)/mailto links are skipped; ``#anchors`` are stripped).

Docs that drift from the code fail CI instead of lying quietly.

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
for _p in (str(REPO / "src"), str(REPO)):
    if _p not in sys.path:
        sys.path.insert(0, _p)

DOC_FILES = sorted(
    set(REPO.glob("*.md"))
    | set((REPO / "docs").glob("**/*.md"))
    | {REPO / "benchmarks" / "README.md"}
)
# Narrative/state files whose snippets are illustrative history, not API
# promises (ROADMAP quotes flags mid-prose, SNIPPETS is third-party code).
SNIPPET_EXEMPT = {"ROADMAP.md", "SNIPPETS.md", "PAPERS.md", "PAPER.md",
                  "CHANGES.md", "ISSUE.md"}

FENCE_RE = re.compile(r"^```(\w[\w-]*)?[^\n]*\n(.*?)^```\s*$",
                      re.MULTILINE | re.DOTALL)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links(path: Path, text: str) -> list:
    errors = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            errors.append(f"{path.relative_to(REPO)}: broken link -> {target}")
    return errors


def run_snippets(path: Path, text: str) -> list:
    blocks = [body for lang, body in FENCE_RE.findall(text)
              if lang == "python"]
    if not blocks:
        return []
    ns: dict = {"__name__": f"docs_snippet:{path.name}"}
    for i, body in enumerate(blocks):
        t0 = time.time()
        try:
            exec(compile(body, f"{path.name}[snippet {i + 1}]", "exec"), ns)
        except Exception as e:
            return [f"{path.relative_to(REPO)}: snippet {i + 1} failed: "
                    f"{type(e).__name__}: {e}"]
        print(f"  ok: {path.relative_to(REPO)} snippet {i + 1} "
              f"({time.time() - t0:.1f}s)")
    return []


def main() -> int:
    errors = []
    for path in DOC_FILES:
        text = path.read_text()
        errors += check_links(path, text)
        if path.name not in SNIPPET_EXEMPT:
            errors += run_snippets(path, text)
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"ok: {len(DOC_FILES)} doc files checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
